"""InternVL2-26B [arXiv:2404.16821]: InternViT-6B frontend (stub: 1024
patch embeddings at 3200d) + InternLM2-20B text backbone: 48L d6144 48H
GQA(kv=8) ff16384 v92553."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=16384, vocab=92553, n_patches=1024, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm", n_layers=2, d_model=96, n_heads=6,
    n_kv_heads=2, d_ff=256, vocab=512, n_patches=8,
)
