"""Logical-axis sharding rules (MaxText-style) — DP/FSDP/TP/EP/SP as config.

Every parameter and activation names its dims with *logical* axes; a rule
table maps logical axes onto mesh axes.  The resolver silently degrades
(replicates) when a dim isn't divisible by the mapped mesh extent — e.g.
kv_heads=8 on a 16-way "model" axis — and records the degradation so the
dry-run can report it.

This is the Fix worldview applied to SPMD: the *placement* of every tensor
is declared up front, and the platform (XLA's partitioner) performs all
resulting I/O (collectives).  Changing a rule = changing the data-movement
schedule, which is exactly what the §Perf hillclimb iterates on.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def compat_shard_map(f, *, mesh, in_specs, out_specs, manual_axes=None,
                     check: bool = False):
    """``shard_map`` across jax versions.

    jax >= 0.6 exposes ``jax.shard_map(check_vma=..., axis_names=...)``;
    jax < 0.5 only has ``jax.experimental.shard_map`` with the inverse
    ``auto=`` convention.  ``manual_axes`` names the manually-mapped mesh
    axes (None = all of them)."""
    manual = (frozenset(manual_axes) if manual_axes is not None
              else frozenset(mesh.axis_names))
    new = getattr(jax, "shard_map", None)
    if new is not None:
        kwargs = {"check_vma": check}
        if manual_axes is not None:
            kwargs["axis_names"] = manual
        return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   **kwargs)
    from jax.experimental.shard_map import shard_map as old
    return old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check, auto=frozenset(mesh.axis_names) - manual)


# ---------------------------------------------------------------- rule sets
# logical axis -> mesh axis name, tuple of names, or None (replicate)
BASE_RULES: dict[str, object] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "res_seq": None,            # residual stream between blocks; "model" = SP
    "kv_seq": "model",          # decode: KV cache length is context-parallel
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "conv_dim": "model",
    # params (p_*: how weights are laid out at rest)
    "p_embed": "data",          # FSDP / ZeRO-3 over the intra-pod data axis
    "p_mlp": "model",           # tensor parallel
    "p_heads": "model",
    "p_kv_heads": "model",
    "p_vocab": "model",
    "p_experts": "model",       # expert parallel
    "p_ssm_heads": "model",
    "p_conv_dim": "model",
    "p_lora": None,
    "p_layers": None,           # scan axis
    "p_none": None,
}


def make_rules(**overrides) -> dict:
    rules = dict(BASE_RULES)
    rules.update(overrides)
    return rules


# named variants used by the perf hillclimb
RULE_VARIANTS: dict[str, dict] = {
    "baseline": make_rules(),
    "seqpar": make_rules(res_seq="model"),                    # Megatron-style SP:
    # only the residual stream is seq-sharded; RS/AG at block boundaries
    "fsdp_pod": make_rules(p_embed=("pod", "data")),         # ZeRO across pods too
    "no_fsdp": make_rules(p_embed=None),                      # pure TP weights
    "ep_wide": make_rules(p_experts=("data", "model"), experts=("data", "model")),
    "seqpar_no_fsdp": make_rules(res_seq="model", p_embed=None),
    "seqpar_ep_wide": make_rules(res_seq="model", p_experts=("data", "model")),
}


@dataclass
class Sharder:
    """Resolves logical axis names to NamedShardings; no-op without a mesh."""

    mesh: Optional[Mesh] = None
    rules: dict = field(default_factory=make_rules)
    degradations: list = field(default_factory=list)

    def spec(self, axes: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for logical ``axes`` (checked against ``shape``)."""
        if self.mesh is None:
            return P()
        mesh_axes = dict(zip(self.mesh.axis_names, self.mesh.shape.values()))
        parts = []
        used: set[str] = set()
        for i, name in enumerate(axes):
            rule = self.rules.get(name) if name is not None else None
            if rule is None:
                parts.append(None)
                continue
            names = (rule,) if isinstance(rule, str) else tuple(rule)
            names = tuple(n for n in names if n in mesh_axes and n not in used)
            if not names:
                parts.append(None)
                continue
            extent = 1
            for n in names:
                extent *= mesh_axes[n]
            if shape is not None and shape[i] % extent != 0:
                # degrade: drop trailing axes until divisible
                while names and shape[i] % extent != 0:
                    extent //= mesh_axes[names[-1]]
                    names = names[:-1]
                self.degradations.append((tuple(axes), i, name))
            if not names:
                parts.append(None)
                continue
            used.update(names)
            parts.append(names[0] if len(names) == 1 else names)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def named(self, axes: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def __call__(self, x, *axes: Optional[str]):
        """Constrain activation ``x`` to the resolved sharding.  Inside a
        shard_map (e.g. the pod-manual EF-int8 grad sync) the constraint
        rebinds to the ambient abstract mesh with manual axes excluded."""
        if self.mesh is None:
            return x
        # jax < 0.5 has no ambient abstract mesh: nothing to rebind against
        get_ctx = getattr(jax.sharding, "get_abstract_mesh", None)
        ctx = get_ctx() if get_ctx is not None else None
        if ctx is not None and getattr(ctx, "_any_axis_manual", False):
            manual = {n for n, t in zip(ctx.axis_names, ctx.axis_types)
                      if str(t) == "Manual"}
            sub = Sharder(ctx, {k: self._strip(v, manual)
                                for k, v in self.rules.items()})
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(ctx, sub.spec(axes, x.shape)))
        return jax.lax.with_sharding_constraint(x, self.named(axes, x.shape))

    @staticmethod
    def _strip(rule, manual: set):
        if rule is None:
            return None
        names = (rule,) if isinstance(rule, str) else tuple(rule)
        kept = tuple(n for n in names if n not in manual)
        return kept if kept else None

    def with_rules(self, **overrides) -> "Sharder":
        return Sharder(self.mesh, make_rules(**{**self.rules, **overrides}))
