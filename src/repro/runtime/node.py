"""A Fixpoint node: content-addressed store + evaluator + worker pool.

Workers execute exactly one Thunk reduction step per job (the codelet runs
to completion, never blocking — Fix guarantee #3).  Tail-call results go
back to the cluster scheduler, which may re-place them (paper §4.2.2).

Accounting distinguishes *busy* (codelet running), *starved* (worker slot
occupied while waiting on "internal" I/O — the ablation mode), and idle,
mirroring the paper's /proc/stat (idle+iowait) measurements in fig 8b.
Durations are measured on the cluster's clock: real nanoseconds under a
``WallClock``, simulated nanoseconds under a ``VirtualClock`` (where codelet
compute is instantaneous and only modeled I/O takes time — which is what
makes utilization fractions reproducible bit-for-bit).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Optional

from ..core import Evaluator, Handle, Repository
from .clock import Clock, WallClock


@dataclass
class WorkItem:
    job_id: int
    epoch: int
    thunk: Optional[Handle]          # None => strictify op on strict_target
    strict_target: Optional[Handle] = None
    # "internal I/O" ablation: (handle, seconds) fetches the worker performs
    # while occupying its slot.  Empty in externalized mode.
    internal_fetches: list = field(default_factory=list)
    ram_bytes: int = 0


class Node:
    def __init__(self, node_id: str, n_workers: int, ram_bytes: int = 64 << 30,
                 clock: Optional[Clock] = None, trace=None,
                 compute_model: Optional[dict] = None):
        self.id = node_id
        self.clock = clock if clock is not None else WallClock()
        self.trace = trace  # cluster's TraceRecorder (None = tracing off)
        # codelet name -> modeled seconds, charged as clock.sleep after an
        # APPLICATION step (CodeletProfile.calibrate() output).  None (the
        # default) keeps codelet compute free — schedules byte-identical
        # to every pre-model trace.
        self.compute_model = compute_model
        self.repo = Repository(node_id)
        self.evaluator = Evaluator(self.repo)
        self.n_workers = n_workers
        self.ram_bytes = ram_bytes
        self.queue = self.clock.make_queue()
        self.nic_lock = self.clock.make_lock()  # serializes the bandwidth share
        self.alive = True
        self.busy_ns = 0
        self.starved_ns = 0
        self.jobs_run = 0
        self._threads: list[threading.Thread] = []
        self._acct_lock = threading.Lock()
        self._fetcher: Optional[Callable] = None

    # ------------------------------------------------------------ lifecycle
    def start(self, on_done: Callable, fetcher: Optional[Callable] = None) -> None:
        """``on_done(node, item, result_or_exc)`` posts back to the scheduler.
        ``fetcher(node, handle)`` performs a blocking fetch (internal-I/O mode
        only; externalized mode never passes fetches to workers)."""
        self._fetcher = fetcher
        for i in range(self.n_workers):
            t = self.clock.spawn(lambda cb=on_done: self._worker_loop(cb),
                                 name=f"{self.id}-w{i}")
            self._threads.append(t)

    def stop(self) -> None:
        for _ in self._threads:
            self.queue.put(None)
        with self.clock.external_wait():  # workers need the clock to drain
            for t in self._threads:
                t.join(timeout=5)
        self._threads.clear()

    def kill(self) -> None:
        """Fail-stop: lose the store, stop accepting work."""
        self.alive = False
        self.repo = Repository(self.id + "-reborn")  # all local data lost
        self.evaluator = Evaluator(self.repo)

    def revive(self) -> None:
        """Rejoin after a crash: empty store (``kill`` already replaced
        it), same worker threads — they kept draining-and-dropping while
        dead and resume real work the moment ``alive`` flips.  The caller
        (cluster) must rewire put listeners onto the reborn repository."""
        self.alive = True

    # -------------------------------------------------------------- workers
    def _worker_loop(self, on_done: Callable) -> None:
        while True:
            item = self.queue.get()
            if item is None:
                return
            if not self.alive:
                continue  # dropped on the floor; scheduler reassigns via epoch
            if item.internal_fetches and self._fetcher is not None:
                # "internal" I/O: the slot is held while dependencies arrive —
                # this is the starvation the paper measures in fig 8a/8b.
                # A failing fetch (e.g. no surviving source) is reported to
                # the scheduler like any run error: the slot survives, the
                # starved window is accounted, and the traced starve_begin
                # always gets its starve_end.
                tr = self.trace
                if tr is not None:
                    tr.emit("starve_begin", node=self.id, job=item.job_id,
                            declared=[h.content_key().hex()
                                      for h, _ in item.internal_fetches])
                t0 = self.clock.ns()
                fetch_exc = None
                try:
                    for handle, _cost in item.internal_fetches:
                        self._fetcher(self, handle)
                except Exception as e:  # noqa: BLE001 — reported to scheduler
                    fetch_exc = e
                with self._acct_lock:
                    self.starved_ns += self.clock.ns() - t0
                if tr is not None:
                    tr.emit("starve_end", node=self.id, job=item.job_id)
                if fetch_exc is not None:
                    on_done(self, item, fetch_exc)
                    continue
            t0 = self.clock.ns()
            apps0 = self.evaluator.applications
            try:
                if item.thunk is None:
                    result = self.evaluator.strictify(item.strict_target)
                else:
                    result = self.evaluator.think(item.thunk)
            except Exception as e:  # noqa: BLE001 — reported to scheduler
                result = e
            if (self.compute_model is not None
                    and self.evaluator.applications > apps0
                    and not isinstance(result, Exception)):
                # Charge the calibrated constant for the codelet that just
                # ran; under a VirtualClock the sleep rides the event heap,
                # so modeled compute is deterministic and shows up in the
                # makespan / busy accounting like real work would.
                cost = self.compute_model.get(self.evaluator.last_codelet, 0.0)
                if cost > 0.0:
                    self.clock.sleep(cost)
            dt = self.clock.ns() - t0
            with self._acct_lock:
                self.busy_ns += dt
                self.jobs_run += 1
            on_done(self, item, result)

    # ------------------------------------------------------------- accounts
    def accounting(self) -> dict:
        return {
            "busy_s": self.busy_ns * 1e-9,
            "starved_s": self.starved_ns * 1e-9,
            "jobs": self.jobs_run,
            "workers": self.n_workers,
        }
