"""Unit tests for the Fix core: handles, repository, evaluator semantics.

PINNED raw-Table-1 module: everything here speaks the paper's interface
directly — hand-packed little-endian blobs, hand-built ``combination``
trees, explicit ``.strict()`` — deliberately bypassing the ``repro.fix``
frontend.  This keeps the core paper-faithful: the typed frontend compiles
*down to* this surface (equivalence asserted in tests/test_fix_frontend.py)
and must never be required to use it.
"""
import struct

import pytest

from repro.core import (
    AccessViolation,
    Evaluator,
    FixError,
    Handle,
    MissingData,
    Repository,
    make_limits,
    parse_limits,
    register,
)
from repro.core.stdlib import LIMITS_SMALL, combination
from repro.core.api import FixAPI


# ----------------------------------------------------------------- handles
class TestHandle:
    def test_literal_roundtrip(self):
        h = Handle.blob(b"hello")
        assert h.is_literal and h.is_blob() and h.size == 5
        assert h.literal_payload() == b"hello"

    def test_literal_threshold(self):
        assert Handle.blob(b"x" * 30).is_literal
        assert not Handle.blob(b"x" * 31).is_literal

    def test_blob_content_addressing(self):
        a, b = Handle.blob(b"y" * 100), Handle.blob(b"y" * 100)
        assert a == b and hash(a) == hash(b)
        assert a != Handle.blob(b"z" * 100)

    def test_size_field(self):
        assert Handle.blob(b"q" * 1000).size == 1000

    def test_metadata_bitflips_preserve_digest(self):
        repo = Repository()
        t = repo.put_tree([Handle.blob(b"a"), Handle.blob(b"b")])
        app = t.application()
        assert app.is_thunk() and app.raw[:30] == t.raw[:30]
        enc = app.strict()
        assert enc.is_encode() and enc.unwrap_encode() == app
        assert app.unwrap_thunk() == t

    def test_encode_subkind_roundtrip(self):
        repo = Repository()
        t = repo.put_tree([])
        for mk in (Handle.application, Handle.selection_of):
            th = mk(t)
            for enc in (th.strict(), th.shallow()):
                assert enc.unwrap_encode() == th

    def test_identification_of_blob(self):
        b = Handle.blob(b"x" * 64)
        idt = b.identification()
        assert idt.is_thunk() and idt.unwrap_thunk() == b

    def test_ref_object_share_content_key(self):
        b = Handle.blob(b"w" * 64)
        assert b.content_key() == b.as_ref().content_key()
        assert b.as_ref().as_object() == b

    def test_invalid_constructions(self):
        b = Handle.blob(b"small")
        with pytest.raises(ValueError):
            b.application()  # blobs aren't combinations
        with pytest.raises(ValueError):
            b.strict()  # encodes wrap thunks only


# -------------------------------------------------------------- repository
class TestRepository:
    def test_blob_tree_roundtrip(self):
        repo = Repository()
        b = repo.put_blob(b"n" * 99)
        t = repo.put_tree([b, Handle.blob(b"lit")])
        assert repo.get_blob(b) == b"n" * 99
        assert repo.get_tree(t)[0] == b

    def test_missing_data(self):
        repo = Repository()
        ghost = Handle.blob(b"g" * 77)
        with pytest.raises(MissingData):
            repo.get_blob(ghost)
        assert not repo.contains(ghost)
        assert repo.contains(Handle.blob(b"tiny"))  # literals always resident

    def test_footprint_objects_vs_refs(self):
        repo = Repository()
        big = repo.put_blob(b"d" * 1000)
        t = repo.put_tree([big, big.as_ref()])
        fp = repo.footprint(t)
        assert big.content_key() in fp.data
        assert big.content_key() in fp.refs
        # refs do not force data residency
        assert repo.missing(t.as_ref()) == []

    def test_footprint_lazy_thunks(self):
        repo = Repository()
        inner = combination(repo, "add", Handle.blob(b"\x01"), Handle.blob(b"\x02"))
        outer = repo.put_tree([inner])  # bare thunk: stays lazy
        fp = repo.footprint(outer)
        assert fp.encodes == []
        outer2 = repo.put_tree([inner.strict()])  # encode: must evaluate
        fp2 = repo.footprint(outer2)
        assert len(fp2.encodes) == 1

    def test_transitive_size_and_export(self):
        a = Repository("a")
        blob = a.put_blob(b"z" * 500)
        tree = a.put_tree([blob, blob])  # dedup: shared child counts once
        assert a.transitive_size(tree) == 500 + 32 * 2
        b = Repository("b")
        moved = a.export(tree, b)
        assert moved == 500 + 64
        assert b.get_blob(blob) == b"z" * 500
        # second export is free — content addressing dedups
        assert a.export(tree, b) == 0

    def test_limits_roundtrip(self):
        raw = make_limits(ram_bytes=123456, cpu_slots=3)
        parsed = parse_limits(raw)
        assert parsed["ram_bytes"] == 123456 and parsed["cpu_slots"] == 3

    def test_blob_bytes_is_maintained_counter(self):
        repo = Repository()
        repo.put_blob(b"a" * 100)
        repo.put_blob(b"a" * 100)  # content-addressed dedup: counted once
        repo.put_blob(b"b" * 50)
        repo.put_blob(b"tiny")     # literal: never stored
        assert repo.stats()["blob_bytes"] == 150
        other = Repository()
        h = other.put_blob(b"c" * 70)
        repo.put_handle_data(h, other.get_blob(h))  # network-install path
        repo.put_handle_data(h, other.get_blob(h))  # duplicate: no recount
        assert repo.stats()["blob_bytes"] == 220

    def test_put_listener_fires_once_per_new_content(self):
        repo = Repository()
        seen = []
        repo.add_put_listener(lambda h: seen.append(h.content_key()))
        b = repo.put_blob(b"c" * 100)
        repo.put_blob(b"c" * 100)      # dedup: no second notification
        t = repo.put_tree([b])
        repo.put_tree([b])
        repo.put_blob(b"small-literal")  # literals never notify
        assert seen == [b.content_key(), t.content_key()]

    def test_strict_memo_public_api(self):
        repo = Repository()
        t = repo.put_tree([Handle.blob(b"x")])
        assert repo.strict_memo_get(t) is None
        repo.strict_memo_put(t, t)
        assert repo.strict_memo_get(t) == t
        repo.strict_memo_put(t, t.as_ref())  # first-write-wins
        assert repo.strict_memo_get(t) == t

    def test_footprint_cache_returns_fresh_copies(self):
        repo = Repository()
        big = repo.put_blob(b"d" * 1000)
        t = repo.put_tree([big])
        fp1 = repo.footprint(t)
        fp1.data.clear()  # caller mutation must not poison the cache
        fp2 = repo.footprint(t)
        assert fp2.data == {t.content_key(), big.content_key()}

    def test_footprint_incomplete_not_cached(self):
        """A footprint computed while a subtree is absent must grow once
        the subtree arrives (no stale complete-cache entry)."""
        repo = Repository()
        blob = Handle.blob(b"q" * 200)
        child = Handle.tree([blob])       # handle only: content not stored
        parent = repo.put_tree([child])
        fp = repo.footprint(parent)
        assert blob.content_key() not in fp.data  # children unknown
        repo.put_tree([blob])             # child tree content arrives
        repo.put_blob(b"q" * 200)
        fp2 = repo.footprint(parent)
        assert blob.content_key() in fp2.data

    def test_missing_uses_closure_and_tracks_eviction(self):
        repo = Repository()
        blob = repo.put_blob(b"m" * 300)
        t = repo.put_tree([blob])
        assert repo.missing(t) == []      # complete: closure now cached
        repo._blobs.pop(blob.content_key(), None)
        assert repo.missing(t) == [blob]  # residency re-checked every call


# --------------------------------------------------------------- evaluator
class TestEvaluator:
    def test_add(self):
        repo = Repository()
        ev = Evaluator(repo)
        th = combination(repo, "add", Handle.blob((3).to_bytes(8, "little")),
                         Handle.blob((4).to_bytes(8, "little")))
        out = ev.evaluate(th.strict())
        assert int.from_bytes(repo.get_blob(out), "little") == 7

    def test_memoization(self):
        repo = Repository()
        ev = Evaluator(repo)
        th = combination(repo, "add", Handle.blob((5).to_bytes(8, "little", signed=True)),
                         Handle.blob((6).to_bytes(8, "little", signed=True)))
        r1 = ev.evaluate(th.strict())
        n = ev.applications
        r2 = ev.evaluate(th.strict())
        assert r1 == r2 and ev.applications == n  # cache hit, no re-run

    def test_chain_constant_stack(self):
        repo = Repository()
        ev = Evaluator(repo)
        th = combination(
            repo, "inc_chain",
            Handle.blob((0).to_bytes(8, "little", signed=True)),
            Handle.blob((5000).to_bytes(8, "little", signed=True)),
        )
        out = ev.evaluate(th.strict())
        assert int.from_bytes(repo.get_blob(out), "little", signed=True) == 5000
        assert ev.applications == 5001

    def test_fib(self):
        repo = Repository()
        ev = Evaluator(repo)
        th = combination(repo, "fib", Handle.blob((10).to_bytes(8, "little", signed=True)))
        out = ev.evaluate(th.strict())
        assert int.from_bytes(repo.get_blob(out), "little", signed=True) == 55

    def test_fib_memoizes_subproblems(self):
        repo = Repository()
        ev = Evaluator(repo)
        th = combination(repo, "fib", Handle.blob((15).to_bytes(8, "little", signed=True)))
        ev.evaluate(th.strict())
        # naive fib(15) needs 1219 calls; memoized needs O(n) fib + adds
        assert ev.applications < 50

    def test_lazy_if_untaken_branch_never_runs(self):
        repo = Repository()
        ev = Evaluator(repo)
        bomb = combination(repo, "add", Handle.blob(b"bad"), Handle.blob(b"bad"))
        good = combination(repo, "add", Handle.blob((1).to_bytes(8, "little", signed=True)),
                           Handle.blob((2).to_bytes(8, "little", signed=True)))
        th = combination(repo, "fix_if",
                         Handle.blob((1).to_bytes(8, "little", signed=True)), good, bomb)
        out = ev.evaluate(th.strict())
        assert int.from_bytes(repo.get_blob(out), "little", signed=True) == 3

    def test_selection_on_tree(self):
        repo = Repository()
        ev = Evaluator(repo)
        kids = [repo.put_blob(bytes([i]) * 40) for i in range(5)]
        t = repo.put_tree(kids)
        pair = repo.put_tree([t, repo.put_blob(struct.pack("<q", 3))])
        sel = pair.selection_of()
        out = ev.evaluate(sel.strict())
        assert repo.get_blob(out) == bytes([3]) * 40

    def test_selection_subrange_blob(self):
        repo = Repository()
        ev = Evaluator(repo)
        b = repo.put_blob(bytes(range(100)))
        pair = repo.put_tree([b, repo.put_blob(struct.pack("<qq", 10, 5))])
        out = ev.evaluate(pair.selection_of().strict())
        assert repo.get_blob(out) == bytes(range(10, 15))

    def test_shallow_returns_ref(self):
        repo = Repository()
        ev = Evaluator(repo)
        payload = b"r" * 200
        th = combination(repo, "identity", repo.put_blob(payload))
        out = ev.eval_encode(th.shallow())
        assert out.is_ref() and out.size == 200

    def test_strict_promotes_nested(self):
        repo = Repository()
        ev = Evaluator(repo)
        inner = combination(repo, "add", Handle.blob((1).to_bytes(8, "little", signed=True)),
                            Handle.blob((1).to_bytes(8, "little", signed=True)))
        t = repo.put_tree([inner, repo.put_blob(b"k" * 50).as_ref()])
        out = ev.strictify(t)
        kids = repo.get_tree(out)
        assert kids[0].is_data() and kids[1].is_object()

    def test_sealed_container_enforced(self):
        repo = Repository()
        ev = Evaluator(repo)
        secret = repo.put_blob(b"s" * 100)  # resident but NOT in the container

        @register("leaky")
        def _leaky(api: FixAPI, comb: Handle) -> Handle:
            api.read_blob(secret)  # must be denied
            return api.create_int(0)

        th = combination(repo, "leaky", Handle.blob(b"x"))
        with pytest.raises(FixError, match="AccessViolation|outside"):
            ev.evaluate(th.strict())

    def test_evaluator_never_fetches(self):
        repo = Repository()
        ev = Evaluator(repo)
        ghost = Handle.blob(b"gg" * 40)  # content never stored
        th = combination(repo, "add", ghost, Handle.blob((1).to_bytes(8, "little", signed=True)))
        with pytest.raises(MissingData):
            ev.evaluate(th.strict())

    def test_unknown_procedure(self):
        repo = Repository()
        ev = Evaluator(repo)
        tree = repo.put_tree([repo.put_blob(LIMITS_SMALL), repo.put_blob(b"fix/proc/nope")])
        with pytest.raises(FixError, match="unknown procedure"):
            ev.evaluate(tree.application().strict())
