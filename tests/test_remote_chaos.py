"""Seeded chaos against the real multi-process backend.

The PR-6 chaos invariant, re-asserted across an actual process boundary:
under seeded schedules of worker SIGKILLs mid-job, control-frame
truncation, at-rest store rot and heartbeat stalls, every submitted job
either completes with result raws byte-identical to the clean run or
fails with an *attributed typed* error — never a hang, never silent
corruption — and the captured traces pass fault-mode
``verify_invariants`` exactly like the simulator's.

Determinism note: the *schedules* are deterministic (same seed → same
injection points), but real thread/process interleaving varies, so the
assertion is schedule-shaped (outcome contract) rather than replay-shaped
(bit-identical traces) — see ``repro.remote.chaos``'s module docstring.

``FIX_REMOTE_CHAOS_SEED`` rotates one extra mixed-fault schedule in CI so
the seed grid keeps growing beyond the fixed ten.
"""
import os
import time

import pytest

import repro.fix as fix
from repro.core.repository import CorruptData, MissingData
from repro.core.stdlib import add, checksum_tree, fib, inc_chain
from repro.fix.future import CancelledError, DeadlineExceeded
from repro.remote import (
    RemoteBackend,
    RemoteChaos,
    RemoteError,
    WorkerCrashed,
    seeded_chaos,
)
from repro.runtime import TraceRecorder, verify_invariants
from repro.runtime.faults import TransferFailed

pytestmark = pytest.mark.usefixtures("no_thread_leaks")

# the acceptance contract: any failure must be one of these, attributed —
# WorkerCrashed only when the respawn+resubmit budget ran out
ALLOWED_FAILURES = (WorkerCrashed, CorruptData, TransferFailed,
                    DeadlineExceeded, CancelledError, MissingData,
                    RemoteError)

_BLOBS = [bytes([i]) * 1024 for i in range(4)]


@fix.codelet
def chaos_stall(ms: int) -> int:
    time.sleep(ms / 1000.0)
    return ms


def _programs(repo):
    tree = repo.put_tree([repo.put_blob(b) for b in _BLOBS])
    return [fib(8), add(21, 21), inc_chain(0, 4), checksum_tree(tree)]


_baseline_raws = None


def _baseline():
    """Clean-run result raws (content-addressed, so backend-independent)."""
    global _baseline_raws
    if _baseline_raws is None:
        with fix.local() as lb:
            futs = [lb.submit(p) for p in _programs(lb.repo)]
            _baseline_raws = [f.result(timeout=60).raw for f in futs]
    return _baseline_raws


def _dump_on_failure(tr, tag):
    """Write the failing case's trace where CI can upload it."""
    from pathlib import Path
    out = Path(os.environ.get("FIX_FUZZ_ARTIFACTS", "fuzz-artifacts"))
    out.mkdir(parents=True, exist_ok=True)
    tr.save(out / f"{tag}.jsonl")


def run_chaos_case(chaos, *, store="memory", store_dir=None, tag="case",
                   **backend_kw):
    """One schedule end-to-end.  Returns (failures, stats); asserts the
    completes-identically-or-fails-typed contract and trace invariants."""
    tr = TraceRecorder()
    kw = dict(n_workers=2, trace=tr, chaos=chaos, store=store,
              store_dir=store_dir, heartbeat_s=0.1, heartbeat_miss_budget=3,
              heartbeat_timeout_s=0.2, retry_backoff_s=0.02,
              drain_timeout_s=15.0)
    kw.update(backend_kw)
    failures = []
    try:
        with RemoteBackend(**kw) as be:
            futs = [be.submit(p) for p in _programs(be.repo)]
            for f, want in zip(futs, _baseline()):
                try:
                    got = f.result(timeout=60)  # bounded: hang = test failure
                except ALLOWED_FAILURES as e:
                    failures.append(type(e).__name__)
                else:
                    assert got.raw == want, \
                        "chaotic run produced different bytes than clean run"
            stats = be.stats()
        violations = verify_invariants(tr.events)
        assert violations == [], violations
    except BaseException:
        _dump_on_failure(tr, f"remote-chaos-{tag}")
        raise
    return failures, stats


# ------------------------------------------------------- seeded schedules
@pytest.mark.parametrize("seed", range(10))
def test_seeded_kill_mid_job(seed):
    chaos = seeded_chaos(seed, ["w0", "w1"], n_faults=2, kinds=("kill",))
    failures, stats = run_chaos_case(chaos, tag=f"kill-{seed}")
    # with the default respawn budget a SIGKILL costs retries, not answers
    assert failures == [], failures


@pytest.mark.parametrize("seed", range(10, 14))
def test_seeded_frame_truncation(seed):
    chaos = seeded_chaos(seed, ["w0", "w1"], n_faults=2, kinds=("truncate",))
    failures, stats = run_chaos_case(chaos, tag=f"truncate-{seed}")
    assert failures == [], failures


@pytest.mark.parametrize("seed", range(20, 24))
def test_seeded_store_rot_file_store(seed, tmp_path):
    chaos = seeded_chaos(seed, ["w0", "w1"], n_faults=2, kinds=("rot",))
    failures, stats = run_chaos_case(chaos, store="file", tag=f"rot-{seed}",
                                     store_dir=str(tmp_path))
    # rot may surface as a typed CorruptData when lineage recovery cannot
    # help; anything else must still be the clean answer
    assert all(f in ("CorruptData", "RemoteError") for f in failures), failures


@pytest.mark.parametrize("seed", range(30, 34))
def test_seeded_heartbeat_stall(seed):
    chaos = seeded_chaos(seed, ["w0", "w1"], n_faults=2, kinds=("stall",))
    failures, stats = run_chaos_case(chaos, tag=f"stall-{seed}")
    # a stalled-heartbeat worker is fenced and replaced: answers survive
    assert failures == [], failures


def test_rotating_seed_mixed_faults():
    """CI rotates FIX_REMOTE_CHAOS_SEED (run id) so the grid keeps growing;
    locally this runs one extra mixed schedule at seed 0."""
    seed = int(os.environ.get("FIX_REMOTE_CHAOS_SEED", "0"))
    chaos = seeded_chaos(seed, ["w0", "w1"], n_faults=3,
                         kinds=("kill", "truncate", "rot", "stall"))
    failures, stats = run_chaos_case(chaos, tag=f"rotating-{seed}")
    assert all(f in ("CorruptData", "RemoteError") for f in failures), failures


# ------------------------------------------------------ targeted recovery
def test_respawn_resubmits_and_answers():
    """SIGKILL the only worker mid-step: the job still completes (respawn
    + resubmit), the trace shows the crash answered by a node_join."""
    tr = TraceRecorder()
    chaos = RemoteChaos().kill_worker("w0", after_send=0)
    with RemoteBackend(n_workers=1, trace=tr, chaos=chaos,
                       heartbeat_s=0.1, retry_backoff_s=0.02) as be:
        assert be.run(add(2, 3), timeout=60) == 5
        assert be.stats()["recovery"]["respawns"] >= 1
        assert be.stats()["recovery"]["resubmits"] >= 1
    kinds = [e.kind for e in tr.events]
    assert "worker_respawn" in kinds
    assert "node_join" in kinds
    assert "job_resubmit" in kinds
    assert verify_invariants(tr.events) == []


def test_respawn_budget_exhausts_to_typed_workercrashed():
    """Every death burns respawn budget; past it, the give-up is the typed
    WorkerCrashed the acceptance contract demands."""
    chaos = (RemoteChaos()
             .kill_worker("w0", after_send=0)
             .kill_worker("w0", after_send=1)
             .kill_worker("w0", after_send=2)
             .kill_worker("w0", after_send=3)
             .kill_worker("w0", after_send=4))
    with RemoteBackend(n_workers=1, chaos=chaos, max_respawns=2,
                       heartbeat_s=0.1, retry_backoff_s=0.02,
                       job_retry_limit=6) as be:
        with pytest.raises(WorkerCrashed):
            be.submit(add(1, 1)).result(timeout=60)


def test_dropped_frame_is_resubmitted_by_watchdog():
    """A silently dropped submit frame strands the step RUNNING; the
    dispatch watchdog resubmits it instead of hanging."""
    tr = TraceRecorder()
    chaos = RemoteChaos().drop_frame("w0", at_send=0)
    with RemoteBackend(n_workers=1, trace=tr, chaos=chaos,
                       heartbeat_s=0.05, dispatch_timeout_s=0.3,
                       retry_backoff_s=0.02) as be:
        assert be.run(add(7, 8), timeout=60) == 15
    assert any(e.kind == "job_resubmit" for e in tr.events)
    assert verify_invariants(tr.events) == []


def test_delayed_frame_still_completes():
    chaos = RemoteChaos().delay_frame("w0", at_send=0, delay_s=0.2)
    with RemoteBackend(n_workers=1, chaos=chaos, heartbeat_s=0.1) as be:
        assert be.run(add(1, 2), timeout=60) == 3


def test_rot_recovers_from_client_repo(tmp_path):
    """Rot an input blob at rest: read-time verification quarantines it
    and the client's own copy re-seeds the store — the job completes with
    clean bytes."""
    tr = TraceRecorder()
    # every input blob put is a candidate; rot the first few store puts
    chaos = RemoteChaos().rot_store(at_put=0).rot_store(at_put=1)
    failures, stats = run_chaos_case(chaos, store="file",
                                     store_dir=str(tmp_path))
    assert failures == [], failures


def test_rot_emits_quarantine_events(tmp_path):
    tr = TraceRecorder()
    chaos = RemoteChaos().rot_store(at_put=0)
    with RemoteBackend(n_workers=1, trace=tr, chaos=chaos, store="file",
                       store_dir=str(tmp_path), heartbeat_s=0.1,
                       retry_backoff_s=0.02) as be:
        with fix.local() as lb:
            want = lb.run(checksum_tree(
                lb.repo.put_tree([lb.repo.put_blob(b) for b in _BLOBS])))
        tree = be.repo.put_tree([be.repo.put_blob(b) for b in _BLOBS])
        assert be.run(checksum_tree(tree), timeout=60) == want
        assert be.quarantines >= 1
    kinds = [e.kind for e in tr.events]
    assert "corruption_detected" in kinds
    assert "quarantine" in kinds
    assert verify_invariants(tr.events) == []


def test_heartbeat_fence_turns_silence_into_death():
    """Swallow enough pongs and the monitor fences the worker: the run
    still answers (respawn + resubmit), and the fence is counted."""
    chaos = RemoteChaos().stall_heartbeats("w0", count=2)
    with RemoteBackend(n_workers=1, chaos=chaos, heartbeat_s=0.05,
                       heartbeat_miss_budget=2, heartbeat_timeout_s=0.1,
                       retry_backoff_s=0.02) as be:
        assert be.run(chaos_stall(1000), timeout=60) == 1000
        assert be.stats()["recovery"]["hb_fences"] >= 1


def test_cancel_future_prunes_job():
    tr = TraceRecorder()
    with RemoteBackend(n_workers=1, trace=tr) as be:
        fut = be.submit(chaos_stall(5000))
        assert fut.cancel() is True
        with pytest.raises(CancelledError):
            fut.result(timeout=30)
        # the backend survives and schedules new work immediately
        assert be.run(add(1, 1), timeout=60) == 2
    assert any(e.kind == "job_cancel" for e in tr.events)
    assert verify_invariants(tr.events) == []


def test_deadline_is_typed_and_prunes():
    with RemoteBackend(n_workers=1) as be:
        with pytest.raises(DeadlineExceeded):
            be.submit(chaos_stall(5000), deadline_s=0.2).result(timeout=30)
        assert be.run(add(2, 2), timeout=60) == 4
