"""Fused RMSNorm Pallas kernel: one HBM round-trip per row block.

Bandwidth-bound op: the unfused lowering reads x for the reduction and
again for the scale (plus writes); the kernel streams a [block_rows, D]
tile through VMEM once, computing stats in f32 VREGs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    o_ref[...] = (xf * inv * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, w, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: [..., D], w: [D]."""
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
