"""Qwen3-4B [hf]: 36L d2560 32H GQA(kv=8) ff9728 v151936, qk-norm."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense", n_layers=36, d_model=2560, n_heads=32,
    n_kv_heads=8, d_ff=9728, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke", family="dense", n_layers=2, d_model=96, n_heads=4,
    n_kv_heads=2, d_ff=192, vocab=512, head_dim=24, qk_norm=True,
)
