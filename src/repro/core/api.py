"""The Table-1 Fix API, as a sealed capability handed to codelets.

A running invocation may only read data reachable as *Objects* from its
definition Tree — the sealed container.  Refs may be inspected (type/size)
but not read.  Creating Blobs/Trees and minting Thunks/Encodes is always
allowed: those are the invocation's outputs and cannot enlarge its own
footprint (paper §3.3 — a function may create children with different
minimum repositories but can't change its own).

This enforcement is what the paper gets from Wasm memory-safety; we get it
from capability discipline at the API boundary, which our property tests
exercise directly.
"""
from __future__ import annotations

import struct
from typing import Iterable, Sequence

from .handle import BLOB, TREE, Handle
from .procedures import procedure_blob
from .repository import MissingData, Repository


class AccessViolation(PermissionError):
    """A codelet tried to read data outside its sealed container."""


class FixAPI:
    """Capability object passed to codelets as their only I/O surface."""

    __slots__ = ("_repo", "_accessible", "_reads", "_writes")

    def __init__(self, repo: Repository, accessible: set):
        self._repo = repo
        self._accessible = accessible  # content keys readable by this codelet
        self._reads = 0
        self._writes = 0

    # ------------------------------------------------------------- checks
    def _check_readable(self, handle: Handle) -> None:
        if handle.is_literal:
            return
        if not handle.is_object():
            raise AccessViolation(f"not an accessible Object: {handle!r}")
        if handle.content_key() not in self._accessible:
            raise AccessViolation(f"outside minimum repository: {handle!r}")

    def _grant(self, handle: Handle) -> None:
        """Data created by the codelet itself becomes readable to it."""
        if not handle.is_literal:
            self._accessible.add(handle.content_key())

    # ------------------------------------------------------------- Table 1
    def read_blob(self, handle: Handle) -> bytes:
        if handle.content_type != BLOB:
            raise AccessViolation("read_blob on a non-blob")
        self._check_readable(handle)
        self._reads += 1
        return self._repo.get_blob(handle)

    def read_tree(self, handle: Handle) -> tuple[Handle, ...]:
        if handle.content_type != TREE:
            raise AccessViolation("read_tree on a non-tree")
        self._check_readable(handle)
        self._reads += 1
        return self._repo.get_tree(handle)

    def create_blob(self, payload: bytes) -> Handle:
        self._writes += 1
        h = self._repo.put_blob(payload)
        self._grant(h)
        return h

    def create_tree(self, children: Sequence[Handle]) -> Handle:
        self._writes += 1
        h = self._repo.put_tree(children)
        self._grant(h)
        return h

    @staticmethod
    def application(tree: Handle) -> Handle:
        return tree.application()

    @staticmethod
    def identification(value: Handle) -> Handle:
        return value.identification()

    def selection(self, value: Handle, index: int) -> Handle:
        """Selection Thunk: pair-tree [target, index] reinterpreted."""
        pair = self.create_tree([value, self.create_blob(struct.pack("<q", index))])
        return pair.selection_of()

    @staticmethod
    def strict(thunk: Handle) -> Handle:
        return thunk.strict()

    @staticmethod
    def shallow(thunk: Handle) -> Handle:
        return thunk.shallow()

    # ------------------------------------------------- metadata inspection
    @staticmethod
    def is_blob(h: Handle) -> bool:
        return h.content_type == BLOB and h.is_data()

    @staticmethod
    def is_tree(h: Handle) -> bool:
        return h.content_type == TREE and h.is_data()

    @staticmethod
    def is_ref(h: Handle) -> bool:
        return h.is_ref()

    @staticmethod
    def is_thunk(h: Handle) -> bool:
        return h.is_thunk()

    @staticmethod
    def is_encode(h: Handle) -> bool:
        return h.is_encode()

    @staticmethod
    def get_size(h: Handle) -> int:
        """Size is metadata: visible even for Refs (but not Thunks)."""
        if h.is_thunk() or h.is_encode():
            raise AccessViolation("thunks are opaque")
        return h.size

    # -------------------------------------------------------- conveniences
    # (thin sugar used by our codelets; all expressed via the Table-1 core)
    def procedure(self, name: str) -> Handle:
        """Handle naming a registered procedure — so codelets composing new
        combinations never hard-code the ``fix/proc/`` prefix."""
        return self.create_blob(procedure_blob(name))

    def read_int(self, handle: Handle) -> int:
        data = self.read_blob(handle)
        return int.from_bytes(data, "little", signed=True)

    def create_int(self, value: int, width: int = 8) -> Handle:
        return self.create_blob(value.to_bytes(width, "little", signed=True))

    @property
    def io_counts(self) -> tuple[int, int]:
        return (self._reads, self._writes)
