"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Data shards are Fix thunks over a content-addressed corpus; checkpoints are
content-addressed trees (unchanged leaves dedup); a mid-run restore proves
checkpoint/restart.  This is the paper's pipeline at laptop scale — the pod
version only swaps the mesh.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
(defaults to a quicker 60-step run with a ~20M model; --full-100m for the
100M configuration)
"""
import argparse
import time

from repro.checkpoint import dedup_stats, load_step
from repro.models import ModelConfig, count_params, ops_for
from repro.parallel.steps import RunConfig
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    if args.full_100m:
        cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                          vocab=256, qk_norm=True)
        batch, seq = 16, 256
    else:
        cfg = ModelConfig(name="lm-20m", family="dense", n_layers=6,
                          d_model=384, n_heads=6, n_kv_heads=2, d_ff=1024,
                          vocab=256, qk_norm=True)
        batch, seq = 8, 128
    n = count_params(ops_for(cfg).specs(cfg))
    print(f"model: {cfg.name}  params: {n/1e6:.1f}M  steps: {args.steps}")

    runcfg = RunConfig(microbatches=2, remat="dots")
    t0 = time.time()
    state, losses, roots, repo = train(
        cfg, runcfg, steps=args.steps, batch=batch, seq=seq,
        checkpoint_every=max(args.steps // 3, 1), log_every=10)
    print(f"\ntrained {args.steps} steps in {time.time()-t0:.0f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss must improve"

    # checkpoint dedup + restart
    print("checkpoint dedup:", dedup_stats(repo, roots))
    meta, _restored = load_step(repo, roots[-1])
    print(f"restored checkpoint at step {meta['step']}; resuming 5 steps")
    state2, losses2, _, _ = train(cfg, runcfg, steps=5, batch=batch, seq=seq,
                                  resume=roots[-1], repo=repo, log_every=5)
    print("resume ok; post-restore loss:", f"{losses2[-1]:.3f}")


if __name__ == "__main__":
    main()
