from .store import dedup_stats, load_step, load_tree, save_step, save_tree
__all__ = ["save_tree", "load_tree", "save_step", "load_step", "dedup_stats"]
