"""Deterministic trace capture, replay verification and schedule analysis.

The virtual clock (PR 3) serializes every runtime event deterministically;
this module *records* them.  A :class:`TraceRecorder` passed to
``Cluster(trace=...)`` captures a typed, ordered event stream from
instrumentation points threaded through the scheduler, the transfer
manager, the worker pools and the blocking-fetch path.  Tracing is opt-in
and zero-cost when off: every emit site is guarded by an ``is None`` check
and no recorder object exists unless the caller made one.

Event vocabulary (``kind`` + fields; keys are content-key hex, ``nbytes``
counts blob bytes / 32 bytes per tree child, like the rest of the runtime):

===================  ======================================================
``job_submit``       new job created: ``job``, ``encode``, ``strict``,
                     ``parent`` (submitting job id or null), ``recompute``
``job_memo_hit``     a submission satisfied from the cluster memo table
``job_place``        placement decision: ``job``, ``node``, ``epoch``,
                     ``n_missing``, ``missing_nbytes``
``job_start``        run bound to a worker queue: ``job``, ``node``,
                     ``epoch``, ``op`` ("run" | "strictify"), ``internal``
``job_finish``       result finalized: ``job``, ``node``, ``result``
``job_fail``         job failed: ``job``, ``error`` (exception type name)
``put``              content landed in a node repository: ``node``,
                     ``key``, ``nbytes``
``stage_request``    scheduler wants a handle moved: ``job`` (null for
                     prefetch), ``dst``, ``key``, ``nbytes``, ``action``
                     ("enqueue" | "join" | "recompute"), ``src`` (enqueue)
``transfer_enqueue`` a TransferPlan submitted: ``src``, ``dst``, ``n``,
                     ``nbytes``, ``keys``, ``mode``
``link_acquire``     source NIC acquired, serialization starts: ``src``,
                     ``dst``, ``nbytes``, ``ser_s``, ``via``
``transfer_deliver`` payload installed at the destination: ``src``,
                     ``dst``, ``n``, ``nbytes``, ``keys``, ``ok``, ``via``
                     (``via``: "batched" | "per_handle" | "blocking")
``prefetch``         a prefetch pass staged toward ``node``: ``n`` handles
``spec_wakeup``      a speculation deadline fired for ``job``
``spec_duplicate``   a straggler run duplicated onto ``node``
``starve_begin``     internal-I/O worker slot blocks on fetches: ``node``,
                     ``job``, ``declared`` (keys the job needs)
``starve_end``       the slot's fetches completed: ``node``, ``job``
===================  ======================================================

Serialization is JSONL with sorted keys and no whitespace, so *identical
schedules produce byte-identical files* — the double-run determinism the
property suite (tests/test_trace_properties.py) pins, and what makes the
committed golden fixture (tests/fixtures/quickstart_trace.jsonl) a
regression net for every later scheduler change.
"""
from __future__ import annotations

import itertools
import json
import threading
from collections import Counter, defaultdict
from dataclasses import dataclass
from typing import Callable, Iterable, Optional, Union


# ------------------------------------------------------------------ events
@dataclass(frozen=True)
class TraceEvent:
    """One runtime event: global sequence number, clock time, kind, fields."""

    seq: int
    t: float
    kind: str
    fields: dict

    def to_dict(self) -> dict:
        d = {"seq": self.seq, "t": self.t, "kind": self.kind}
        d.update(self.fields)
        return d


def _as_dict(ev: Union[TraceEvent, dict]) -> dict:
    return ev.to_dict() if isinstance(ev, TraceEvent) else ev


def event_dicts(events: Iterable[Union[TraceEvent, dict]]) -> list[dict]:
    """Normalize a trace (live events or loaded JSONL rows) to dicts."""
    return [_as_dict(e) for e in events]


# ---------------------------------------------------------------- recorder
class TraceRecorder:
    """Collects :class:`TraceEvent`s from every runtime layer.

    ``Cluster(trace=recorder)`` binds the recorder to the cluster's clock
    (timestamps are ``clock.now()`` — simulated seconds under a
    ``VirtualClock``, where two identical runs yield byte-identical
    traces).  ``emit`` is called from scheduler, worker, link-worker and
    timer threads; the lock makes the sequence numbering atomic, and under
    a virtual clock the cooperative run token already serializes callers,
    so event order is deterministic.
    """

    def __init__(self):
        self.events: list[TraceEvent] = []
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self._clock = None

    def bind(self, clock) -> None:
        """Timestamps come from ``clock.now()`` from here on."""
        self._clock = clock

    def emit(self, kind: str, **fields) -> None:
        t = self._clock.now() if self._clock is not None else 0.0
        with self._lock:
            self.events.append(TraceEvent(next(self._seq), t, kind, fields))

    def __len__(self) -> int:
        return len(self.events)

    # ------------------------------------------------------- serialization
    def to_jsonl(self) -> str:
        """Byte-stable JSONL: sorted keys, no whitespace, one event/line."""
        return "".join(
            json.dumps(e.to_dict(), sort_keys=True, separators=(",", ":"))
            + "\n"
            for e in self.events)

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())


def load_trace(path) -> list[dict]:
    """Load a JSONL trace file back into event dicts."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# -------------------------------------------------------------------- diff
@dataclass
class TraceDiff:
    """First divergence between two traces (``identical`` when none)."""

    index: Optional[int]          # first differing event index, or None
    left: Optional[dict]          # event at that index (None = missing)
    right: Optional[dict]
    len_left: int
    len_right: int

    @property
    def identical(self) -> bool:
        return self.index is None

    def __bool__(self) -> bool:  # truthy == "there IS a difference"
        return not self.identical

    def explain(self) -> str:
        if self.identical:
            return f"traces identical ({self.len_left} events)"
        return (f"traces diverge at event {self.index} "
                f"(lengths {self.len_left} vs {self.len_right}):\n"
                f"  left : {self.left}\n"
                f"  right: {self.right}")


def diff_traces(left: Iterable, right: Iterable) -> TraceDiff:
    a, b = event_dicts(left), event_dicts(right)
    for i, (x, y) in enumerate(zip(a, b)):
        if x != y:
            return TraceDiff(i, x, y, len(a), len(b))
    if len(a) != len(b):
        i = min(len(a), len(b))
        return TraceDiff(i, a[i] if i < len(a) else None,
                         b[i] if i < len(b) else None, len(a), len(b))
    return TraceDiff(None, None, None, len(a), len(b))


def replay_check(run: Callable[[TraceRecorder], object],
                 golden: Union[str, Iterable]) -> TraceDiff:
    """Re-run a workload and diff its trace against a recorded one.

    ``run(recorder)`` must build its own ``VirtualClock`` cluster with
    ``trace=recorder`` and drive the workload to completion (see
    tests/workloads.py for the canonical shape).  ``golden`` is a JSONL
    path or an iterable of events.  Returns the :class:`TraceDiff`;
    ``diff.identical`` is the replay assertion.
    """
    rec = TraceRecorder()
    run(rec)
    want = load_trace(golden) if isinstance(golden, str) else golden
    return diff_traces(rec.events, want)


# ---------------------------------------------------------------- analysis
def waterfall(events: Iterable) -> dict[str, list[dict]]:
    """Per-lane schedule intervals derived from a trace.

    Node lanes (``"n0"``...) carry job intervals: ``phase="stage"`` from
    placement to run start, ``phase="run"`` from run start to finish.
    Link lanes (``"n0->n1"``) carry ``phase="xfer"`` serialization
    intervals from ``link_acquire`` events.  This is the data behind
    ``benchmarks --fig waterfall``.
    """
    lanes: dict[str, list[dict]] = defaultdict(list)
    placed: dict[int, tuple[float, str]] = {}
    started: dict[int, tuple[float, str]] = {}
    for ev in event_dicts(events):
        k = ev["kind"]
        if k == "job_place":
            placed[ev["job"]] = (ev["t"], ev["node"])
        elif k == "job_start":
            job = ev["job"]
            if job in placed and placed[job][1] == ev["node"]:
                t0 = placed.pop(job)[0]
                if ev["t"] > t0:
                    lanes[ev["node"]].append(
                        {"job": job, "phase": "stage",
                         "start": t0, "end": ev["t"]})
            started[job] = (ev["t"], ev["node"])
        elif k == "job_finish":
            job = ev["job"]
            if job in started:
                t0, node = started.pop(job)
                lanes[node].append({"job": job, "phase": "run",
                                    "start": t0, "end": ev["t"]})
        elif k == "link_acquire":
            lanes[f"{ev['src']}->{ev['dst']}"].append(
                {"phase": "xfer", "start": ev["t"],
                 "end": ev["t"] + ev["ser_s"], "nbytes": ev["nbytes"]})
    return dict(lanes)


def link_utilization(events: Iterable, horizon_s: float) -> dict[str, float]:
    """Fraction of ``horizon_s`` each (src → dst) link spent serializing."""
    busy: dict[str, float] = defaultdict(float)
    for ev in event_dicts(events):
        if ev["kind"] == "link_acquire":
            busy[f"{ev['src']}->{ev['dst']}"] += ev["ser_s"]
    if horizon_s <= 0:
        return {k: 0.0 for k in busy}
    return {k: min(v / horizon_s, 1.0) for k, v in busy.items()}


def starvation_intervals(events: Iterable) -> list[dict]:
    """Starvation windows (internal-I/O slots held during fetches), each
    attributed to the blob arrivals that ended it.

    ``attributed`` is the key of the last *declared* blob that landed on
    the starved node inside the window — the arrival that released the
    slot.  A window with no arrivals (every declared handle was already
    resident) has ``attributed=None`` and ~zero duration.
    """
    open_: dict[tuple[str, int], dict] = {}
    out: list[dict] = []
    for ev in event_dicts(events):
        k = ev["kind"]
        if k == "starve_begin":
            open_[(ev["node"], ev["job"])] = {
                "node": ev["node"], "job": ev["job"], "start": ev["t"],
                "declared": set(ev["declared"]), "arrivals": []}
        elif k == "put":
            for iv in open_.values():
                if iv["node"] == ev["node"]:
                    iv["arrivals"].append((ev["t"], ev["key"]))
        elif k == "starve_end":
            iv = open_.pop((ev["node"], ev["job"]), None)
            if iv is None:
                continue
            iv["end"] = ev["t"]
            attributed = None
            for _t, key in iv["arrivals"]:
                if key in iv["declared"]:
                    attributed = key
            iv["attributed"] = attributed
            iv["declared"] = sorted(iv["declared"])
            out.append(iv)
    return out


# -------------------------------------------------------------- invariants
def verify_invariants(events: Iterable) -> list[str]:
    """Check a (failure-free) run's trace against schedule invariants.

    Returns a list of human-readable violations (empty == all hold):

    * **no redundant transfer** — no handle is enqueued toward a node
      where its content was already resident at enqueue time;
    * **conservation** — bytes delivered by the transfer subsystem equal
      bytes the scheduler enqueued (requested minus dedup joins and
      recomputes), and each (dst, key) enqueue has exactly one delivery;
    * **completeness** — every submitted job finishes or fails;
    * **starvation attribution** — every starvation interval of positive
      duration ends with the arrival of a blob the job declared.
    """
    violations: list[str] = []
    resident: dict[str, set] = defaultdict(set)
    enq_counts: Counter = Counter()
    del_counts: Counter = Counter()
    enq_bytes = 0
    del_bytes = 0
    submitted: set[int] = set()
    completed: set[int] = set()
    evs = event_dicts(events)
    for ev in evs:
        k = ev["kind"]
        if k == "put":
            resident[ev["node"]].add(ev["key"])
        elif k == "stage_request" and ev["action"] == "enqueue":
            if ev["key"] in resident[ev["dst"]]:
                violations.append(
                    f"seq {ev['seq']}: transfer enqueued for key "
                    f"{ev['key'][:12]}… already resident at {ev['dst']}")
            enq_bytes += ev["nbytes"]
            enq_counts[(ev["dst"], ev["key"])] += 1
        elif k == "transfer_deliver" and ev.get("via") != "blocking":
            del_bytes += ev["nbytes"]
            for key in ev["keys"]:
                del_counts[(ev["dst"], key)] += 1
        elif k == "job_submit":
            submitted.add(ev["job"])
        elif k in ("job_finish", "job_fail"):
            completed.add(ev["job"])
    if enq_bytes != del_bytes:
        violations.append(
            f"bytes delivered ({del_bytes}) != bytes enqueued ({enq_bytes})")
    if enq_counts != del_counts:
        missing = set(enq_counts) - set(del_counts)
        extra = set(del_counts) - set(enq_counts)
        violations.append(
            f"per-(dst,key) enqueue/delivery mismatch: "
            f"{len(missing)} undelivered, {len(extra)} unrequested")
    unfinished = submitted - completed
    if unfinished:
        violations.append(f"jobs never completed: {sorted(unfinished)}")
    for iv in starvation_intervals(evs):
        if iv["end"] - iv["start"] > 0 and iv["attributed"] is None:
            violations.append(
                f"starvation interval on {iv['node']} (job {iv['job']}, "
                f"{iv['start']:.6f}→{iv['end']:.6f}) not ended by a "
                f"declared blob arrival")
    return violations
