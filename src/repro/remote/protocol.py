"""Length-prefixed wire protocol for the remote backend.

Every message between the coordinator, the worker processes and the
object-store server is one *frame*: a 4-byte big-endian payload length
followed by the payload.  The payload is a small tagged binary encoding
(no pickle — nothing executable crosses the boundary, and the format is
the same few shapes the runtime already speaks):

=====  ==========================================================
tag    payload
=====  ==========================================================
``N``  None
``T``  True
``F``  False
``I``  int, 8-byte little-endian signed (the repo-wide convention)
``B``  bytes: u32 length + raw bytes
``S``  str: u32 length + UTF-8 bytes
``L``  list: u32 count + encoded items
``D``  dict: u32 count + (str key, encoded value) pairs
=====  ==========================================================

Handles travel as their raw 32 bytes (``B``); blob payloads travel
verbatim; tree payloads travel as the concatenation of the children's raw
handles — exactly the canonical bytes the content digest is computed over,
so every delivery is verifiable against its handle at the receiving end.

The op vocabulary (all dicts with an ``"op"`` key):

* coordinator → worker: ``submit`` (a think/strictify step with its memo
  pairs and pre-staged needs), ``heartbeat``, ``shutdown``
* worker → coordinator: ``ran``, ``error``, ``pong``
* worker → store server: ``fetch``, ``put``, ``contains`` (each answered
  in order on the same socket)
"""
from __future__ import annotations

import socket
import struct
from typing import Any, Optional

MAX_FRAME = 1 << 30  # 1 GiB: far above any single message we produce


class ProtocolError(RuntimeError):
    """A malformed frame or an unknown tag on the wire.

    Subclasses split the failure modes the backend treats differently:
    :class:`FrameTruncated` is a *connection*-level loss (the peer or the
    wire died mid-frame) — the stream is gone but nothing says the peer
    misbehaved, so the backend may retry the work elsewhere.
    :class:`FrameTooLarge` and :class:`BadTag` are *protocol*-level: the
    peer produced bytes our codec cannot have produced, so resending the
    same message can only fail the same way — fatal, never retried.
    """


class FrameTruncated(ProtocolError):
    """The connection closed (or the buffer ended) mid-frame: a partial
    length header, a short payload, or a value cut off inside a message.
    Retriable — the *channel* failed, not the conversation."""


class FrameTooLarge(ProtocolError):
    """A frame length over ``MAX_FRAME`` (ours or the peer's).  Fatal: a
    header this size means framing desync or a hostile/buggy peer."""


class BadTag(ProtocolError):
    """An unknown type tag, a non-str dict key, an unencodable value, or
    trailing garbage — the payload is not our encoding.  Fatal."""


def retriable(exc: BaseException) -> bool:
    """Is this wire failure safe to answer with respawn-and-resubmit?

    ``OSError`` (socket died) and :class:`FrameTruncated` (stream cut
    mid-frame) are connection casualties: the work they carried is
    re-derivable, so the backend retries it.  Everything else —
    :class:`BadTag`, :class:`FrameTooLarge`, generic
    :class:`ProtocolError` — indicates a corrupted conversation where a
    retry would re-poison the channel."""
    return isinstance(exc, (OSError, FrameTruncated))


# ---------------------------------------------------------------- encoding
def _encode(obj: Any, out: list) -> None:
    if obj is None:
        out.append(b"N")
    elif obj is True:
        out.append(b"T")
    elif obj is False:
        out.append(b"F")
    elif isinstance(obj, int):
        try:
            out.append(b"I" + obj.to_bytes(8, "little", signed=True))
        except OverflowError as e:
            raise BadTag(f"int {obj!r} does not fit 8 bytes") from e
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        b = bytes(obj)
        out.append(b"B" + struct.pack(">I", len(b)))
        out.append(b)
    elif isinstance(obj, str):
        b = obj.encode("utf-8")
        out.append(b"S" + struct.pack(">I", len(b)))
        out.append(b)
    elif isinstance(obj, (list, tuple)):
        out.append(b"L" + struct.pack(">I", len(obj)))
        for item in obj:
            _encode(item, out)
    elif isinstance(obj, dict):
        out.append(b"D" + struct.pack(">I", len(obj)))
        for k, v in obj.items():
            if not isinstance(k, str):
                raise BadTag(f"dict keys must be str, got {type(k).__name__}")
            kb = k.encode("utf-8")
            out.append(struct.pack(">I", len(kb)))
            out.append(kb)
            _encode(v, out)
    else:
        raise BadTag(f"cannot encode {type(obj).__name__} on the wire")


def pack(obj: Any) -> bytes:
    """Encode one message payload (no frame header)."""
    out: list = []
    _encode(obj, out)
    return b"".join(out)


class _Cursor:
    __slots__ = ("data", "pos")

    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.data):
            raise FrameTruncated("truncated message")
        b = self.data[self.pos:self.pos + n]
        self.pos += n
        return b

    def u32(self) -> int:
        return struct.unpack(">I", self.take(4))[0]


def _decode(c: _Cursor) -> Any:
    tag = c.take(1)
    if tag == b"N":
        return None
    if tag == b"T":
        return True
    if tag == b"F":
        return False
    if tag == b"I":
        return int.from_bytes(c.take(8), "little", signed=True)
    if tag == b"B":
        return c.take(c.u32())
    if tag == b"S":
        return c.take(c.u32()).decode("utf-8")
    if tag == b"L":
        return [_decode(c) for _ in range(c.u32())]
    if tag == b"D":
        d = {}
        for _ in range(c.u32()):
            key = c.take(c.u32()).decode("utf-8")
            d[key] = _decode(c)
        return d
    raise BadTag(f"unknown tag {tag!r}")


def unpack(data: bytes) -> Any:
    """Decode one message payload; the whole buffer must be consumed."""
    c = _Cursor(data)
    obj = _decode(c)
    if c.pos != len(data):
        raise BadTag(f"{len(data) - c.pos} trailing bytes in message")
    return obj


# ------------------------------------------------------------------ framing
def recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes, or None on clean EOF at a frame boundary."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                return None
            raise FrameTruncated("connection closed mid-frame")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_msg(sock: socket.socket, obj: Any, lock=None) -> None:
    """Frame and send one message (``lock`` serializes multi-writer sides)."""
    body = pack(obj)
    if len(body) > MAX_FRAME:
        raise FrameTooLarge(f"frame of {len(body)} bytes exceeds MAX_FRAME")
    frame = struct.pack(">I", len(body)) + body
    if lock is not None:
        with lock:
            sock.sendall(frame)
    else:
        sock.sendall(frame)


def recv_msg(sock: socket.socket) -> Any:
    """Receive one message, or None on clean EOF (peer closed)."""
    header = recv_exact(sock, 4)
    if header is None:
        return None
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME:
        raise FrameTooLarge(f"incoming frame of {length} bytes exceeds MAX_FRAME")
    body = recv_exact(sock, length) if length else b""
    if body is None:
        raise FrameTruncated("connection closed mid-frame")
    return unpack(body)
