"""``repro.fix`` — the user-facing Fix frontend.

The Table-1 core (:mod:`repro.core`) is the paper's shared representation:
handles, sealed :class:`~repro.core.api.FixAPI` capabilities, combination
trees ``[limits, procedure, arg...]``.  This package is the *compiler* from
ergonomic Python programs down to that representation — it adds no new
semantics and no new I/O path:

* :func:`codelet` — a decorator that reads a Python signature (``int``,
  ``bytes``, ``str``, ``bool``, nested tuples/lists, raw ``Handle``
  passthrough) and generates the marshal/unmarshal shims, so codelet bodies
  take real values and return real values while the sealed ``FixAPI``
  remains the only I/O surface.
* :class:`Lazy` — calling a typed codelet returns a lazy expression; nesting
  calls, ``.strict()`` / ``.shallow()``, and ``expr[i]`` selection sugar
  build the whole thunk DAG client-side.  ``Lazy.compile(repo)`` produces
  handles **byte-identical** to the equivalent hand-built ``combination``
  tree — the shared-representation guarantee, asserted by the test suite.
* :class:`Backend` — one protocol (``submit`` / ``evaluate`` / ``fetch`` /
  ``as_completed``) over the local :class:`~repro.core.evaluator.Evaluator`
  (:func:`local`) and the distributed :class:`~repro.runtime.cluster.Cluster`
  (:func:`on`): the same program runs unchanged on either.

Quickstart::

    import repro.fix as fix
    from repro.core.stdlib import add, fib

    with fix.local() as be:
        print(be.run(add(40, 2)))          # -> 42
        print(be.run(fib(15)))             # -> 610

    from repro.runtime import Cluster
    with fix.on(Cluster(n_nodes=3)) as be:
        print(be.run(fib(15)))             # same program, unchanged
"""
from .backend import Backend, ClusterBackend, LocalBackend, local, on
from .codelet import DEFAULT_LIMITS, TypedCodelet, codelet
from .future import CancelledError, DeadlineExceeded, Future, as_completed
from .lazy import Lazy, lit
from .marshal import MarshalError

__all__ = [
    "Backend", "ClusterBackend", "LocalBackend", "local", "on", "remote",
    "TypedCodelet", "codelet", "DEFAULT_LIMITS",
    "Future", "as_completed", "CancelledError", "DeadlineExceeded",
    "Lazy", "lit",
    "MarshalError",
]


def remote(n_workers: int = 2, **kwargs):
    """Multi-process backend: ``fix.remote(n_workers=2)``.

    Imported lazily — :mod:`repro.remote` pulls in the runtime package
    (for the shared :class:`~repro.runtime.transfers.LocationIndex`), and
    the runtime imports *this* package, so a top-level import would be
    circular.  See :class:`repro.remote.RemoteBackend` for parameters
    (``store=``, ``store_dir=``, ``trace=``, ``log_dir=``).
    """
    from ..remote import remote as _remote
    return _remote(n_workers, **kwargs)
