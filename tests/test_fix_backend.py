"""The Backend protocol: Future callbacks / error paths / as_completed,
local ≡ cluster program portability, and the satellites that ride on it
(fetch accounting, utilization partition).
"""
import threading
import time

import pytest

import repro.fix as fix
from repro.core import FixError, Handle, Repository
from repro.core.stdlib import add, count_string, fib, inc_chain, slice_blob
from repro.runtime import Cluster, Link, Network


def make_cluster(**kw) -> Cluster:
    kw.setdefault("n_nodes", 3)
    kw.setdefault("workers_per_node", 2)
    kw.setdefault("network", Network(Link(latency_s=0.0005, gbps=10)))
    return Cluster(**kw)


# ------------------------------------------------------------------ future
class TestFuture:
    def test_callback_after_set(self):
        f = fix.Future()
        seen = []
        f.add_done_callback(seen.append)
        assert seen == []
        f.set("r")
        assert seen == [f]

    def test_callback_on_already_done(self):
        f = fix.Future()
        f.set("r")
        seen = []
        f.add_done_callback(seen.append)
        assert seen == [f]

    def test_first_write_wins(self):
        f = fix.Future()
        f.set("a")
        f.set("b")
        f.set_exception(RuntimeError("late"))
        assert f.result(0) == "a" and f.exception(0) is None

    def test_exception_path(self):
        f = fix.Future()
        f.set_exception(ValueError("boom"))
        assert isinstance(f.exception(0), ValueError)
        with pytest.raises(ValueError, match="boom"):
            f.result(0)

    def test_callback_exception_swallowed(self):
        f = fix.Future()
        f.add_done_callback(lambda _: 1 / 0)
        seen = []
        f.add_done_callback(seen.append)
        f.set("ok")  # must not raise, must reach later callbacks
        assert seen == [f]

    def test_timeout(self):
        f = fix.Future()
        with pytest.raises(TimeoutError):
            f.result(0.01)


class TestAsCompleted:
    def test_completion_order(self):
        futs = [fix.Future() for _ in range(3)]

        def finisher():
            for i in (2, 0, 1):
                time.sleep(0.01)
                futs[i].set(i)

        threading.Thread(target=finisher, daemon=True).start()
        order = [f.result(1) for f in fix.as_completed(futs, timeout=5)]
        assert order == [2, 0, 1]

    def test_already_done_yield_immediately(self):
        futs = [fix.Future() for _ in range(3)]
        for i, f in enumerate(futs):
            f.set(i)
        assert sorted(f.result(0) for f in fix.as_completed(futs)) == [0, 1, 2]

    def test_timeout(self):
        stuck = fix.Future()
        with pytest.raises(TimeoutError):
            list(fix.as_completed([stuck], timeout=0.05))


# ----------------------------------------------------------- local backend
class TestLocalBackend:
    def test_submit_evaluate_fetch_run(self):
        with fix.local() as be:
            fut = be.submit(add(20, 22))
            assert be.fetch(fut) == 42
            out = be.evaluate(add(20, 22))
            assert isinstance(out, Handle)
            assert be.fetch(out, as_type=int) == 42
            assert be.run(add(1, 2)) == 3

    def test_codelet_error_delivered_via_future(self):
        with fix.local() as be:
            bomb = add(Handle.blob(b"not-an-int"), Handle.blob(b"x"))
            fut = be.submit(bomb)
            assert isinstance(fut.exception(10), FixError)
            with pytest.raises(FixError):
                fut.result(10)

    def test_close_idempotent_and_submit_after_close_rejected(self):
        be = fix.local()
        be.run(add(1, 1))
        be.close()
        be.close()
        with pytest.raises(RuntimeError, match="closed"):
            be.submit(add(1, 2))

    def test_evaluate_honors_timeout(self):
        """The portability contract: a bounded evaluate must raise
        TimeoutError on the local backend just like on the cluster."""
        @fix.codelet(name="t_sleepy")
        def t_sleepy(n: int) -> int:
            time.sleep(0.4)
            return n

        with fix.local() as be:
            with pytest.raises(TimeoutError):
                be.evaluate(t_sleepy(1), timeout=0.05)

    def test_evaluate_inline_fast_path(self):
        with fix.local() as be:
            out = be.evaluate(add(3, 4), timeout=None)  # runs on this thread
            assert be.fetch(out, as_type=int) == 7

    def test_fetch_untyped_defaults(self):
        with fix.local() as be:
            h = be.evaluate(slice_blob(b"hello world", 0, 5))
            assert be.fetch(h) == b"hello"  # no type: blobs decode to bytes

    def test_submit_rejects_non_programs(self):
        with fix.local() as be:
            with pytest.raises(fix.MarshalError):
                be.submit(42)


# --------------------------------------------------- program portability
class TestPortability:
    """The acceptance bar: the same program, unchanged, on both backends."""

    PROGRAMS = [
        (lambda: add(20, 22), 42),
        (lambda: fib(12), 144),
        (lambda: inc_chain(0, 60), 60),
        (lambda: add(add(1, 2), add(add(3, 4), 5)), 15),
    ]

    def test_same_value_and_same_result_handle(self):
        local_results = []
        with fix.local() as be:
            for mk, want in self.PROGRAMS:
                h = be.evaluate(mk(), timeout=60)
                assert be.fetch(h, as_type=int) == want
                local_results.append(h.raw)
        c = make_cluster()
        try:
            be = fix.on(c)
            for (mk, want), local_raw in zip(self.PROGRAMS, local_results):
                h = be.evaluate(mk(), timeout=60)
                assert be.fetch(h, as_type=int) == want
                assert h.raw == local_raw  # content-addressed: same name
        finally:
            c.shutdown()

    def test_cluster_error_path(self):
        c = make_cluster()
        try:
            be = fix.on(c)
            bomb = add(Handle.blob(b"not-an-int"), Handle.blob(b"x"))
            with pytest.raises(FixError):
                be.submit(bomb).result(30)
        finally:
            c.shutdown()

    def test_as_completed_on_cluster(self):
        c = make_cluster()
        try:
            be = fix.on(c)
            futs = [be.submit(add(i, i)) for i in range(6)]
            got = sorted(be.fetch(f) for f in be.as_completed(futs, timeout=30))
            assert got == [0, 2, 4, 6, 8, 10]
        finally:
            c.shutdown()

    def test_cluster_thin_delegates_accept_programs(self):
        """Cluster.submit/evaluate are Backend delegates: Lazy in, raw
        encodes still accepted."""
        c = make_cluster()
        try:
            assert c.backend.fetch(c.submit(add(2, 3))) == 5
            raw = add(4, 5).compile(c.client_repo).strict()
            assert c.backend.fetch(c.evaluate(raw), as_type=int) == 9
        finally:
            c.shutdown()


# ------------------------------------------------------- fetch accounting
class TestFetchAccounting:
    def test_result_fetch_counts_transfers_and_bytes(self):
        """Satellite: fetch_result used to sleep for link costs but never
        account them — result-fetch traffic must show up in the counters."""
        c = make_cluster()
        try:
            be = fix.on(c)
            corpus = c.client_repo.put_blob(bytes(range(256)) * 1000)
            fut = be.submit(slice_blob(corpus, 0, 100_000))
            h = fut.result(30)
            tx0, by0 = c.transfers, c.bytes_moved
            got = be.fetch(fut)
            assert len(got) == 100_000
            assert c.transfers == tx0 + 1
            assert c.bytes_moved >= by0 + 100_000
            # a second fetch moves nothing new (content addressing)
            tx1, by1 = c.transfers, c.bytes_moved
            be.fetch(h, as_type=bytes)
            assert (c.transfers, c.bytes_moved) == (tx1, by1)
        finally:
            c.shutdown()

    def test_literal_results_fetch_free(self):
        c = make_cluster()
        try:
            be = fix.on(c)
            fut = be.submit(add(1, 2))
            fut.result(30)
            tx0, by0 = c.transfers, c.bytes_moved
            assert be.fetch(fut) == 3
            assert (c.transfers, c.bytes_moved) == (tx0, by0)
        finally:
            c.shutdown()


# -------------------------------------------------- utilization partition
class TestUtilization:
    def test_fractions_partition_the_window(self):
        """Satellite: busy + starved + idle_iowait must cover the window
        exactly once — starvation is not double-counted into idle."""
        net = Network(Link(latency_s=0.02, gbps=10))
        c = make_cluster(n_nodes=2, io_mode="internal", network=net)
        try:
            be = fix.on(c)
            c.nodes["n0"].repo.put_blob(b"z" * 100_000)
            shard = Handle.blob(b"z" * 100_000)
            t0 = time.perf_counter()
            futs = [be.submit(count_string(shard, bytes([i % 3]) + b"zz"))
                    for i in range(8)]
            for f in futs:
                f.result(30)
            dt = time.perf_counter() - t0
            u = c.utilization(dt)
            assert u["starved_frac"] > 0  # internal mode held slots on I/O
            total = u["busy_frac"] + u["starved_frac"] + u["idle_iowait_frac"]
            assert total >= 1.0 - 1e-9
            assert u["idle_iowait_frac"] >= 0.0
            # unclamped case: the three cover the window exactly
            if u["busy_frac"] + u["starved_frac"] <= 1.0:
                assert total == pytest.approx(1.0)
        finally:
            c.shutdown()
