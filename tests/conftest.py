"""Shared fixtures for the runtime test suite."""
import threading
import time

import pytest


@pytest.fixture
def no_thread_leaks():
    """Flake guard: every thread a test starts must be joinable by the end
    of that test.

    Clusters and clocks now drain their workers on ``shutdown()``/
    ``close()`` (scheduler, node workers, link workers, per-handle
    transfer threads, wall/virtual timer threads); this fixture pins that
    contract so a leaked thread fails the leaking test instead of
    corrupting a later one (the cross-test interference that makes
    cooperative-scheduling suites flaky).

    Opt in per module with
    ``pytestmark = pytest.mark.usefixtures("no_thread_leaks")`` — it is
    deliberately not autouse: jax/XLA tests keep process-lifetime thread
    pools that are not leaks.
    """
    before = set(threading.enumerate())
    yield
    deadline = time.monotonic() + 10.0
    leaked = []
    for t in threading.enumerate():
        if t in before or t is threading.current_thread():
            continue
        t.join(timeout=max(0.0, deadline - time.monotonic()))
        if t.is_alive():
            leaked.append(t.name)
    assert not leaked, f"threads leaked across test boundary: {leaked}"
