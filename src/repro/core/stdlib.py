"""Codelets used across tests and benchmarks — the paper's running examples.

``add`` (fig 7a's trivial function), ``inc_chain`` (fig 7b's 500-deep chain),
``fix_if`` (Fig 2's lazy conditional), ``fib`` (Fig 3's recursion via Thunks),
``btree_get`` lives in examples/btree_kv.py, ``count_string`` / ``merge_counts``
(fig 8b's map-reduce) live here too since the runtime benchmarks share them.

All of them are **typed codelets** (:func:`repro.fix.codelet`): bodies take
real Python values and return real values; the generated shims do the
Table-1 marshalling through the sealed FixAPI.  Tail calls are typed too —
``inc_chain`` returns ``inc_chain(v + 1, r - 1)``, a Lazy expression the
shim compiles into an Application Thunk through the same capability.

The raw spelling stays first-class: :func:`combination` builds the
``[limits, procedure, arg...]`` tree by hand (paper §4.1), and evaluates
through the *same* registered shims — typed calls compile to byte-identical
trees (asserted in tests/test_fix_frontend.py).
"""
from __future__ import annotations

from ..fix.codelet import DEFAULT_LIMITS, codelet
from .handle import Handle
from .procedures import handle_for
from .repository import Repository

LIMITS_SMALL = DEFAULT_LIMITS


def combination(repo: Repository, proc_name: str, *args: Handle,
                limits: bytes = LIMITS_SMALL) -> Handle:
    """Build an Application Thunk for ``proc_name(*args)`` by hand — the
    raw Table-1 spelling of what typed codelet calls compile to."""
    tree = repo.put_tree([repo.put_blob(limits), handle_for(repo, proc_name), *args])
    return tree.application()


# --------------------------------------------------------------------- add
@codelet(name="add")
def add(a: int, b: int) -> int:
    return a + b


# ----------------------------------------------------------------- fig 7b
@codelet(name="inc_chain")
def inc_chain(value: int, remaining: int) -> int:
    """Increment; if steps remain, tail-call self (one submission, no client
    round-trips — the whole chain is described by the initial thunk)."""
    if remaining <= 0:
        return value
    return inc_chain(value + 1, remaining - 1)


# ------------------------------------------------------------------ fig 2
@codelet(name="fix_if")
def fix_if(pred: bool, then_t: Handle, else_t: Handle) -> Handle:
    """Lazy conditional: the branches stay *names* (Handle parameters), so
    the untaken branch's thunk is never evaluated and its minimum
    repository is never fetched."""
    return then_t if pred else else_t


# ------------------------------------------------------------------ fig 3
@codelet(name="fib")
def fib(n: int) -> int:
    if n < 2:
        return n
    # Nested calls in value position compile to strict-Encoded child
    # thunks — exactly the hand-built [limits, add, strict(f1), strict(f2)].
    return add(fib(n - 1), fib(n - 2))


# ------------------------------------------------------------------ fig 8b
@codelet(name="count_string")
def count_string(shard: bytes, needle: bytes) -> int:
    """Count non-overlapping occurrences of a needle in one corpus shard."""
    return shard.count(needle)


@codelet(name="merge_counts")
def merge_counts(a: int, b: int) -> int:
    return a + b


# ------------------------------------------------- data-pipeline codelets
@codelet(name="slice_blob")
def slice_blob(corpus: bytes, start: int, length: int) -> bytes:
    """Deterministic re-derivation of a shard from (corpus, start, len) —
    the paper's recompute-instead-of-transfer strategy needs shards to be
    products of pure functions."""
    return corpus[start : start + length]


@codelet(name="identity")
def identity(x: Handle) -> Handle:
    return x


@codelet(name="checksum_tree")
def checksum_tree(inputs: list[bytes]) -> int:
    """Fold a Tree of input Blobs into one checksum — a fan-out staging
    workload: every child blob is in the minimum repository, so the
    platform must move all of them before the slot binds (the batched
    transfer scheduler's benchmark case)."""
    acc = 0
    for data in inputs:
        acc = (acc * 31 + len(data) + (data[0] if data else 0)) & 0x7FFFFFFF
    return acc
