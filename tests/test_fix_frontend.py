"""The typed frontend: marshalling, lazy graphs, and — load-bearing — the
shared-representation guarantee: every frontend-compiled program has
**byte-identical content keys** to the equivalent hand-built Table-1 tree.
"""
import struct

import pytest

import repro.fix as fix
from repro.core import Evaluator, FixError, Handle, Repository
from repro.core.stdlib import (
    LIMITS_SMALL,
    add,
    checksum_tree,
    combination,
    count_string,
    fib,
    fix_if,
    inc_chain,
    merge_counts,
    slice_blob,
)
from repro.fix.marshal import MarshalError, marshal, unmarshal


def _i(v: int) -> Handle:
    return Handle.blob(v.to_bytes(8, "little", signed=True))


# Test-local typed codelets exercising the full annotation surface.
@fix.codelet(name="t_echo_nested")
def t_echo_nested(x: tuple[tuple[int, bytes], str, bool]) -> tuple[tuple[int, bytes], str, bool]:
    return x


@fix.codelet(name="t_echo_list")
def t_echo_list(xs: list[int]) -> list[int]:
    return xs


@fix.codelet(name="t_pass_handle")
def t_pass_handle(h: Handle, n: int) -> Handle:
    return h


@fix.codelet(name="t_pair")
def t_pair(a: int, b: bytes) -> tuple[int, bytes]:
    return (a * 2, b + b)


# ------------------------------------------------- content-key equivalence
class TestSharedRepresentation:
    """Frontend-compiled graph ≡ hand-built combination tree, byte for byte."""

    def test_simple_call(self):
        repo = Repository()
        typed = add(40, 2).compile(repo)
        hand = combination(repo, "add", _i(40), _i(2))
        assert typed.raw == hand.raw

    def test_nested_calls_strict_in_value_position(self):
        repo = Repository()
        typed = add(add(1, 2), add(3, 4)).compile(repo)
        hand = combination(repo, "add",
                           combination(repo, "add", _i(1), _i(2)).strict(),
                           combination(repo, "add", _i(3), _i(4)).strict())
        assert typed.raw == hand.raw

    def test_handle_position_stays_lazy(self):
        """fig 2: branches of fix_if are Handle params — bare thunks, no
        Encode wrapper, exactly like the hand-built spelling."""
        repo = Repository()
        good, bomb = add(1, 2), add(10, 20)
        typed = fix_if(True, good, bomb).compile(repo)
        hand = combination(repo, "fix_if", _i(1),
                           combination(repo, "add", _i(1), _i(2)),
                           combination(repo, "add", _i(10), _i(20)))
        assert typed.raw == hand.raw

    def test_inc_chain_and_fib(self):
        repo = Repository()
        assert inc_chain(0, 500).compile(repo).raw == \
            combination(repo, "inc_chain", _i(0), _i(500)).raw
        assert fib(10).compile(repo).raw == \
            combination(repo, "fib", _i(10)).raw

    def test_wordcount_reduction_dag(self):
        """The fig-8b map+binary-reduce program, both spellings."""
        repo = Repository()
        shards = [repo.put_blob(bytes([i]) * 100) for i in range(5)]
        needle = b"ab"
        # typed
        level_t = [count_string(h, needle) for h in shards]
        while len(level_t) > 1:
            nxt = [merge_counts(level_t[i], level_t[i + 1])
                   for i in range(0, len(level_t) - 1, 2)]
            if len(level_t) % 2:
                nxt.append(level_t[-1])
            level_t = nxt
        typed = level_t[0].strict().compile(repo)
        # hand-built
        level_h = [combination(repo, "count_string", h,
                               Handle.blob(needle)).strict() for h in shards]
        while len(level_h) > 1:
            nxt = [combination(repo, "merge_counts",
                               level_h[i], level_h[i + 1]).strict()
                   for i in range(0, len(level_h) - 1, 2)]
            if len(level_h) % 2:
                nxt.append(level_h[-1])
            level_h = nxt
        assert typed.raw == level_h[0].raw

    def test_checksum_tree_handle_passthrough(self):
        repo = Repository()
        tree = repo.put_tree([repo.put_blob(bytes([i]) * 64) for i in range(4)])
        typed = checksum_tree(tree).compile(repo)
        hand = combination(repo, "checksum_tree", tree)
        assert typed.raw == hand.raw

    def test_selection_sugar(self):
        repo = Repository()
        kids = [repo.put_blob(bytes([i]) * 40) for i in range(5)]
        tree = repo.put_tree(kids)
        typed = fix.lit(tree)[3].compile(repo)
        pair = repo.put_tree([tree, repo.put_blob(struct.pack("<q", 3))])
        assert typed.raw == pair.selection_of().raw
        # subrange
        typed_r = fix.lit(tree)[1:4].compile(repo)
        pair_r = repo.put_tree([tree, repo.put_blob(struct.pack("<qq", 1, 3))])
        assert typed_r.raw == pair_r.selection_of().raw

    def test_encode_sugar(self):
        repo = Repository()
        expr = add(1, 2)
        hand = combination(repo, "add", _i(1), _i(2))
        assert expr.strict().compile(repo).raw == hand.strict().raw
        assert expr.shallow().compile(repo).raw == hand.shallow().raw

    def test_limits_match_raw_default(self):
        assert fix.DEFAULT_LIMITS == LIMITS_SMALL

    def test_pipeline_shard_recipe(self):
        from repro.data import TokenPipeline, corpus_handle
        repo = Repository()
        corpus = corpus_handle(repo, 1 << 16)
        pipe = TokenPipeline(repo, corpus, seq_len=16, batch=2)
        need = 2 * 17
        offset = (3 * need) % max(corpus.size - need, 1)
        hand = combination(repo, "slice_blob", corpus, _i(offset), _i(need))
        assert pipe.shard_thunk(3).raw == hand.raw

    def test_raw_and_typed_spellings_evaluate_identically(self):
        repo = Repository()
        ev = Evaluator(repo)
        typed_out = ev.evaluate(add(19, 23).compile(repo).strict())
        hand_out = ev.evaluate(combination(repo, "add", _i(19), _i(23)).strict())
        assert typed_out.raw == hand_out.raw


# ----------------------------------------------------- marshal round trips
# (hypothesis widens these in tests/test_fix_marshal_props.py; the pinned
# cases here run everywhere)
NESTED = tuple[tuple[int, bytes], str, bool]


class TestMarshalRoundTrip:
    @pytest.mark.parametrize("v", [0, 1, -1, 255, -256, 2**62, -(2**63),
                                   2**63 - 1])
    def test_int(self, v):
        repo = Repository()
        assert unmarshal(repo, marshal(repo, v, int), int) == v

    @pytest.mark.parametrize("b", [b"", b"x", b"\x00" * 30, b"y" * 31,
                                   bytes(range(256))])
    def test_bytes(self, b):
        """Includes the empty blob (a 0-length literal handle) and both
        sides of the literal threshold."""
        repo = Repository()
        assert unmarshal(repo, marshal(repo, b, bytes), bytes) == b

    @pytest.mark.parametrize("s", ["", "plain", "ünïcodé ✓", "a" * 100])
    def test_str(self, s):
        repo = Repository()
        assert unmarshal(repo, marshal(repo, s, str), str) == s

    @pytest.mark.parametrize("v", [True, False])
    def test_bool(self, v):
        repo = Repository()
        assert unmarshal(repo, marshal(repo, v, bool), bool) is v

    @pytest.mark.parametrize("xs", [[], [1], [-5, 0, 5], list(range(20))])
    def test_list(self, xs):
        repo = Repository()
        assert unmarshal(repo, marshal(repo, xs, list[int]), list[int]) == xs

    @pytest.mark.parametrize("v", [((0, b""), "", False),
                                   ((-42, b"blob" * 20), "déjà", True)])
    def test_nested_tuple(self, v):
        repo = Repository()
        assert unmarshal(repo, marshal(repo, v, NESTED), NESTED) == v

    def test_handle_passthrough(self):
        repo = Repository()
        h = repo.put_blob(b"q" * 64)
        assert marshal(repo, h, bytes) is h       # handles bypass encoding
        assert unmarshal(repo, h, Handle) is h    # and decoding

    @pytest.mark.parametrize("v", [((0, b""), "", False),
                                   ((2**40, b"\x00\xff"), "mid ✓", True)])
    def test_echo_codelet_end_to_end(self, v):
        """Values survive the full trip: client marshal -> sealed-API
        unmarshal -> codelet body -> sealed-API marshal -> client decode."""
        with fix.local() as be:
            assert be.run(t_echo_nested(v)) == v

    def test_echo_list_end_to_end(self):
        with fix.local() as be:
            assert be.run(t_echo_list([7, -9, 2**50])) == [7, -9, 2**50]


# ------------------------------------------------------------- lazy sugar
class TestLazy:
    def test_calling_runs_nothing(self):
        expr = add(1, 2)
        assert isinstance(expr, fix.Lazy)
        assert expr.out_type is int

    def test_no_truth_value(self):
        with pytest.raises(MarshalError, match="truth value"):
            bool(add(1, 2))

    def test_strict_idempotent(self):
        e = add(1, 2).strict()
        assert e.strict() is e
        assert e.shallow() is not e

    def test_selection_types(self):
        p = t_pair(3, b"xy")
        assert p.out_type == tuple[int, bytes]
        assert p[0].out_type is int
        assert p[1].out_type is bytes
        with fix.local() as be:
            assert be.run(p[0]) == 6
            assert be.run(p[1]) == b"xyxy"

    def test_bad_selection_index(self):
        with pytest.raises(MarshalError):
            add(1, 2)["k"]
        with pytest.raises(MarshalError):
            add(1, 2)[::2]

    def test_negative_selection_rejected(self):
        """The target's length is unknown client-side, so negative indices
        cannot be normalized — reject them instead of mis-selecting."""
        with pytest.raises(MarshalError, match="non-negative"):
            fix.lit(b"abc")[-1]
        with pytest.raises(MarshalError, match="non-negative"):
            t_pair(1, b"x")[-2:]
        with pytest.raises(MarshalError, match="non-negative"):
            fix.lit((1, 2, 3))[0:-1]

    def test_arity_checked_client_side(self):
        with pytest.raises(MarshalError):
            add(1)
        with pytest.raises(MarshalError):
            add(1, 2, 3)

    def test_type_checked_client_side(self):
        with pytest.raises(MarshalError):
            add("one", 2).compile(Repository())

    def test_handle_args_bypass_type_checks(self):
        """Raw Table-1 escape hatch: a Handle arg is passed through even
        where a value type is annotated (same trust as hand-built trees)."""
        repo = Repository()
        h = repo.put_blob(b"whatever")
        compiled = add(h, 2).compile(repo)
        hand = combination(repo, "add", h, _i(2))
        assert compiled.raw == hand.raw

    def test_shared_subexpression_compiles_once(self):
        repo = Repository()
        shared = add(1, 2)
        expr = add(shared, shared)
        compiled = expr.compile(repo)
        hand_child = combination(repo, "add", _i(1), _i(2)).strict()
        hand = combination(repo, "add", hand_child, hand_child)
        assert compiled.raw == hand.raw


# -------------------------------------------------------- codelet hygiene
class TestCodeletDefinition:
    def test_unannotated_param_rejected(self):
        with pytest.raises(MarshalError, match="annotation"):
            @fix.codelet(name="t_bad1")
            def bad(x):
                return x

    def test_unsupported_annotation_rejected(self):
        with pytest.raises(MarshalError, match="unsupported"):
            @fix.codelet(name="t_bad2")
            def bad(x: float) -> int:
                return 0

    def test_varargs_rejected(self):
        with pytest.raises(MarshalError, match="marshallable"):
            @fix.codelet(name="t_bad3")
            def bad(*xs: int) -> int:
                return 0

    def test_wrong_arity_combination_fails_at_apply(self):
        repo = Repository()
        ev = Evaluator(repo)
        th = combination(repo, "add", _i(1))  # missing an argument
        with pytest.raises(FixError, match="argument"):
            ev.evaluate(th.strict())

    def test_handle_return_passthrough(self):
        repo = Repository()
        ev = Evaluator(repo)
        big = repo.put_blob(b"p" * 64)
        out = ev.evaluate(t_pass_handle(big, 1).compile(repo).strict())
        assert out.content_key() == big.content_key()
