"""Serving driver: resumable prefill + group-batched decode on a real model.

Bridges the family ops to the engine's contracts
(:class:`~repro.serving.engine.ServeEngine`):

* ``prefill_fn(tokens, state) -> state`` — ``state=None`` runs the jitted
  full-block prefill; with a cached state the uncovered tail is fed through
  the decode step (resume-from-KV, the per-boundary states land in the
  :class:`~repro.serving.engine.PrefixCache`);
* ``decode_fn(states, tokens[B,1]) -> (logits[B,1,V], states)`` — the
  batched contract from ``parallel.steps``.  Per-row caches are stacked
  along the batch axis (every family lays caches out ``[layers, batch,
  ...]`` with scalar counters) and decoded in one jitted call per group of
  rows whose cache shapes/counters agree — rows admitted together stay in
  lockstep, so continuous batching forms groups naturally; a lone ragged
  row decodes at width 1.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_8b --requests 8
  PYTHONPATH=src python -m repro.launch.serve --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config
from ..models import init_params, ops_for
from ..parallel.sharding import Sharder
from ..serving import PrefixCache, Request, ServeEngine


def _group_key(cache) -> tuple:
    """Rows are batchable iff their cache pytrees agree on structure, leaf
    shapes, and scalar counters (``pos`` — SSM states are O(1)-shaped, so
    shape alone can't prove rows are at the same position)."""
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    return (treedef,
            tuple((x.shape, int(x) if x.ndim == 0 else None) for x in leaves))


def _stack_rows(caches: list):
    """Concatenate per-row (batch-1) caches along the batch axis."""
    return jax.tree.map(
        lambda *xs: xs[0] if xs[0].ndim == 0 else jnp.concatenate(xs, axis=1),
        *caches)


def _split_rows(cache, n: int) -> list:
    return [jax.tree.map(
        lambda x, i=i: x if x.ndim == 0 else x[:, i: i + 1], cache)
        for i in range(n)]


def build_model_fns(cfg):
    """(prefill_fn, decode_fn) in the engine contracts, over family ops."""
    ops = ops_for(cfg)
    params = init_params(ops.specs(cfg), cfg)
    sh = Sharder(None)

    @jax.jit
    def prefill_jit(tokens):
        _logits, cache = ops.prefill(params, {"tokens": tokens[None]}, cfg, sh)
        return cache

    @jax.jit
    def decode_jit(cache, tokens):
        return ops.decode_step(params, cache, tokens, cfg, sh)

    def prefill_fn(tokens, state=None):
        tokens = np.ascontiguousarray(tokens, np.int32)
        if state is None:
            return prefill_jit(jnp.asarray(tokens))
        # resume from a cached boundary: append the uncovered tail through
        # the decode step (same KV entries as a fresh prefill would write)
        cache = state
        for t in tokens:
            _logits, cache = decode_jit(cache,
                                        jnp.asarray([[int(t)]], jnp.int32))
        return cache

    def decode_fn(states, tokens):
        tokens = np.ascontiguousarray(tokens, np.int32)
        groups: dict = {}
        for i, c in enumerate(states):
            groups.setdefault(_group_key(c), []).append(i)
        out_states: list = [None] * len(states)
        logits_rows: list = [None] * len(states)
        for rows in groups.values():
            cache = _stack_rows([states[i] for i in rows])
            toks = jnp.asarray(tokens[rows], jnp.int32)
            logits, cache = decode_jit(cache, toks)
            logits = np.asarray(logits, np.float32)
            for row_pos, i in enumerate(rows):
                logits_rows[i] = logits[row_pos: row_pos + 1]
            for i, st in zip(rows, _split_rows(cache, len(rows))):
                out_states[i] = st
        return np.concatenate(logits_rows, axis=0), out_states

    return prefill_fn, decode_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b", choices=ARCHS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--block", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: tiny workload + cached-vs-uncached "
                         "stream equivalence check")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.prompt_len, args.max_new, args.batch = 4, 24, 4, 2

    cfg = get_config(args.arch, smoke=True)
    prefill_fn, decode_fn = build_model_fns(cfg)

    def make_requests():
        rng = np.random.default_rng(0)
        shared_prefix = rng.integers(1, cfg.vocab, args.block)  # 1 full block
        reqs = []
        for i in range(args.requests):
            tail = rng.integers(1, cfg.vocab,
                                args.prompt_len - len(shared_prefix))
            prompt = np.concatenate([shared_prefix, tail]).astype(np.int32)
            reqs.append(Request(rid=i, prompt=prompt, max_new=args.max_new))
        return reqs

    def serve(cache_capacity):
        engine = ServeEngine(prefill_fn, decode_fn, batch=args.batch, eos=-1,
                             prefix_cache=PrefixCache(capacity=cache_capacity),
                             block=args.block)
        reqs = make_requests()
        for r in reqs:
            engine.submit(r)
        t0 = time.time()
        engine.run()
        return reqs, engine, time.time() - t0

    reqs, engine, dt = serve(cache_capacity=64)
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s), {engine.steps} engine steps")
    print(f"prefix cache: {engine.cache.hits} block hits / "
          f"{engine.cache.misses} block misses")
    assert all(r.done for r in reqs)
    assert engine.cache.hits > 0, "shared prefix block never hit"

    if args.smoke:
        # cached streams must be bit-identical to the cache-disabled run
        # (capacity 0 => every lookup misses, every insert evicts)
        base, _, _ = serve(cache_capacity=0)
        for a, b in zip(reqs, base):
            assert a.out_tokens == b.out_tokens, \
                f"request {a.rid}: cached stream diverged from uncached"
        print(f"smoke: cached == uncached streams for {len(reqs)} requests")


if __name__ == "__main__":
    main()
