"""Targeted tests for the deterministic fault-injection plane and every
recovery mechanism it exercises: transfer retry/backoff, alternate-source
failover, corruption detection (on the wire, at rest, at read), node churn
with LocationIndex/in-flight cleanup, lineage recompute, cancellation and
deadlines.

Each test pins ONE mechanism with a hand-built :class:`FaultSchedule` on a
small virtual-clock cluster; the seeded end-to-end properties (any schedule
→ every job completes-or-fails-attributed, bit-identical replay) live in
tests/test_chaos_properties.py.
"""
import sys
from pathlib import Path

import pytest

import repro.fix as fix
from repro.core.stdlib import add, checksum_tree, count_string, fib, slice_blob
from repro.runtime import (
    Cluster,
    DataUnrecoverable,
    FaultSchedule,
    Link,
    Network,
    TraceRecorder,
    TransferFailed,
    VirtualClock,
    verify_invariants,
)

sys.path.insert(0, str(Path(__file__).resolve().parent))

pytestmark = pytest.mark.usefixtures("no_thread_leaks")

# A thin pipe everywhere: 16 KB takes ~13 ms of virtual time to serialize,
# so faults scheduled in the first few milliseconds land while transfers
# are genuinely in flight.
SLOW = Network(Link(latency_s=0.0002, gbps=0.01))
PAYLOAD = bytes(range(256)) * 64  # 16 KB


def make_cluster(faults=None, trace=None, **kw) -> tuple[Cluster, VirtualClock]:
    clk = VirtualClock()
    kw.setdefault("n_nodes", 2)
    kw.setdefault("workers_per_node", 1)
    kw.setdefault("storage_nodes", ("s0",))
    kw.setdefault("network", SLOW)
    c = Cluster(clock=clk, seed=0, trace=trace, faults=faults, **kw)
    return c, clk


def storage_job(c: Cluster, n_blobs: int = 2):
    """A checksum over blobs resident only on s0 — every worker placement
    must stage them over the (slow) network."""
    store = c.nodes["s0"].repo
    blobs = [store.put_blob(bytes([i]) + PAYLOAD) for i in range(n_blobs)]
    return checksum_tree(store.put_tree(blobs))


def expected_checksum(n_blobs: int = 2):
    """The same job on a pristine fault-free cluster."""
    c, clk = make_cluster()
    try:
        return fix.on(c).submit(storage_job(c, n_blobs)).result(timeout=120)
    finally:
        c.shutdown()
        clk.close()


def kinds(trace: TraceRecorder) -> list[str]:
    return [ev.kind for ev in trace.events]


class TestTransferRecovery:
    def test_drop_is_retried_to_completion(self):
        """A transient plan drop delays the job; the backoff retry delivers
        the same bytes and the result is unchanged."""
        want = expected_checksum()
        tr = TraceRecorder()
        faults = FaultSchedule().drop(0.0, "s0", "n0").drop(0.0, "s0", "n1")
        c, clk = make_cluster(faults=faults, trace=tr)
        try:
            got = fix.on(c).submit(storage_job(c)).result(timeout=120)
        finally:
            c.shutdown()
            clk.close()
        assert got.raw == want.raw
        ks = kinds(tr)
        assert "transfer_drop" in ks and "transfer_retry" in ks
        assert not verify_invariants(tr.events)

    def test_permanent_link_down_fails_attributed(self):
        """With one worker and its only source unreachable, retries cap out
        and the waiting job fails with a typed TransferFailed."""
        tr = TraceRecorder()
        faults = FaultSchedule().link_down(0.0, "s0", "n0")
        c, clk = make_cluster(faults=faults, trace=tr, n_nodes=1)
        try:
            exc = fix.on(c).submit(storage_job(c)).exception(timeout=120)
        finally:
            c.shutdown()
            clk.close()
        assert isinstance(exc, TransferFailed)
        assert exc.dst == "n0" and exc.attempts > 1
        gaveups = [ev for ev in tr.events if ev.kind == "transfer_gaveup"]
        assert gaveups and all(ev.fields["jobs"] for ev in gaveups[:1])
        assert not verify_invariants(tr.events)

    def test_wire_corruption_detected_and_refetched(self):
        """Bytes flipped in flight are rejected by content verification at
        delivery and re-fetched; the job still produces the clean result."""
        want = expected_checksum()
        tr = TraceRecorder()
        faults = (FaultSchedule()
                  .corrupt_wire(0.0, "s0", "n0")
                  .corrupt_wire(0.0, "s0", "n1"))
        c, clk = make_cluster(faults=faults, trace=tr)
        try:
            got = fix.on(c).submit(storage_job(c)).result(timeout=120)
        finally:
            c.shutdown()
            clk.close()
        assert got.raw == want.raw
        assert "corruption_detected" in kinds(tr)
        assert not verify_invariants(tr.events)

    def test_degraded_link_slows_but_completes(self):
        """Bandwidth degradation stretches the makespan but changes no
        bytes: same result, degrade faults visible in the trace."""
        want = expected_checksum()
        tr = TraceRecorder()
        faults = FaultSchedule().degrade(0.0, "s0", "n0", factor=8.0,
                                         for_s=10.0)
        c, clk = make_cluster(faults=faults, trace=tr)
        try:
            got = fix.on(c).submit(storage_job(c)).result(timeout=120)
        finally:
            c.shutdown()
            clk.close()
        assert got.raw == want.raw
        assert not verify_invariants(tr.events)


class TestCorruptionAtRest:
    def test_resident_corruption_quarantined_and_failed_over(self):
        """corrupt_blob rots a worker-resident input; dispatch-time (or
        read-time) verification quarantines it and the replica on s0 is
        fetched instead — the result is the clean one."""
        tr = TraceRecorder()
        faults = FaultSchedule().corrupt_blob(0.0, "n0", index=0)
        c, clk = make_cluster(faults=faults, trace=tr)
        try:
            payload = bytes([7]) + PAYLOAD
            c.nodes["n0"].repo.put_blob(payload)        # the copy that rots
            blob = c.nodes["s0"].repo.put_blob(payload)  # surviving replica
            tree = c.nodes["s0"].repo.put_tree([blob])
            got = fix.on(c).submit(checksum_tree(tree)).result(timeout=120)
        finally:
            c.shutdown()
            clk.close()
        ks = kinds(tr)
        assert "quarantine" in ks
        assert got is not None
        assert not verify_invariants(tr.events)

    def test_sole_copy_corrupted_no_lineage_fails_attributed(self):
        """When the rotted blob has no replica and no lineage, the job dies
        with DataUnrecoverable — never a wrong answer, never a hang."""
        tr = TraceRecorder()
        # empty schedule still arms the fault plane (verify-on-read etc.)
        c, clk = make_cluster(faults=FaultSchedule(), trace=tr, n_nodes=1)
        try:
            repo = c.nodes["s0"].repo
            blob = repo.put_blob(bytes([9]) + PAYLOAD)
            tree = repo.put_tree([blob])
            rotten = bytearray(repo._blobs[blob.content_key()])
            rotten[0] ^= 0xFF                     # rot the only copy at rest
            repo._blobs[blob.content_key()] = bytes(rotten)
            exc = fix.on(c).submit(checksum_tree(tree)).exception(timeout=120)
        finally:
            c.shutdown()
            clk.close()
        assert isinstance(exc, (DataUnrecoverable, TransferFailed))
        assert not verify_invariants(tr.events)


class TestNodeChurn:
    def test_crash_and_rejoin_traced(self):
        """A crashed worker rejoins with an empty store; the job survives
        via re-placement and both lifecycle events are recorded."""
        tr = TraceRecorder()
        faults = (FaultSchedule()
                  .crash(0.005, "n1")
                  .join(0.02, "n1"))
        c, clk = make_cluster(faults=faults, trace=tr, n_nodes=3)
        try:
            got = fix.on(c).submit(storage_job(c, 3)).result(timeout=120)
        finally:
            c.shutdown()
            clk.close()
        assert got is not None
        crashes = [ev for ev in tr.events
                   if ev.kind == "fault" and ev.fields["fault"] == "crash"]
        assert crashes and crashes[0].fields["applied"]
        joins = [ev for ev in tr.events if ev.kind == "node_join"]
        assert joins and joins[0].fields == {"node": "n1", "fresh": False}
        assert not verify_invariants(tr.events)

    def test_join_brand_new_node_extends_cluster(self):
        """Joining an unknown id adds a fresh worker that can host work."""
        tr = TraceRecorder()
        faults = FaultSchedule().join(0.001, "n9", workers=2)
        c, clk = make_cluster(faults=faults, trace=tr)
        try:
            got = fix.on(c).submit(storage_job(c)).result(timeout=120)
            assert "n9" in c.nodes and c.nodes["n9"].alive
        finally:
            c.shutdown()
            clk.close()
        assert got is not None
        joins = [ev for ev in tr.events if ev.kind == "node_join"]
        assert joins and joins[0].fields["fresh"] is True

    def test_sole_holder_crash_without_lineage_unrecoverable(self):
        """Crash the only node holding an input before it can be served:
        no replica, no lineage — the consumer fails attributed."""
        tr = TraceRecorder()
        faults = FaultSchedule().crash(0.0, "n1")
        c, clk = make_cluster(faults=faults, trace=tr, n_nodes=2)
        try:
            blob = c.nodes["n1"].repo.put_blob(bytes([3]) + PAYLOAD)
            tree = c.nodes["s0"].repo.put_tree([blob])
            exc = fix.on(c).submit(checksum_tree(tree)).exception(timeout=120)
        finally:
            c.shutdown()
            clk.close()
        assert isinstance(exc, (DataUnrecoverable, TransferFailed))
        assert not verify_invariants(tr.events)

    def test_crash_drives_lineage_recompute(self):
        """A derived blob lost to a crash is recomputed from its producing
        Encode (lineage) and the consumer completes with the right answer."""
        c, clk = make_cluster(faults=FaultSchedule(), n_nodes=3,
                              network=Network(Link(latency_s=0.0005, gbps=10)))
        try:
            be = fix.on(c)
            corpus = be.repo.put_blob(bytes(range(256)) * 1000)
            out1 = be.evaluate(slice_blob(corpus, 1000, 500), timeout=60)
            holders = [n.id for n in c.worker_nodes()
                       if n.repo.contains(out1)]
            assert holders
            for nid in holders[:len(c.worker_nodes()) - 1]:
                c.kill_node(nid)
            for n in c.worker_nodes():   # wipe any survivor's copy too
                n.repo._blobs.pop(out1.content_key(), None)
            c._locs.drop_node("nowhere")  # no-op; index already pruned
            out2 = be.run(count_string(out1.as_object(), bytes([232])),
                          timeout=60)
            assert out2 >= 1
        finally:
            c.shutdown()
            clk.close()

    def test_kill_node_races_inflight_transfers_and_prefetch(self):
        """Satellite: kill a node while TransferPlans toward it (and
        prefetches) are in flight.  No worker thread dies, the surviving
        nodes finish the work, and both the LocationIndex and the
        in-flight dedup map drop every entry for the dead node."""
        c, clk = make_cluster(n_nodes=3, workers_per_node=2)
        try:
            be = fix.on(c)
            futs = [be.submit(storage_job(c, 3)) for _ in range(4)]
            futs.append(be.submit(fib(8)))      # fan-out → prefetch pass
            import time as _time
            _time.sleep(0.02)                   # let staging start
            c.kill_node("n1")
            results = [f.result(timeout=300) for f in futs]
            assert all(r is not None for r in results)
            # location index holds nothing for n1 (its store is gone)
            assert all("n1" not in nodes
                       for nodes in c._locs._locs.values())
            # in-flight transfer dedup map dropped the dead destination
            assert all(k[0] != "n1" for k in c._inflight)
            assert all(k[0] != "n1" for k in c._retry)
            # the cluster still schedules new work (no thread death)
            assert be.run(add(1, 2), timeout=60) == 3
        finally:
            c.shutdown()
            clk.close()


class TestCancelAndDeadline:
    def test_future_cancel_prunes_children(self):
        """Cancelling the only waiter aborts the job tree: the future
        raises CancelledError and orphaned child submissions are
        job_cancel'ed rather than left running."""
        tr = TraceRecorder()
        c, clk = make_cluster(trace=tr)
        try:
            fut = fix.on(c).submit(storage_job(c, 4))
            fut.cancel()
            with pytest.raises(Exception) as ei:
                fut.result(timeout=120)
            assert type(ei.value).__name__ in ("CancelledError",)
            assert fut.cancelled()
            # the scheduler survives and accepts new work
            assert fix.on(c).run(add(2, 3), timeout=60) == 5
        finally:
            c.shutdown()
            clk.close()
        assert any(ev.kind == "job_cancel" and ev.fields["reason"] == "cancel"
                   for ev in tr.events)

    def test_deadline_exceeded_is_typed_and_attributed(self):
        """A per-job deadline shorter than the (slow) staging fails that
        job with DeadlineExceeded; unrelated jobs are untouched."""
        tr = TraceRecorder()
        c, clk = make_cluster(trace=tr)
        try:
            be = fix.on(c)
            doomed = be.submit(storage_job(c, 3), deadline_s=0.001)
            fine = be.submit(add(40, 2))
            exc = doomed.exception(timeout=120)
            assert type(exc).__name__ == "DeadlineExceeded"
            assert fine.result(timeout=60) is not None
        finally:
            c.shutdown()
            clk.close()
        assert any(ev.kind == "job_cancel" and ev.fields["reason"] == "deadline"
                   for ev in tr.events)

    def test_local_backend_deadline_and_cancel_api(self):
        """The frontend surface works on the in-process backend too: a
        generous deadline doesn't fire, and results are unchanged."""
        with fix.local() as be:
            assert be.submit(add(20, 22), deadline_s=60.0).result(
                timeout=30) is not None


class TestInternalIOFaults:
    def test_blocking_fetch_survives_drops(self):
        """Internal-I/O mode: the slot-held blocking fetch retries through
        transient drops and the starved job still completes correctly."""
        tr = TraceRecorder()
        faults = FaultSchedule().drop(0.0, "s0", "n0", count=2)
        c, clk = make_cluster(faults=faults, trace=tr, n_nodes=1,
                              io_mode="internal")
        try:
            got = fix.on(c).submit(storage_job(c)).result(timeout=120)
        finally:
            c.shutdown()
            clk.close()
        assert got is not None
        assert not verify_invariants(tr.events)


class TestDeterminism:
    def test_fault_run_replays_bit_identical(self):
        """The same schedule on the same workload yields byte-identical
        trace JSONL — faults, retries, recoveries and all."""
        dumps = []
        for _ in range(2):
            tr = TraceRecorder()
            faults = (FaultSchedule()
                      .drop(0.0, "s0", "n0")
                      .corrupt_wire(0.0, "s0", "n1")
                      .crash(0.01, "n1")
                      .join(0.05, "n1"))
            c, clk = make_cluster(faults=faults, trace=tr, n_nodes=3)
            try:
                fix.on(c).submit(storage_job(c, 3)).result(timeout=120)
            finally:
                c.shutdown()
                clk.close()
            dumps.append(tr.to_jsonl())
        assert dumps[0] == dumps[1]
