"""Content-addressed repository: Fix's storage substrate.

A Repository holds Blobs (bytes) and Trees (tuples of Handles), keyed by
``Handle.content_key()`` so an Object, a Ref, and a Thunk over the same bytes
share storage.  It also holds the *memo table* — the map from Thunks/Encodes
to their evaluation results — which is what makes Fix's deterministic
computations memoizable ("pay-for-results": a result computed anywhere is a
result computed everywhere).

The reachability analysis here is the paper's "minimum repository" (§3.3):
the complete set of data an invocation may touch, computable from the handle
alone before the task runs.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

from .handle import (
    BLOB,
    TREE,
    Handle,
    OBJECT,
    REF,
)


@dataclass
class Footprint:
    """The statically-computable data needs of evaluating a handle.

    ``data`` — content keys of Blobs/Trees that must be resident (Objects
    reachable through the definition).  ``refs`` — content keys referenced
    only as Refs (metadata visible, bytes not needed here).  ``encodes`` —
    Encode handles whose referent Thunks must be *evaluated* before the
    enclosing Application can run; their own footprints become visible once
    the runtime descends into them.
    """

    data: set = field(default_factory=set)
    refs: set = field(default_factory=set)
    encodes: list = field(default_factory=list)

    def merge(self, other: "Footprint") -> None:
        self.data |= other.data
        self.refs |= other.refs
        self.encodes.extend(other.encodes)


class MissingData(KeyError):
    """Raised when data for a handle is not resident in this repository."""

    def __init__(self, handle: Handle):
        super().__init__(repr(handle))
        self.handle = handle


class Repository:
    """A thread-safe content-addressed store plus memo table."""

    def __init__(self, name: str = "repo"):
        self.name = name
        self._blobs: dict[bytes, bytes] = {}
        self._trees: dict[bytes, tuple[Handle, ...]] = {}
        # memo: raw handle bytes of a Thunk or Encode -> result Handle
        self._memo: dict[bytes, Handle] = {}
        self._lock = threading.RLock()

    # ------------------------------------------------------------------ put
    def put_blob(self, payload: bytes) -> Handle:
        h = Handle.blob(payload)
        if not h.is_literal:
            with self._lock:
                self._blobs[h.content_key()] = bytes(payload)
        return h

    def put_tree(self, children: Iterable[Handle]) -> Handle:
        kids = tuple(children)
        h = Handle.tree(kids)
        with self._lock:
            self._trees[h.content_key()] = kids
        return h

    def put_handle_data(self, handle: Handle, payload) -> None:
        """Install data received from elsewhere (network worker path)."""
        if handle.is_literal:
            return
        key = handle.content_key()
        with self._lock:
            if handle.content_type == BLOB:
                assert isinstance(payload, (bytes, bytearray))
                self._blobs[key] = bytes(payload)
            else:
                self._trees[key] = tuple(payload)

    # ------------------------------------------------------------------ get
    def get_blob(self, handle: Handle) -> bytes:
        if handle.content_type != BLOB:
            raise ValueError(f"not a blob handle: {handle!r}")
        if handle.is_literal:
            return handle.literal_payload()
        try:
            return self._blobs[handle.content_key()]
        except KeyError:
            raise MissingData(handle) from None

    def get_tree(self, handle: Handle) -> tuple[Handle, ...]:
        if handle.content_type != TREE:
            raise ValueError(f"not a tree handle: {handle!r}")
        try:
            return self._trees[handle.content_key()]
        except KeyError:
            raise MissingData(handle) from None

    def raw_payload(self, handle: Handle):
        """Blob bytes or Tree children — whatever this handle's content is."""
        return self.get_blob(handle) if handle.content_type == BLOB else self.get_tree(handle)

    # ----------------------------------------------------------------- memo
    def memo_get(self, handle: Handle) -> Optional[Handle]:
        return self._memo.get(handle.raw)

    def memo_put(self, handle: Handle, result: Handle) -> None:
        # first-write-wins: determinism makes duplicate writes identical, so
        # speculative/straggler duplicate execution is harmless.
        with self._lock:
            self._memo.setdefault(handle.raw, result)

    # ----------------------------------------------------------- membership
    def contains(self, handle: Handle) -> bool:
        """Is this handle's own content resident (not transitively)?"""
        if handle.is_literal:
            return True
        key = handle.content_key()
        if handle.content_type == BLOB:
            return key in self._blobs
        return key in self._trees

    def contains_deep(self, handle: Handle) -> bool:
        """Is every Object reachable from this handle resident?"""
        return not self.missing(handle)

    # --------------------------------------------------------- reachability
    def footprint(self, handle: Handle, *, follow_memo: bool = True) -> Footprint:
        """Minimum repository of ``handle`` (paper §3.3).

        Objects are descended recursively (their bytes are accessible to the
        invocation); Refs contribute metadata only; Thunks inside trees stay
        lazy; Encodes are dependencies that must be evaluated first.  If an
        Encode already has a memoized result and ``follow_memo``, its result's
        footprint is folded in instead (the runtime sees through finished
        work).
        """
        fp = Footprint()
        stack = [handle]
        seen: set[bytes] = set()
        while stack:
            h = stack.pop()
            if h.raw in seen:
                continue
            seen.add(h.raw)
            if h.is_encode():
                if follow_memo:
                    res = self.memo_get(h)
                    if res is not None:
                        stack.append(res)
                        continue
                fp.encodes.append(h)
                continue
            if h.is_thunk():
                # Fully lazy (paper fig. 2: the `if` codelet's minimum
                # repository *excludes* the branch thunks' definitions and
                # results).  A bare Thunk is an opaque 32-byte name; its
                # definition is staged only if/when the runtime reduces it.
                continue
            if h.is_ref():
                if not h.is_literal:
                    fp.refs.add(h.content_key())
                continue
            # Object
            if h.is_literal:
                continue
            fp.data.add(h.content_key())
            if h.content_type == TREE:
                try:
                    stack.extend(self.get_tree(h))
                except MissingData:
                    # Tree node itself not resident: its key is already in
                    # fp.data; children unknown until it arrives.
                    pass
        return fp

    def missing(self, handle: Handle) -> list[Handle]:
        """Handles reachable as Objects whose content is not resident."""
        out: list[Handle] = []
        stack = [handle]
        seen: set[bytes] = set()
        while stack:
            h = stack.pop()
            if h.raw in seen:
                continue
            seen.add(h.raw)
            if h.is_encode():
                res = self.memo_get(h)
                if res is not None:
                    stack.append(res)
                continue  # unevaluated encode: not a *data* gap
            if h.is_thunk():
                continue  # lazy — see footprint()
            if h.is_ref() or h.is_literal:
                continue
            if not self.contains(h):
                out.append(h)
                continue
            if h.content_type == TREE:
                stack.extend(self.get_tree(h))
        return out

    def transitive_size(self, handle: Handle) -> int:
        """Bytes of resident data reachable as Objects from ``handle``.

        This is the scheduler's data-movement cost for shipping the minimum
        repository of a task to another node.
        """
        total = 0
        stack = [handle]
        seen: set[bytes] = set()
        while stack:
            h = stack.pop()
            if h.raw in seen:
                continue
            seen.add(h.raw)
            if h.is_encode():
                res = self.memo_get(h)
                if res is not None:
                    stack.append(res)
                continue
            if h.is_thunk():
                continue  # lazy — see footprint()
            if h.is_ref():
                continue
            if h.is_literal:
                total += h.size
                continue
            if h.content_type == BLOB:
                if self.contains(h):
                    total += h.size
            else:
                total += 32 * h.size  # the tree node itself
                if self.contains(h):
                    stack.extend(self.get_tree(h))
        return total

    # -------------------------------------------------------------- export
    def export(self, handle: Handle, sink: "Repository") -> int:
        """Copy everything reachable from ``handle`` into ``sink``.

        Returns bytes copied.  Used by the simulated network worker; real
        deployments would serialize over RPC — the wire format is exactly
        (handle, payload) pairs because handles are self-describing.
        """
        moved = 0
        stack = [handle]
        seen: set[bytes] = set()
        while stack:
            h = stack.pop()
            if h.raw in seen:
                continue
            seen.add(h.raw)
            if h.is_encode():
                res = self.memo_get(h)
                if res is not None:
                    sink.memo_put(h, res)
                    stack.append(res)
                continue
            if h.is_thunk():
                stack.append(h.unwrap_thunk())
                continue
            if h.is_ref() or h.is_literal:
                continue
            if not self.contains(h):
                continue
            if not sink.contains(h):
                payload = self.raw_payload(h)
                sink.put_handle_data(h, payload)
                moved += h.size if h.content_type == BLOB else 32 * h.size
            if h.content_type == TREE:
                stack.extend(self.get_tree(h))
        return moved

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "blobs": len(self._blobs),
            "trees": len(self._trees),
            "memos": len(self._memo),
            "blob_bytes": sum(len(b) for b in self._blobs.values()),
        }
