"""Fixpoint's distributed execution engine (paper §4.2), as a multi-node
cluster simulation faithful to the real system's code paths.

Every node owns a content-addressed Repository and a worker pool; a network
model charges latency + serialized bandwidth per transfer.  The scheduler is
event-driven (single scheduler thread owns all job state; workers and
transfer threads only post events):

* **I/O externalization** — the scheduler walks a Thunk's definition and
  stages its *minimum repository* onto the chosen node before any worker
  slot is bound (late binding).  The ``io_mode="internal"`` ablation instead
  binds the slot first and makes the worker perform blocking fetches —
  reproducing the starvation of conventional serverless platforms (fig 8a/b).
* **Batched transfers** — all of a job's missing handles are coalesced into
  per-(src → dst) :class:`~repro.runtime.transfers.TransferPlan`s that pay
  link latency once and serialize the summed payload, executed by
  persistent per-link workers (see ``transfers.py``).  In-flight transfers
  are deduplicated across jobs: two jobs staging the same blob to the same
  node share one wire transfer.
* **Prefetch** — while a job waits on child Encodes, its already-known
  needs start staging toward the tentatively placed node, overlapping
  child compute with data movement (the paper's fig-8 starvation-reduction
  mechanism).
* **Dataflow-aware placement** — each job runs on the node minimizing the
  *seconds* until its minimum repository is resident (per-link latency +
  serialized time + transfer-queue backlog from the ``TransferManager``),
  computed from the self-describing thunk via the scheduler's location
  index (content key → nodes) — O(needs), no repository scans.  A far node
  behind an idle fat pipe beats a near node behind a congested one.  The
  ``placement="bytes"`` ablation keeps PR 1's bytes-missing score for A/B
  runs; ``placement="random"`` reproduces "Fixpoint (no locality)".
* **Pluggable time** — every sleep, timer, timestamp and deadline goes
  through a :class:`~repro.runtime.clock.Clock`.  The default
  ``WallClock`` behaves exactly like the pre-clock runtime; passing
  ``clock=VirtualClock()`` runs the whole simulation in deterministic
  virtual time, where multi-second topologies execute in milliseconds and
  two identical runs produce identical schedules and accounting.  A
  virtual-clock cluster must be driven from the thread that created it.
* **Trace capture** — ``Cluster(trace=TraceRecorder())`` records every
  scheduling decision (submit/place/start/finish, transfer enqueue/
  link-acquire/deliver, prefetch, speculation, starvation intervals,
  repository puts) as a typed event stream; under a ``VirtualClock`` two
  runs serialize to byte-identical JSONL, which is what makes golden-trace
  regression tests and the randomized invariant fuzz suite possible (see
  ``runtime/trace.py``).  Opt-in and zero-cost when off.
* **Tail calls** — a codelet returning a Thunk yields a *new* job that is
  re-placed from scratch: 500-deep chains need one client submission.
* **Determinism dividends** — results are memoized first-write-wins, so
  straggler speculation is free of side effects; lost data is *recomputed*
  from its lineage (the Encode that produced it) when no replica survives.
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass, field
from typing import Optional

from ..core import CorruptData, Handle, MissingData, Repository
from ..core.handle import APPLICATION, BLOB, IDENTIFICATION, SELECTION, STRICT, TREE
from ..core.repository import walk_object_closure
from ..fix.backend import ClusterBackend
from ..fix.future import CancelledError, DeadlineExceeded, Future
from .clock import Clock, WallClock
from .faults import DataUnrecoverable, FaultState, TransferFailed
from .node import Node, WorkItem
from .telemetry import CodeletProfile, MetricsRegistry, SpanEmitter
from .trace import TraceRecorder
from .transfers import LocationIndex, TransferManager, single_transfer


# ----------------------------------------------------------------- network
@dataclass(frozen=True)
class Link:
    latency_s: float = 0.0002
    gbps: float = 10.0

    def serialized_s(self, nbytes: int) -> float:
        return nbytes * 8 / (self.gbps * 1e9)


class Network:
    def __init__(self, default: Link = Link(), overrides: Optional[dict] = None):
        self.default = default
        self.overrides = dict(overrides or {})

    def link(self, src: str, dst: str) -> Link:
        return self.overrides.get((src, dst), self.default)


# --------------------------------------------------------------------- job
RESOLVE, WAIT_CHILDREN, STAGING, RUNNING, STRICT_WAIT, STRICT_STAGE, DONE = range(7)
_PHASE_NAMES = ["RESOLVE", "WAIT_CHILDREN", "STAGING", "RUNNING",
                "STRICT_WAIT", "STRICT_STAGE", "DONE"]


@dataclass
class Job:
    id: int
    encode: Handle            # the Encode this job resolves
    thunk: Handle             # current WHNF-in-progress thunk
    strict: bool
    ignore_memo: bool = False  # recompute-on-loss path
    tenant: Optional[str] = None  # accounting tag, inherited by children
    phase: int = RESOLVE
    epoch: int = 0
    node: Optional[str] = None
    futures: list = field(default_factory=list)
    parents: list = field(default_factory=list)       # job ids to notify
    pending_children: set = field(default_factory=set)  # encode raws
    staging: set = field(default_factory=set)           # handle raws in flight
    whnf: Optional[Handle] = None                        # data result pre-strictify
    result: Optional[Handle] = None
    started_at: float = 0.0
    duplicated: bool = False
    spec_timer: Optional[object] = None                  # pending speculation wakeup
    on_complete: list = field(default_factory=list)      # callbacks (scheduler thread)
    on_fail: list = field(default_factory=list)          # cb(job, exc) on failure
    span: Optional[int] = None        # causal span ids (spans=True only)
    stage_span: Optional[int] = None
    run_span: Optional[int] = None
    _metric_t0: float = 0.0           # submit instant on the cluster clock


class Cluster:
    """A Fixpoint deployment: N worker nodes (+ optional storage/client)."""

    def __init__(
        self,
        n_nodes: int = 4,
        workers_per_node: int = 2,
        network: Optional[Network] = None,
        placement: str = "locality",      # "locality" (seconds-to-stage)
        #                                  | "bytes" (PR-1 score) | "random"
        io_mode: str = "external",        # "external" | "internal"
        oversubscribe: int = 1,            # internal-mode CPU oversubscription
        storage_nodes: tuple = (),         # ids of 0-worker data-only nodes
        speculate_after_s: Optional[float] = None,
        seed: int = 0,
        node_ram: int = 64 << 30,
        transfer_mode: str = "batched",    # "batched" | "per_handle" (seed A/B)
        prefetch: bool = True,             # stage known needs during WAIT_CHILDREN
        prefetch_depth: int = 1,           # >1: follow child Encodes' definitions
        clock: Optional[Clock] = None,     # WallClock (default) | VirtualClock
        trace: Optional[TraceRecorder] = None,  # opt-in event capture
        faults=None,                       # FaultSchedule: seeded injections
        transfer_retries: int = 4,         # per-(node, key) staging attempts
        retry_backoff_s: float = 0.05,     # first retry delay (doubles)
        retry_backoff_max_s: float = 1.0,  # backoff cap
        metrics: bool = True,              # always-on MetricsRegistry
        spans: bool = False,               # causal span events (needs trace)
        compute_model=None,                # codelet -> modeled seconds, or
        #                                    a CodeletProfile (calibrate()d)
    ):
        if placement not in ("locality", "bytes", "random"):
            raise ValueError(f"unknown placement {placement!r}")
        self.network = network or Network()
        self.placement = placement
        self.io_mode = io_mode
        self.prefetch = prefetch
        if prefetch_depth < 1:
            raise ValueError("prefetch_depth must be >= 1")
        self.prefetch_depth = prefetch_depth
        self.rng = random.Random(seed)
        self._own_clock = clock is None  # we close only what we created
        self.clock = clock if clock is not None else WallClock()
        # Trace capture is opt-in and zero-cost when off: no recorder, no
        # listeners, and every emit site guards on `is None`.  Timestamps
        # are this cluster's clock (deterministic under a VirtualClock).
        self.trace = trace
        if trace is not None:
            trace.bind(self.clock)
        # Live telemetry: metrics are pure in-memory arithmetic — no clock
        # reads, no trace events — so the default-on registry leaves
        # VirtualClock schedules (and the golden trace) byte-identical.
        self.metrics = MetricsRegistry() if metrics else None
        # instrument-handle cache: label-key rendering off the hot path
        # (one dict hit per counter bump instead of kwargs + formatting)
        self._instruments: dict = {}
        if self.metrics is not None:
            self._m_transfers = self.metrics.counter("transfers_total")
            self._m_bytes = self.metrics.counter("bytes_moved_total")
        # Spans ride the trace stream and are opt-in: the default event
        # vocabulary, and the committed golden fixture, stay untouched.
        self.spans = (SpanEmitter(trace)
                      if spans and trace is not None else None)
        if compute_model is not None and hasattr(compute_model, "calibrate"):
            compute_model = compute_model.calibrate()
        self.compute_model = compute_model
        # Under a virtual clock the creating thread becomes the registered
        # driver: its blocking waits (Future deadlines, fetches) participate
        # in the deterministic token handoff.  No-op for WallClock.
        self.clock.register_current()
        workers = workers_per_node * (oversubscribe if io_mode == "internal" else 1)
        self._workers_per_node = workers   # default for nodes joining later
        self._node_ram = node_ram
        self.transfer_retries = transfer_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_max_s = retry_backoff_max_s
        # Live link-fault state (down links, degradation, drop/corrupt
        # budgets): shared with the TransferManager's link workers.  None
        # when fault injection is off — every fault-path check guards on it,
        # so no-fault runs keep byte-identical traces.
        self._fstate: Optional[FaultState] = (
            FaultState() if faults is not None else None)
        self.nodes: dict[str, Node] = {}
        for i in range(n_nodes):
            self.nodes[f"n{i}"] = Node(f"n{i}", workers, node_ram,
                                       clock=self.clock, trace=trace,
                                       compute_model=self.compute_model)
        for sid in storage_nodes:
            self.nodes[sid] = Node(sid, 0, node_ram,
                                   clock=self.clock, trace=trace)
        self.client = Node("client", 0, node_ram, clock=self.clock, trace=trace)
        self.nodes["client"] = self.client
        self.speculate_after_s = speculate_after_s

        self._events = self.clock.make_queue()
        self._jobs: dict[int, Job] = {}
        self._by_encode: dict[bytes, int] = {}
        self._memo: dict[bytes, Handle] = {}            # encode raw -> result
        self._lineage: dict[bytes, Handle] = {}          # content key -> encode
        self._inflight: dict[tuple, list] = {}           # (node, raw) -> waiter ids
        self._retry: dict[tuple, int] = {}               # (node, raw) -> attempts
        self._retry_src: dict[tuple, str] = {}           # (node, raw) -> failed src
        self._pending_retries = 0                        # armed backoff timers
        self._reach: dict[bytes, tuple] = {}             # handle raw -> object closure
        self._ids = itertools.count()
        self.transfers = 0
        self.bytes_moved = 0

        # Location index: every repository put (worker results, client puts,
        # transfer deliveries) lands here, so source lookup and placement
        # never scan node repositories.
        self._locs = LocationIndex()
        for name, n in self.nodes.items():
            self._wire_node(name, n)
        self._xfer = TransferManager(
            self.network, self.nodes, self._events.put,
            account=self._account_transfer, mode=transfer_mode,
            clock=self.clock, trace=trace, faults=self._fstate,
            metrics=self.metrics, spans=self.spans)

        # The user-facing surface: Cluster.submit/evaluate/fetch_result are
        # thin delegates to this Backend (repro.fix), which owns program
        # compilation, fetch accounting and decode.
        self.backend = ClusterBackend(self)

        self._sched = self.clock.spawn(self._loop, name="fix-sched")
        for n in self.nodes.values():
            n.start(self._on_worker_done, fetcher=self._blocking_fetch)
        # Straggler speculation is event-driven: each run schedules one
        # clock wakeup at its speculation deadline (see _enqueue_run) — no
        # polling thread to spin under a virtual clock or oversleep under
        # the wall clock.

        # Fault injection: one clock timer per schedule entry, armed at
        # startup so injections land at exact (virtual) instants and the
        # whole run — faults, recoveries and all — replays bit-identically.
        if faults is not None:
            start = self.clock.now()
            for f in faults.expanded():
                self.clock.call_at(start + f.t,
                                   lambda ff=f: self._events.put(("fault", ff)))

    def _wire_node(self, name: str, node: Node) -> None:
        """Attach the location-index and trace put listeners to a node's
        (possibly reborn) repository.  Listeners live on the Repository
        object, which ``Node.kill()`` replaces — so a rejoining node must
        be rewired or its puts become invisible to the scheduler."""
        if self._fstate is not None:
            node.repo.verify_reads = True  # kill() replaces the repo object
        node.repo.add_put_listener(
            lambda h, _name=name: self._locs.add(h.content_key(), _name))
        if self.trace is not None:
            # residency stream: every content arrival (worker results,
            # client puts, transfer deliveries) becomes a "put" event,
            # which is what the invariant checker and starvation
            # attribution consume.
            node.repo.add_put_listener(
                lambda h, _name=name: self.trace.emit(
                    "put", node=_name, key=h.content_key().hex(),
                    nbytes=h.size if h.content_type == BLOB
                    else 32 * h.size))

    # --------------------------------------------------------------- public
    @property
    def client_repo(self) -> Repository:
        return self.client.repo

    def worker_nodes(self) -> list[Node]:
        return [n for n in self.nodes.values() if n.n_workers > 0 and n.alive]

    def submit(self, program, *, deadline_s: Optional[float] = None,
               tenant: Optional[str] = None) -> Future:
        """Thin delegate: accepts a Lazy program or a Handle (thunks are
        strict-wrapped), compiled by the Backend against the client repo.
        ``deadline_s`` bounds the job itself (clock-seconds from submit):
        expiry fails the future with DeadlineExceeded and cancels orphaned
        child work.  ``tenant`` tags the job (and its children) in trace
        events for per-tenant SLO attribution."""
        return self.backend.submit(program, deadline_s=deadline_s,
                                   tenant=tenant)

    def evaluate(self, program, timeout: float = 120.0) -> Handle:
        return self.backend.evaluate(program, timeout)

    def fetch_result(self, handle: Handle, into: Optional[Repository] = None) -> Repository:
        """Pull result bytes to the client — link costs paid *and accounted*
        (see ClusterBackend.fetch_result)."""
        return self.backend.fetch_result(handle, into)

    def _submit_encode(self, encode: Handle,
                       deadline_s: Optional[float] = None,
                       tenant: Optional[str] = None) -> Future:
        """Raw submission path the Backend compiles down to."""
        fut = Future()
        fut._clock = self.clock  # clock-aware deadlines (virtual timeouts)
        # cancel() routes through the scheduler thread, which owns job
        # state and can prune orphaned child submissions
        fut._canceller = lambda f: self._events.put(("cancel", f))
        self._events.put(("submit", encode, fut, None, False, deadline_s,
                          tenant))
        return fut

    def kill_node(self, node_id: str) -> None:
        self.nodes[node_id].kill()
        self._events.put(("node_failed", node_id))

    def reset_accounting(self) -> None:
        for n in self.nodes.values():
            n.busy_ns = n.starved_ns = 0
            n.jobs_run = 0
        self.transfers = 0
        self.bytes_moved = 0

    def utilization(self, window_s: float) -> dict:
        """Worker-slot time over ``window_s``, as three fractions that
        partition the window: *busy* (codelet running), *starved* (slot held
        while internal-mode I/O completes — the paper's iowait), and
        *idle_iowait* (the remainder: slots with nothing bound).  Starvation
        is no longer double-counted into the idle fraction.

        Degenerate windows are well-defined: a zero-length (or negative)
        window — e.g. a virtual-clock workload whose jobs finish in the
        same simulated instant they start — contains no slot-time, so it
        reports all-idle rather than dividing by ~0.  Fractions are
        clamped to [0, 1]; no input produces NaN or a negative fraction."""
        busy = sum(n.busy_ns for n in self.worker_nodes()) * 1e-9
        starved = sum(n.starved_ns for n in self.worker_nodes()) * 1e-9
        slots = sum(n.n_workers for n in self.worker_nodes())
        denom = slots * window_s
        if denom <= 0.0:
            busy_frac = starved_frac = 0.0  # empty window: nothing measurable
        else:
            busy_frac = min(busy / denom, 1.0)
            # starved takes what headroom busy left, so the three fractions
            # always partition the window (sum == 1) even when the window
            # undercounts accumulated slot-time
            starved_frac = min(starved / denom, 1.0 - busy_frac)
        return {
            "busy_frac": busy_frac,
            "starved_frac": starved_frac,
            "idle_iowait_frac": max(0.0, 1.0 - busy_frac - starved_frac),
            "transfers": self.transfers,
            "bytes_moved": self.bytes_moved,
        }

    def codelet_profile(self) -> CodeletProfile:
        """Aggregate per-codelet wall timings across every node's
        evaluator — the local/simulated half of the record → model →
        replay seam (``fix.remote()`` workers ship theirs in ``ran``
        replies)."""
        prof = CodeletProfile()
        for n in self.nodes.values():
            prof.update((name, ent[0], ent[1])
                        for name, ent in n.evaluator.codelets.items())
        return prof

    def stats(self) -> dict:
        """One live snapshot, same top-level shape as
        ``RemoteBackend.stats()`` / ``FixServeEngine.stats()``:
        ``backend`` / ``metrics`` / ``codelets`` plus backend-specific
        sections (node accounting, link backlog)."""
        src_backlog, link_depth = self._xfer.backlog_snapshot()
        return {
            "backend": "cluster",
            "metrics": (self.metrics.snapshot()
                        if self.metrics is not None else {}),
            "codelets": self.codelet_profile().to_dict(),
            "nodes": {name: n.accounting()
                      for name, n in sorted(self.nodes.items())},
            "transfers": self.transfers,
            "bytes_moved": self.bytes_moved,
            "links": {f"{s}->{d}": depth
                      for (s, d), depth in sorted(link_depth.items())},
            "src_backlog_bytes": dict(sorted(src_backlog.items())),
        }

    def shutdown(self) -> None:
        self._events.put(("stop",))
        # Join the scheduler FIRST: transfer submissions are scheduler-
        # thread-only, so once it drains to the stop sentinel no new link
        # workers or per-handle threads can race TransferManager.stop()'s
        # join snapshot.
        with self.clock.external_wait():  # scheduler needs the clock to drain
            self._sched.join(timeout=5)
        self._xfer.stop()
        for n in self.nodes.values():
            n.stop()
        if self._own_clock:
            # A caller-provided clock (e.g. two clusters sharing one
            # simulated timeline) outlives us; its creator closes it.
            self.clock.close()

    # ------------------------------------------------------ scheduler loop
    def _loop(self) -> None:
        draining = False
        while True:
            ev = self._events.get()
            kind = ev[0]
            try:
                if kind == "stop":
                    # Graceful drain: keep processing until every in-flight
                    # transfer has delivered (or dropped) and every armed
                    # retry timer has fired, so recovery plays out fully and
                    # traces end quiescent.  Bounded by the retry caps.
                    if self._quiet():
                        return
                    draining = True
                    continue
                elif kind == "submit":
                    self._on_submit(*ev[1:])
                elif kind == "child_done":
                    self._on_child_done(*ev[1:])
                elif kind == "transfer_done":
                    self._on_transfer_done(*ev[1:])
                elif kind == "transfer_failed":
                    self._on_transfer_failed(*ev[1:])
                elif kind == "retry_stage":
                    self._pending_retries -= 1
                    self._on_retry_stage(ev[1])
                elif kind == "recompute":
                    self._on_retry_stage(ev[1], parent=ev[2])
                elif kind == "ran":
                    self._on_ran(*ev[1:])
                elif kind == "node_failed":
                    self._on_node_failed(ev[1])
                elif kind == "fault":
                    self._on_fault(ev[1])
                elif kind == "cancel":
                    self._on_cancel(ev[1])
                elif kind == "deadline":
                    self._on_deadline(ev[1])
                elif kind == "source_suspect":
                    self._check_source(ev[1], (ev[2],))
                elif kind == "tick":
                    self._on_tick(ev[1])
            except Exception as e:  # noqa: BLE001 — fail the affected job only
                self._scope_failure(kind, ev, e)
            if draining and self._quiet():
                return

    def _quiet(self) -> bool:
        """True when no transfer is in flight, no retry timer is armed, no
        event is queued and no job is running on a live worker — safe to
        exit the scheduler loop.  (A RUNNING job on a *dead* node never
        posts "ran"; the crash handler re-places it, so it can't persist.)"""
        return (self._events.qsize() == 0
                and self._pending_retries == 0
                and self._xfer.pending() == 0
                and not any(j.phase == RUNNING
                            and j.node is not None
                            and j.node in self.nodes
                            and self.nodes[j.node].alive
                            for j in self._jobs.values()))

    def _scope_failure(self, kind: str, ev: tuple, exc: BaseException) -> None:
        """A handler blew up: fail the job(s) the event belonged to (and
        their parents) but keep the scheduler loop — and every unrelated
        in-flight job — alive."""
        jids: set[int] = set()
        if kind == "submit":
            encode, fut, parent = ev[1], ev[2], ev[3]
            if fut is not None and not fut.done():
                fut.set_exception(exc)
            if parent is not None:
                jids.add(parent)
            jid = self._by_encode.get(encode.raw)
            if jid is not None:
                jids.add(jid)
        elif kind == "child_done":
            jids.add(ev[1])
        elif kind in ("transfer_done", "transfer_failed"):
            node_id, raws = ev[1], ev[2]
            for raw in raws:
                jids.update(self._inflight.pop((node_id, raw), []))
        elif kind in ("retry_stage", "recompute"):
            jids.update(self._inflight.pop(ev[1], []))
        elif kind in ("cancel", "deadline"):
            fut = ev[1]
            if not fut.done():
                fut.set_exception(exc)
            jid = getattr(fut, "_jid", None)
            if jid is not None:
                jids.add(jid)
        elif kind == "source_suspect":
            return  # advisory only; no job to blame
        elif kind == "ran":
            jids.add(ev[2].job_id)
        elif kind == "tick":
            jids.add(ev[1])  # job-targeted speculation wakeup
        else:
            # node_failed / fault touch many jobs; no single owner to blame.
            self._fail_all(exc)
            return
        for jid in jids:
            self._fail_job(self._jobs.get(jid), exc)

    def _fail_job(self, job: Optional[Job], exc: BaseException) -> None:
        if job is None or job.phase == DONE:
            return
        job.phase = DONE
        if self.trace is not None:
            self.trace.emit("job_fail", job=job.id,
                            error=type(exc).__name__)
        self._count_job(job, "failed")
        self._end_job_spans(job, "fail")
        self._cancel_speculation(job)
        for f in job.futures:
            f.set_exception(exc)
        self._run_on_fail(job, exc)
        self._notify_parents_exc(job, exc)

    def _fail_all(self, exc: BaseException) -> None:
        for job in list(self._jobs.values()):
            if job.phase != DONE:
                for f in job.futures:
                    f.set_exception(exc)
                job.phase = DONE
                if self.trace is not None:
                    self.trace.emit("job_fail", job=job.id,
                                    error=type(exc).__name__)
                self._count_job(job, "failed")
                self._end_job_spans(job, "fail")
                self._cancel_speculation(job)
                self._run_on_fail(job, exc)

    def _run_on_fail(self, job: Job, exc: BaseException) -> None:
        """Failure callbacks (scheduler thread): recompute jobs use these
        so waiters blocked on them fail attributed instead of hanging."""
        callbacks, job.on_fail = job.on_fail, []
        for cb in callbacks:
            try:
                cb(job, exc)
            except Exception:  # noqa: BLE001 — a callback must not cascade
                pass

    # ----------------------------------------------------------- telemetry
    def _count_job(self, job: Job, outcome: str) -> None:
        """``jobs_<outcome>`` counter, tenant-labelled when the job is
        tagged — incremented exactly where the matching trace event is
        emitted, so metrics and trace-derived counts always agree."""
        m = self.metrics
        if m is None:
            return
        key = (outcome, job.tenant)
        c = self._instruments.get(key)
        if c is None:
            tl = {} if job.tenant is None else {"tenant": job.tenant}
            c = self._instruments[key] = m.counter("jobs_" + outcome, **tl)
        c.inc()

    def _end_job_spans(self, job: Job, status: str) -> None:
        """Close any open stage/run span and the job span itself (failure
        and cancellation paths can leave inner spans dangling)."""
        sp = self.spans
        if sp is None:
            return
        sp.end(job.run_span)
        job.run_span = None
        sp.end(job.stage_span)
        job.stage_span = None
        if job.span is not None:
            sp.end(job.span, status=status)
            job.span = None

    # ------------------------------------------------------------- events
    def _on_submit(self, encode: Handle, fut: Optional[Future],
                   parent: Optional[int], ignore_memo: bool,
                   deadline_s: Optional[float] = None,
                   tenant: Optional[str] = None) -> None:
        tr = self.trace
        if tenant is None and parent is not None:
            # child work bills to whoever submitted the root program
            pj = self._jobs.get(parent)
            if pj is not None:
                tenant = pj.tenant
        if fut is not None and deadline_s is not None:
            # the deadline runs on the cluster clock (virtual deadlines are
            # simulated seconds); completing first cancels the timer so the
            # residual no-op fire never outlives the job
            timer = self.clock.call_later(
                deadline_s, lambda f=fut: self._events.put(("deadline", f)))
            fut.add_done_callback(lambda _f, t=timer: t.cancel())
        if not ignore_memo:
            memo = self._memo.get(encode.raw)
            if memo is not None and self._find_source_name(memo) is not None:
                if self.metrics is not None:
                    tl = {} if tenant is None else {"tenant": tenant}
                    self.metrics.counter("jobs_memo_hit", **tl).inc()
                if tr is not None:
                    extra = {} if tenant is None else {"tenant": tenant}
                    tr.emit("job_memo_hit", encode=encode.raw.hex(), **extra)
                if fut is not None:
                    fut.set(memo)
                if parent is not None:
                    self._child_resolved(parent, encode)
                return
            existing = self._by_encode.get(encode.raw)
            if existing is not None and self._jobs[existing].phase != DONE:
                job = self._jobs[existing]
                if fut is not None:
                    fut._jid = existing
                    job.futures.append(fut)
                if parent is not None:
                    job.parents.append(parent)
                return
        jid = next(self._ids)
        job = Job(jid, encode, encode.unwrap_encode(), encode.interp == STRICT,
                  ignore_memo=ignore_memo, tenant=tenant)
        if fut is not None:
            fut._jid = jid
            job.futures.append(fut)
        if parent is not None:
            job.parents.append(parent)
        self._jobs[jid] = job
        if not ignore_memo:
            self._by_encode[encode.raw] = jid
        job._metric_t0 = self.clock.now()
        self._count_job(job, "submitted")
        if self.spans is not None:
            pspan = None
            if parent is not None:
                pj = self._jobs.get(parent)
                if pj is not None:
                    pspan = pj.span
            job.span = self.spans.begin("job", parent=pspan, job=jid)
        if tr is not None:
            # tenant only when tagged: untagged runs keep byte-identical
            # traces (the golden-fixture replay diff)
            extra = {} if tenant is None else {"tenant": tenant}
            tr.emit("job_submit", job=jid, encode=encode.raw.hex(),
                    strict=job.strict, parent=parent, recompute=ignore_memo,
                    **extra)
        self._advance(job)

    def _on_child_done(self, parent_id: int, child_encode: Handle) -> None:
        self._child_resolved(parent_id, child_encode)

    def _child_resolved(self, parent_id: int, child_encode: Handle) -> None:
        job = self._jobs.get(parent_id)
        if job is None or job.phase == DONE:
            return
        job.pending_children.discard(child_encode.raw)
        if not job.pending_children and job.phase in (WAIT_CHILDREN, STRICT_WAIT):
            job.phase = RESOLVE if job.phase == WAIT_CHILDREN else STRICT_STAGE
            self._advance(job)

    def _on_transfer_done(self, node_id: str, raws: tuple) -> None:
        for raw in raws:
            self._complete_stage(node_id, raw)

    def _complete_stage(self, node_id: str, raw: bytes) -> None:
        """A staged handle is settled for ``node_id`` (delivered, or its
        plan toward a dead node was reaped): clear retry state and unblock
        waiting jobs."""
        key = (node_id, raw)
        self._retry.pop(key, None)
        self._retry_src.pop(key, None)
        waiters = self._inflight.pop(key, [])
        for jid in waiters:
            job = self._jobs.get(jid)
            if job is None or job.phase not in (STAGING, STRICT_STAGE):
                continue
            job.staging.discard(raw)
            if not job.staging:
                if job.phase == STAGING:
                    self._enqueue_run(job)
                else:
                    self._enqueue_strictify(job)

    # ------------------------------------------------------ fault recovery
    def _live_waiter(self, jid: int, raw: bytes) -> bool:
        """Is this waiter still a job actually blocked on ``raw``?  Jobs
        re-placed after a node failure leave stale ids in the in-flight
        table; retrying (or failing!) on their behalf would be wrong."""
        job = self._jobs.get(jid)
        return (job is not None and job.phase in (STAGING, STRICT_STAGE)
                and raw in job.staging)

    def _on_transfer_failed(self, node_id: str, raws: tuple, reason: str,
                            src: Optional[str]) -> None:
        """A plan (or single handle) was lost to a fault: retry with capped
        exponential backoff, switching source when one is suspect."""
        if reason == "corrupt" and src is not None:
            self._check_source(src, raws)
        node = self.nodes.get(node_id)
        for raw in raws:
            key = (node_id, raw)
            h = Handle(raw)
            if node is None or not node.alive:
                # dst died anyway — the node-failure path re-places waiters
                self._inflight.pop(key, None)
                self._retry.pop(key, None)
                self._retry_src.pop(key, None)
                continue
            if node.repo.contains(h):  # a parallel replica already landed
                self._complete_stage(node_id, raw)
                continue
            if not any(self._live_waiter(jid, raw)
                       for jid in self._inflight.get(key, [])):
                self._give_up(key, h, "abandoned")
                continue
            attempts = self._retry.get(key, 0) + 1
            self._retry[key] = attempts
            if attempts > self.transfer_retries:
                self._give_up(key, h, reason)
                continue
            if src is not None:
                self._retry_src[key] = src  # prefer another replica next try
            delay = min(self.retry_backoff_s * (2 ** (attempts - 1)),
                        self.retry_backoff_max_s)
            if self.trace is not None:
                self.trace.emit("transfer_retry", dst=node_id,
                                key=h.content_key().hex(), attempt=attempts,
                                delay_s=delay, reason=reason)
            self._pending_retries += 1
            self.clock.call_later(
                delay, lambda k=key: self._events.put(("retry_stage", k)))

    def _on_retry_stage(self, key: tuple,
                        parent: Optional[int] = None) -> None:
        """Backoff elapsed (or a deferred recompute request): restage one
        (node, raw) from the best surviving source, falling back to
        lineage recompute."""
        node_id, raw = key
        if key not in self._inflight:
            self._retry.pop(key, None)
            self._retry_src.pop(key, None)
            return
        h = Handle(raw)
        node = self.nodes.get(node_id)
        if node is None or not node.alive:
            self._inflight.pop(key, None)
            self._retry.pop(key, None)
            self._retry_src.pop(key, None)
            return
        if node.repo.contains(h):
            self._complete_stage(node_id, raw)
            return
        if not any(self._live_waiter(jid, raw)
                   for jid in self._inflight.get(key, [])):
            self._give_up(key, h, "abandoned")
            return
        src = self._find_source_name(h, exclude=node_id,
                                     avoid=self._retry_src.get(key),
                                     dst=node_id)
        payload = None
        while src is not None:
            payload = self._read_source(src, h)
            if payload is not None:
                break
            src = self._find_source_name(h, exclude=node_id,
                                         avoid=self._retry_src.get(key),
                                         dst=node_id)
        if src is None:
            self._spawn_recompute(node, h, key, parent=parent)
            return
        size = h.size if h.content_type == BLOB else 32 * h.size
        if self.trace is not None:
            self.trace.emit("stage_request", job=None, dst=node_id,
                            key=h.content_key().hex(), nbytes=size,
                            action="enqueue", src=src,
                            retry=self._retry.get(key, 0))
        self._xfer.submit(src, node_id, [(h, payload, size)])

    def _give_up(self, key: tuple, h: Handle, reason: str) -> None:
        """Retry budget exhausted (or nothing left to retry for): fail the
        jobs still blocked on this handle with an attributed, typed error
        and drop the in-flight entry."""
        node_id, raw = key
        attempts = self._retry.pop(key, 0)
        self._retry_src.pop(key, None)
        waiters = self._inflight.pop(key, [])
        failed: list[int] = []
        key_hex = h.content_key().hex()
        for jid in waiters:
            job = self._jobs.get(jid)
            if (job is None or job.phase not in (STAGING, STRICT_STAGE)
                    or raw not in job.staging):
                continue  # re-placed elsewhere; not this entry's casualty
            if reason in ("unrecoverable", "recompute_failed"):
                exc: Exception = DataUnrecoverable(key_hex, reason)
            else:
                exc = TransferFailed(key_hex, node_id, attempts, reason)
            self._fail_job(job, exc)
            failed.append(jid)
        if self.trace is not None:
            self.trace.emit("transfer_gaveup", dst=node_id, key=key_hex,
                            attempts=attempts, reason=reason, jobs=failed)

    def _scrub_resident(self, node: Node, needs: list) -> None:
        """Fault plane active: re-verify this job's *resident* inputs before
        dispatch, so a blob rotted at rest (``corrupt_blob``) is quarantined
        and re-staged from a replica (or recomputed) instead of silently
        feeding the computation a wrong byte."""
        for h in needs:
            if node.repo.contains(h) and not node.repo.verify_resident(h):
                node.repo.quarantine(h)
                self._locs.discard(h.content_key(), node.id)
                if self.trace is not None:
                    key_hex = h.content_key().hex()
                    self.trace.emit("corruption_detected", src=node.id,
                                    dst=node.id, key=key_hex, via="dispatch")
                    self.trace.emit("quarantine", node=node.id, key=key_hex)

    def _check_source(self, src_id: str, raws: tuple) -> None:
        """A delivery from ``src_id`` failed content verification: if the
        source's own copy is rotten (at-rest corruption), quarantine it and
        drop it from the location index so retries use another replica."""
        node = self.nodes.get(src_id)
        if node is None or not node.alive:
            return
        for raw in raws:
            h = Handle(raw)
            if node.repo.contains(h) and not node.repo.verify_resident(h):
                node.repo.quarantine(h)
                self._locs.discard(h.content_key(), src_id)
                if self.trace is not None:
                    self.trace.emit("quarantine", node=src_id,
                                    key=h.content_key().hex())

    def _on_ran(self, node: Node, item: WorkItem, result) -> None:
        job = self._jobs.get(item.job_id)
        if job is None or job.phase == DONE or item.epoch != job.epoch:
            return  # stale (straggler duplicate / failed-over epoch)
        if self.spans is not None and job.run_span is not None:
            self.spans.end(job.run_span)
            job.run_span = None
        if isinstance(result, CorruptData):
            self._recover_corrupt_read(job, result)
            return
        if isinstance(result, BaseException):
            self._fail_job(job, result)
            return
        if item.thunk is None:  # strictify op completed
            self._finalize(job, result)
            return
        if result.is_thunk():  # tail call: fresh placement (paper §4.2.2)
            job.thunk = result
            job.epoch += 1
            job.phase = RESOLVE
            # the thunk's definition may have died with its producing node
            # (kill racing the "ran" event): restart from the encode if so
            self._advance_or_restart(job)
            return
        # WHNF data
        job.whnf = result
        job.epoch += 1
        if not job.strict:
            out = result.as_ref() if result.is_data() else result
            self._finalize(job, out)
            return
        self._begin_strictify(job)

    def _recover_corrupt_read(self, job: Job, exc: CorruptData) -> None:
        """A run tripped over at-rest corruption (``verify_reads``): the
        handle's bytes no longer match its digest.  Quarantine the rotten
        copy, drop it from the location index, and replay the job from its
        current step — re-placement finds the content missing and re-stages
        it from a replica or recomputes it from lineage."""
        h = exc.handle
        if job.node is not None:
            node = self.nodes.get(job.node)
            if node is not None:
                node.repo.quarantine(h)
                self._locs.discard(h.content_key(), job.node)
                if self.trace is not None:
                    key_hex = h.content_key().hex()
                    self.trace.emit("corruption_detected", src=job.node,
                                    dst=job.node, key=key_hex, via="read")
                    self.trace.emit("quarantine", node=job.node, key=key_hex)
        job.epoch += 1
        self._cancel_speculation(job)
        if job.whnf is not None and job.strict:
            self._begin_strictify(job)
        else:
            job.phase = RESOLVE
            self._advance_or_restart(job)

    # ------------------------------------------------------------ advance
    def _advance_or_restart(self, job: Job) -> None:
        """Advance; if the in-progress (tail-call) thunk's definition is
        gone (its producing node died), restart from the original encode —
        the determinism dividend: every step re-derives identically."""
        try:
            self._advance(job)
        except MissingData:
            job.epoch += 1
            job.thunk = job.encode.unwrap_encode()
            job.whnf = None
            job.phase = RESOLVE
            self._advance(job)  # a second failure escapes to _scope_failure

    def _advance(self, job: Job) -> None:
        thunk = job.thunk
        if thunk.is_data():  # submitted encode over an already-data handle
            job.whnf = thunk
            if job.strict:
                self._begin_strictify(job)
            else:
                self._finalize(job, thunk.as_ref())
            return
        needs, children, memo_pairs = self._step_needs(thunk)
        unresolved = [c for c in children if self._memo.get(c.raw) is None]
        if unresolved:
            job.phase = WAIT_CHILDREN
            job.pending_children = {c.raw for c in unresolved}
            for c in unresolved:
                self._events.put(("submit", c, None, job.id, False, None,
                                  None))
            # overlap child compute with data movement: stage what we
            # already know this job needs toward its tentative placement
            self._maybe_prefetch(needs, children=unresolved)
            return
        # fold resolved child results into the staging set
        for enc in children:
            res = self._memo[enc.raw]
            memo_pairs.append((enc, res))
            needs.extend(self._deep_object_handles(res))
        node = self._place(job, needs)
        job.node = node.id
        for enc, res in memo_pairs:
            node.repo.memo_put(enc, res)
            node.repo.memo_put(enc.unwrap_encode(), res)
        if self._fstate is not None:
            self._scrub_resident(node, needs)
        missing = [h for h in needs if not node.repo.contains(h)]
        if self.trace is not None:
            self.trace.emit(
                "job_place", job=job.id, node=node.id, epoch=job.epoch,
                n_missing=len(missing),
                missing_nbytes=sum(h.size if h.content_type == BLOB
                                   else 32 * h.size for h in missing))
        if self.io_mode == "internal":
            self._enqueue_run(job, internal=missing)
            return
        if missing:
            job.phase = STAGING
            if self.spans is not None:
                job.stage_span = self.spans.begin(
                    "stage", parent=job.span, job=job.id, n=len(missing))
            job.staging = self._stage_missing(node, missing, job.id)
            if not job.staging:
                self._enqueue_run(job)
        else:
            self._enqueue_run(job)

    def _enqueue_run(self, job: Job, internal: Optional[list] = None) -> None:
        node = self.nodes[job.node]
        fetches = [(h, 0.0) for h in (internal or [])]
        item = WorkItem(job.id, job.epoch, job.thunk, internal_fetches=fetches)
        job.phase = RUNNING
        job.started_at = self.clock.now()
        if self.spans is not None:
            self.spans.end(job.stage_span)
            job.stage_span = None
            job.run_span = self.spans.begin(
                "run", parent=job.span, job=job.id, node=job.node, op="run")
        if self.trace is not None:
            self.trace.emit("job_start", job=job.id, node=job.node,
                            epoch=job.epoch, op="run", internal=len(fetches))
        self._arm_speculation(job)
        node.queue.put(item)

    def _arm_speculation(self, job: Job) -> None:
        """One clock wakeup at this run's straggler deadline (replaces the
        seed's sleep(speculate/4) polling thread): the tick fires exactly
        when the job *could* first be overdue, and not before.  The timer
        is cancelled when the job finishes so long-lived clusters don't
        accumulate spurious global ticks."""
        if self.speculate_after_s is None or job.duplicated:
            return
        self._cancel_speculation(job)
        job.spec_timer = self.clock.call_at(
            job.started_at + self.speculate_after_s,
            lambda jid=job.id: self._events.put(("tick", jid)))

    def _cancel_speculation(self, job: Job) -> None:
        if job.spec_timer is not None:
            job.spec_timer.cancel()
            job.spec_timer = None

    # ---------------------------------------------------------- strictify
    def _begin_strictify(self, job: Job) -> None:
        """Deep-evaluate the WHNF result: nested thunks/encodes become child
        jobs; Ref'd data is staged; then the node runs a local strictify."""
        whnf = job.whnf
        children: list[Handle] = []
        stage: list[Handle] = []
        stack = [whnf]
        seen = set()
        while stack:
            h = stack.pop()
            if h.raw in seen or h.is_literal:
                continue
            seen.add(h.raw)
            if h.is_encode():
                res = self._memo.get(h.raw)
                if res is None:
                    children.append(h)
                else:
                    stack.append(res)
                continue
            if h.is_thunk():
                children.append(h.strict())
                continue
            # data (object or ref): strict promotes refs, so stage content
            stage.append(h)
            if h.content_type == TREE:
                kids = self._tree_children(h)
                if kids is not None:
                    stack.extend(kids)
        job._strict_stage = stage  # type: ignore[attr-defined]
        unresolved = [c for c in children if self._memo.get(c.raw) is None]
        if unresolved:
            job.phase = STRICT_WAIT
            job.pending_children = {c.raw for c in unresolved}
            job._strict_children = children  # type: ignore[attr-defined]
            for c in unresolved:
                self._events.put(("submit", c, None, job.id, False, None,
                                  None))
            self._maybe_prefetch(stage, node_id=job.node, children=unresolved)
            return
        job._strict_children = children  # type: ignore[attr-defined]
        job.phase = STRICT_STAGE
        self._advance_strict_stage(job)

    def _advance_strict_stage(self, job: Job) -> None:
        node = self.nodes[job.node] if job.node else self._pick_any_node()
        job.node = node.id
        needs = list(job._strict_stage)  # type: ignore[attr-defined]
        for c in getattr(job, "_strict_children", []):
            res = self._memo[c.raw]
            node.repo.memo_put(c, res)
            node.repo.memo_put(c.unwrap_encode(), res)
            needs.extend(self._deep_object_handles(res))
        if self._fstate is not None:
            self._scrub_resident(node, needs)
        missing = [h for h in needs if not node.repo.contains(h)]
        if missing:
            if self.spans is not None and job.stage_span is None:
                job.stage_span = self.spans.begin(
                    "stage", parent=job.span, job=job.id, n=len(missing))
            job.staging = self._stage_missing(node, missing, job.id)
            if not job.staging:
                self._enqueue_strictify(job)
        else:
            self._enqueue_strictify(job)

    def _enqueue_strictify(self, job: Job) -> None:
        node = self.nodes[job.node]
        if job.whnf.content_type == BLOB and job.whnf.is_data():
            self._finalize(job, job.whnf.as_object())
            return
        item = WorkItem(job.id, job.epoch, None, strict_target=job.whnf)
        job.phase = RUNNING
        job.started_at = self.clock.now()
        if self.spans is not None:
            self.spans.end(job.stage_span)
            job.stage_span = None
            job.run_span = self.spans.begin(
                "run", parent=job.span, job=job.id, node=job.node,
                op="strictify")
        if self.trace is not None:
            self.trace.emit("job_start", job=job.id, node=job.node,
                            epoch=job.epoch, op="strictify", internal=0)
        self._arm_speculation(job)  # strictify ops can straggle too
        node.queue.put(item)

    # ----------------------------------------------------------- finalize
    def _finalize(self, job: Job, result: Handle) -> None:
        job.result = result
        job.phase = DONE
        if self.trace is not None:
            self.trace.emit("job_finish", job=job.id, node=job.node,
                            result=result.raw.hex())
        m = self.metrics
        if m is not None:
            key = ("latency", job.tenant)
            h = self._instruments.get(key)
            if h is None:
                tl = {} if job.tenant is None else {"tenant": job.tenant}
                h = self._instruments[key] = m.histogram(
                    "job_latency_s", **tl)
            h.observe(self.clock.now() - job._metric_t0)
            self._count_job(job, "finished")
        self._end_job_spans(job, "ok")
        self._cancel_speculation(job)
        self._memo.setdefault(job.encode.raw, result)
        if job.node:
            repo = self.nodes[job.node].repo
            repo.memo_put(job.encode, result)
            repo.memo_put(job.encode.unwrap_encode(), result)
        if result.is_data() and not result.is_literal:
            self._lineage.setdefault(result.content_key(), job.encode)
        for f in job.futures:
            f.set(result)
        for cb in job.on_complete:
            cb(job)
        for pid in job.parents:
            self._child_resolved(pid, job.encode)

    def _notify_parents_exc(self, job: Job, exc: BaseException) -> None:
        for pid in job.parents:
            parent = self._jobs.get(pid)
            if parent and parent.phase != DONE:
                for f in parent.futures:
                    f.set_exception(exc)
                parent.phase = DONE
                if self.trace is not None:
                    self.trace.emit("job_fail", job=parent.id,
                                    error=type(exc).__name__)
                self._count_job(parent, "failed")
                self._end_job_spans(parent, "fail")
                self._cancel_speculation(parent)
                self._run_on_fail(parent, exc)
                self._notify_parents_exc(parent, exc)

    # ----------------------------------------------------------- stepneeds
    def _step_needs(self, thunk: Handle):
        """(stage handles, child encodes, memo pairs) for one reduction."""
        interp = thunk.interp
        if interp == IDENTIFICATION:
            return [], [], []
        if interp == SELECTION:
            pair_h = thunk.unwrap_thunk()
            needs = [pair_h]
            pair = self._tree_children(pair_h)
            if pair is None:
                raise MissingData(pair_h)
            target, idx = pair
            if not idx.is_literal:
                needs.append(idx)
            children: list[Handle] = []
            memo_pairs: list[tuple] = []
            if target.is_encode():
                res = self._memo.get(target.raw)
                if res is None:
                    return needs, [target], []
                memo_pairs.append((target, res))
                target = res
            if target.is_thunk():
                res = self._memo.get(target.shallow().raw)
                if res is None:
                    return needs, [target.shallow()], []
                memo_pairs.append((target.shallow(), res))
                target = res
            if not target.is_literal:
                needs.append(target)  # the node itself; children stay put
            return needs, children, memo_pairs
        if interp == APPLICATION:
            defn = thunk.unwrap_thunk()
            needs = []
            children = []
            memo_pairs = []
            stack = [defn]
            seen = set()
            while stack:
                h = stack.pop()
                if h.raw in seen or h.is_literal:
                    continue
                seen.add(h.raw)
                if h.is_encode():
                    res = self._memo.get(h.raw)
                    if res is None:
                        children.append(h)
                    else:
                        memo_pairs.append((h, res))
                        stack.append(res)
                    continue
                if h.is_thunk() or h.is_ref():
                    continue  # lazy / metadata-only
                needs.append(h)
                if h.content_type == TREE:
                    kids = self._tree_children(h)
                    if kids is None:
                        raise MissingData(h)
                    stack.extend(kids)
            return needs, children, memo_pairs
        raise ValueError(f"not a thunk: {thunk!r}")

    # ---------------------------------------------------------- placement
    def _place(self, job: Optional[Job], needs: list[Handle]) -> Node:
        candidates = self.worker_nodes()
        if not candidates:
            raise RuntimeError("no live worker nodes")
        if self.placement == "random":
            return self.rng.choice(candidates)
        # One pass over `needs`: size + live replica sites per handle, via
        # the location index — O(needs) walks of each handle's (few)
        # replica sites, no repository scans.
        infos: list[tuple[int, list[str]]] = []
        seen: set[bytes] = set()
        for h in needs:
            if h.is_literal or h.raw in seen:
                continue
            seen.add(h.raw)
            size = h.size if h.content_type == BLOB else 32 * h.size
            sites = [name for name in self._locs.nodes_for(h.content_key())
                     if (n := self.nodes.get(name)) is not None
                     and n.alive and n.repo.contains(h)]
            infos.append((size, sites))
        if self.placement == "bytes":
            return self._place_bytes_missing(candidates, infos)
        return self._place_seconds_to_stage(candidates, infos)

    def _place_bytes_missing(self, candidates: list[Node],
                             infos: list) -> Node:
        """PR 1's cost model, kept as the ``placement="bytes"`` ablation:
        run where the fewest bytes of `needs` are missing."""
        total = 0
        credit: dict[str, int] = {}
        for size, sites in infos:
            total += size
            for name in sites:
                if self.nodes[name].n_workers > 0:
                    credit[name] = credit.get(name, 0) + size
        best, best_cost = None, None
        for n in candidates:
            cost = total - credit.get(n.id, 0)
            cost += n.queue.qsize() * 16  # mild load-balancing tiebreak
            if best_cost is None or cost < best_cost:
                best, best_cost = n, cost
        return best

    def _place_seconds_to_stage(self, candidates: list[Node],
                                infos: list) -> Node:
        """Score each candidate by estimated *seconds* until the job's
        minimum repository is resident there, not bytes missing:

        * per missing handle, pick the cheapest live replica source —
          NIC backlog already queued at that source (TransferManager
          bytes-awaiting-serialization) + link latency + serialized time;
        * transfers from distinct sources ride distinct link workers in
          parallel, so the node's staging cost is the max over sources,
          with per-link queued plans charging their pipelined latency;
        * a µs-scale run-queue term breaks exact ties toward idle nodes.

        Bytes-missing cannot distinguish a near congested node from a far
        one behind an idle fat pipe; this model can.
        """
        src_backlog, link_depth = self._xfer.backlog_snapshot()
        # Fault-aware staging costs: degraded links stretch serialized
        # time, a downed link is near-infinite (retries, maybe failover).
        # fstate is None in no-fault runs, leaving the float math untouched.
        fstate = self._fstate
        best, best_cost = None, None
        for n in candidates:
            per_src: dict[str, int] = {}
            for size, sites in infos:
                if n.id in sites:
                    continue  # already resident: free
                src, src_cost = None, None
                for s in sites:
                    link = self.network.link(s, n.id)
                    c = (link.serialized_s(src_backlog.get(s, 0) + size)
                         + link.latency_s)
                    if fstate is not None:
                        c *= fstate.bandwidth_factor(s, n.id)
                        if fstate.link_down(s, n.id):
                            c += 1e6
                    if src_cost is None or c < src_cost:
                        src, src_cost = s, c
                if src is None:
                    continue  # no live replica: recomputed, not staged
                per_src[src] = per_src.get(src, 0) + size
            cost = 0.0
            for s, nbytes in per_src.items():
                link = self.network.link(s, n.id)
                t = (link.serialized_s(src_backlog.get(s, 0) + nbytes)
                     + link.latency_s * (1 + link_depth.get((s, n.id), 0)))
                if fstate is not None:
                    t *= fstate.bandwidth_factor(s, n.id)
                    if fstate.link_down(s, n.id):
                        t += 1e6
                if t > cost:
                    cost = t
            cost += n.queue.qsize() * 1e-6
            if best_cost is None or cost < best_cost:
                best, best_cost = n, cost
        return best

    def _pick_any_node(self) -> Node:
        return self.worker_nodes()[0]

    # ---------------------------------------------------------- transfers
    def _stage_missing(self, node: Node, handles: list[Handle],
                       job_id: Optional[int] = None, *,
                       recompute: bool = True) -> set:
        """Coalesce ``handles`` into per-source batched transfers to
        ``node``, joining any transfer already in flight (cross-job dedup).

        Returns the set of handle raws now pending for ``job_id``.  With
        ``job_id=None`` (prefetch) transfers are registered waiterless and
        missing sources are skipped instead of recomputed.
        """
        batches: dict[str, list] = {}
        pending: set[bytes] = set()
        waiters = [job_id] if job_id is not None else []
        tr = self.trace
        for h in handles:
            if node.repo.contains(h):
                continue
            key = (node.id, h.raw)
            size = h.size if h.content_type == BLOB else 32 * h.size
            if key in self._inflight:  # shared wire transfer: join it
                self._inflight[key].extend(waiters)
                pending.add(h.raw)
                if tr is not None:
                    tr.emit("stage_request", job=job_id, dst=node.id,
                            key=h.content_key().hex(), nbytes=size,
                            action="join")
                continue
            src = self._find_source_name(h, exclude=node.id)
            payload = None
            while src is not None:
                payload = self._read_source(src, h)
                if payload is not None:
                    break
                src = self._find_source_name(h, exclude=node.id)
            if src is None:
                if recompute:
                    pending.add(h.raw)
                    if tr is not None:
                        tr.emit("stage_request", job=job_id, dst=node.id,
                                key=h.content_key().hex(), nbytes=size,
                                action="recompute")
                    self._recompute_for(node, h, job_id)
                continue
            self._inflight[key] = list(waiters)
            pending.add(h.raw)
            if tr is not None:
                tr.emit("stage_request", job=job_id, dst=node.id,
                        key=h.content_key().hex(), nbytes=size,
                        action="enqueue", src=src)
            batches.setdefault(src, []).append((h, payload, size))
        sp = None
        if self.spans is not None and job_id is not None:
            j = self._jobs.get(job_id)
            if j is not None:
                sp = j.stage_span if j.stage_span is not None else j.span
        for src, items in batches.items():
            self._xfer.submit(src, node.id, items, span_parent=sp)
        return pending

    def _maybe_prefetch(self, needs: list[Handle],
                        node_id: Optional[str] = None,
                        children: Optional[list] = None) -> None:
        """Job is blocked on children: start moving its already-known needs
        toward the (tentative) placement so data motion overlaps compute.
        With ``prefetch_depth > 1`` the pending child Encodes' own
        definitions are followed ``depth - 1`` levels down and *their*
        known needs staged too (depth 1 = exactly the seed behaviour).
        Externalized locality mode only — the ablations must keep their
        seed behaviour — and never toward a dead node."""
        if not self.prefetch or self.io_mode != "external" or self.placement == "random":
            return
        cands = [h for h in needs if not h.is_literal]
        if self.prefetch_depth > 1 and children:
            cands.extend(self._deeper_needs(children, self.prefetch_depth - 1))
        if not cands:
            return
        if node_id is not None:
            node = self.nodes.get(node_id)
        else:
            try:
                node = self._place(None, cands)
            except RuntimeError:
                return
        if node is None or not node.alive or node.n_workers == 0:
            return
        if self.trace is not None:
            self.trace.emit("prefetch", node=node.id, n=len(cands))
        self._stage_missing(node, cands, None, recompute=False)

    def _deeper_needs(self, children: list, depth: int) -> list[Handle]:
        """Known data needs of pending child Encodes, ``depth`` levels of
        definitions down.  Best-effort by construction: a definition whose
        trees aren't readable yet contributes nothing (no recompute, no
        failure) — prefetch only ever moves content that already exists."""
        out: list[Handle] = []
        frontier = list(children)
        seen: set[bytes] = set()
        for _ in range(depth):
            nxt: list[Handle] = []
            for enc in frontier:
                if enc.raw in seen or not enc.is_encode():
                    continue
                seen.add(enc.raw)
                if self._memo.get(enc.raw) is not None:
                    continue  # resolved: its result is staged, not prefetched
                try:
                    needs, kids, _ = self._step_needs(enc.unwrap_encode())
                except (MissingData, ValueError):
                    continue
                out.extend(h for h in needs if not h.is_literal)
                nxt.extend(kids)
            frontier = nxt
            if not frontier:
                break
        return out

    def _read_source(self, src: str, h: Handle):
        """Read a transfer payload from a source replica, verified under
        the fault plane.  A rotten copy is quarantined and a vanished one
        forgotten — both return None so the caller moves to the next
        replica.  Scheduler thread only (mutates the location index)."""
        repo = self.nodes[src].repo
        try:
            return repo.raw_payload(h)
        except CorruptData:
            repo.quarantine(h)
            self._locs.discard(h.content_key(), src)
            if self.trace is not None:
                self.trace.emit("quarantine", node=src,
                                key=h.content_key().hex())
        except MissingData:
            self._locs.discard(h.content_key(), src)
        return None

    def _recompute_for(self, node: Node, h: Handle, job_id: Optional[int]) -> None:
        """No replica survives: recompute from lineage (determinism!).

        The decision is *deferred* to a scheduler event: this runs inside
        ``_stage_missing``, before the caller has assigned ``job.staging``
        — a synchronous no-lineage give-up here would phase-guard past the
        very waiter it should fail, leaving it staged forever."""
        key = (node.id, h.raw)
        waiters = [job_id] if job_id is not None else []
        self._inflight.setdefault(key, []).extend(waiters)
        self._events.put(("recompute", key, job_id))

    def _spawn_recompute(self, node: Node, h: Handle, key: tuple,
                         parent: Optional[int] = None) -> None:
        """Re-derive ``h`` from its producing Encode — any blob lost to a
        crash, not just tail-call definitions.  No lineage (an input the
        client never re-put) or a failing recompute gives up attributed:
        waiters get DataUnrecoverable rather than hanging to a timeout."""
        enc = self._lineage.get(h.content_key())
        if enc is None:
            self._give_up(key, h, "unrecoverable")
            return
        jid = next(self._ids)
        rejob = Job(jid, enc, enc.unwrap_encode(), enc.interp == STRICT, ignore_memo=True)
        rejob._metric_t0 = self.clock.now()
        self._count_job(rejob, "submitted")
        if self.spans is not None:
            pj = self._jobs.get(parent) if parent is not None else None
            rejob.span = self.spans.begin(
                "job", parent=pj.span if pj is not None else None,
                job=jid, recompute=True)
        if self.trace is not None:
            self.trace.emit("job_submit", job=jid, encode=enc.raw.hex(),
                            strict=rejob.strict, parent=parent,
                            recompute=True)
        rejob.on_complete.append(
            lambda _j, node=node, h=h, key=key: self._retry_transfer(node, h, key)
        )
        rejob.on_fail.append(
            lambda _j, _e, h=h, key=key: self._give_up(key, h, "recompute_failed")
        )
        self._jobs[jid] = rejob
        self._advance(rejob)

    def _retry_transfer(self, node: Node, h: Handle, key: tuple) -> None:
        """A recompute finished (the content exists *somewhere* again):
        restage toward the waiting node.  Attempts share the same capped
        per-(node, key) budget as fault retries, so a recompute loop whose
        output keeps dying cannot spin forever."""
        if key not in self._inflight:
            self._retry.pop(key, None)
            self._retry_src.pop(key, None)
            return
        if not node.alive:
            self._inflight.pop(key, None)
            self._retry.pop(key, None)
            self._retry_src.pop(key, None)
            return
        if node.repo.contains(h):  # recompute landed on the waiter's node
            self._complete_stage(node.id, h.raw)
            return
        attempts = self._retry.get(key, 0) + 1
        self._retry[key] = attempts
        if attempts > self.transfer_retries:
            self._give_up(key, h, "retry_cap")
            return
        src = self._find_source_name(h, exclude=node.id,
                                     avoid=self._retry_src.get(key),
                                     dst=node.id)
        payload = None
        while src is not None:
            payload = self._read_source(src, h)
            if payload is not None:
                break
            src = self._find_source_name(h, exclude=node.id,
                                         avoid=self._retry_src.get(key),
                                         dst=node.id)
        if src is None:
            # result already evicted again — re-derive once more (the
            # attempts counter above bounds this loop)
            self._spawn_recompute(node, h, key)
            return
        size = h.size if h.content_type == BLOB else 32 * h.size
        if self.trace is not None:
            self.trace.emit("stage_request", job=None, dst=node.id,
                            key=h.content_key().hex(), nbytes=size,
                            action="enqueue", src=src,
                            retry=attempts)
        self._xfer.submit(src, node.id, [(h, payload, size)])

    def _blocking_fetch(self, node: Node, h: Handle) -> None:
        """Internal-I/O mode: the worker performs the fetch while holding
        its slot (this is the starvation conventional platforms suffer).
        The wire choreography is the shared per-handle helper — the same
        one ``transfer_mode="per_handle"`` replays.  Under fault injection
        the fetch retries with the same capped backoff as externalized
        staging — slot-held, so the wasted time is *accounted* as
        starvation, exactly the cost internal I/O pays for faults."""
        if node.repo.contains(h):
            return
        attempts = 0
        last_src: Optional[str] = None
        while True:
            src = self._find_source_name(h, exclude=node.id,
                                         avoid=last_src, dst=node.id)
            if src is None:
                raise MissingData(h)
            size = h.size if h.content_type == BLOB else 32 * h.size
            try:
                payload = self.nodes[src].repo.raw_payload(h)
                status = single_transfer(self.clock, self.network, self.nodes,
                                         src, node.id, h, payload, size,
                                         trace=self.trace, via="blocking",
                                         faults=self._fstate)
                self._account_transfer(1, size)
            except CorruptData:
                # source copy rotted at rest: read verification caught it
                # before any bytes moved — treat like a corrupt delivery
                status = "corrupt"
            if status in ("ok", "dst_dead"):
                return
            if status == "corrupt":
                # scheduler owns quarantine decisions; post, don't mutate
                self._events.put(("source_suspect", src, h.raw))
            last_src = src
            attempts += 1
            if attempts > self.transfer_retries:
                raise TransferFailed(h.content_key().hex(), node.id,
                                     attempts, status)
            self.clock.sleep(min(self.retry_backoff_s * (2 ** (attempts - 1)),
                                 self.retry_backoff_max_s))

    def _account_transfer(self, n_transfers: int, n_bytes: int) -> None:
        self.transfers += n_transfers
        self.bytes_moved += n_bytes
        if self.metrics is not None:
            # incremented in lockstep with the legacy counters, so the
            # metric can never double-count what the trace/accounting saw
            self._m_transfers.inc(n_transfers)
            self._m_bytes.inc(n_bytes)

    # -------------------------------------------------------- node failure
    def _on_node_failed(self, node_id: str) -> None:
        self._locs.drop_node(node_id)
        for job in list(self._jobs.values()):
            if job.phase in (STAGING, RUNNING, STRICT_STAGE) and job.node == node_id:
                job.epoch += 1
                job.staging.clear()
                job.node = None
                if job.phase == STRICT_STAGE or (job.phase == RUNNING and job.whnf is not None):
                    # whnf data may have died with the node; re-run the step
                    job.whnf = None
                job.phase = RESOLVE
                try:
                    self._advance_or_restart(job)
                except Exception as e:  # noqa: BLE001 — this job only
                    self._fail_job(job, e)
        # drop in-flight transfer bookkeeping involving the dead node
        for key in [k for k in self._inflight if k[0] == node_id]:
            self._inflight.pop(key, None)
        for key in [k for k in self._retry if k[0] == node_id]:
            self._retry.pop(key, None)
        for key in [k for k in self._retry_src if k[0] == node_id]:
            self._retry_src.pop(key, None)

    # ------------------------------------------------------ fault schedule
    def _on_fault(self, f) -> None:
        """Apply one schedule entry.  The ``fault`` trace event is emitted
        before the injection's consequences so checkers can order cause
        before effect; ``applied`` records no-op injections (e.g. crashing
        an already-dead node) so every scheduled fault is accounted."""
        applied = True
        extra: dict = {}
        node = self.nodes.get(f.node) if f.node is not None else None
        if f.kind == "crash":
            applied = (node is not None and node.alive
                       and node is not self.client)
        elif f.kind == "join":
            applied = node is None or not node.alive
        elif f.kind == "corrupt_blob":
            key = (node.repo.corrupt_nth_blob(f.index)
                   if node is not None and node.alive else None)
            applied = key is not None
            if key is not None:
                extra["key"] = key.hex()
        if self.trace is not None:
            self.trace.emit("fault", fault=f.kind, node=f.node, src=f.src,
                            dst=f.dst, count=f.count, factor=f.factor,
                            applied=applied, **extra)
        if not applied:
            return
        if f.kind == "crash":
            node.kill()
            self._on_node_failed(f.node)
        elif f.kind == "join":
            self._join_node(f.node, f.workers)
        elif f.kind == "link_down":
            self._fstate.set_link_down(f.src, f.dst, True)
        elif f.kind == "link_up":
            self._fstate.set_link_down(f.src, f.dst, False)
        elif f.kind == "degrade":
            self._fstate.set_factor(f.src, f.dst, f.factor)
        elif f.kind == "degrade_end":
            self._fstate.set_factor(f.src, f.dst, None)
        elif f.kind == "drop":
            self._fstate.add_drops(f.src, f.dst, f.count)
        elif f.kind == "corrupt_wire":
            self._fstate.add_corrupts(f.src, f.dst, f.count)

    def _join_node(self, node_id: str, workers: int = 0) -> None:
        """(Re)join a node.  A crashed node revives in place — empty store
        (``kill`` wiped it), the same parked worker threads — and must be
        rewired: listeners lived on the repo object kill() replaced.  An
        unknown id becomes a brand-new worker node."""
        node = self.nodes.get(node_id)
        if node is not None:
            node.revive()
            self._wire_node(node_id, node)
            if self.trace is not None:
                self.trace.emit("node_join", node=node_id, fresh=False)
            return
        node = Node(node_id, workers or self._workers_per_node,
                    self._node_ram, clock=self.clock, trace=self.trace,
                    compute_model=self.compute_model)
        self.nodes[node_id] = node
        self._wire_node(node_id, node)
        node.start(self._on_worker_done, fetcher=self._blocking_fetch)
        if self.trace is not None:
            self.trace.emit("node_join", node=node_id, fresh=True)

    # ---------------------------------------------------- cancel / deadline
    def _on_cancel(self, fut: Future) -> None:
        self._terminate_future(fut, CancelledError("future cancelled"),
                               "cancel")

    def _on_deadline(self, fut: Future) -> None:
        self._terminate_future(
            fut, DeadlineExceeded("job deadline exceeded"), "deadline")

    def _terminate_future(self, fut: Future, exc: BaseException,
                          reason: str) -> None:
        """Fail one future; if that leaves its job with no other waiter
        (no future, no parent), abort the job and prune orphaned child
        submissions."""
        if fut.done():
            return
        fut.set_exception(exc)
        jid = getattr(fut, "_jid", None)
        job = self._jobs.get(jid) if jid is not None else None
        if job is None or job.phase == DONE:
            return
        if fut in job.futures:
            job.futures.remove(fut)
        if not job.futures and not job.parents:
            self._abort_job(job, reason, exc)

    def _abort_job(self, job: Job, reason: str, exc: BaseException) -> None:
        """Tear one job down cleanly: any straggler futures fail, in-flight
        stage registrations are released, and children nobody else waits on
        are aborted recursively."""
        job.phase = DONE
        if self.trace is not None:
            self.trace.emit("job_cancel", job=job.id, reason=reason)
        self._count_job(job, "cancelled")
        self._end_job_spans(job, "cancel")
        self._cancel_speculation(job)
        for f in job.futures:
            f.set_exception(exc)
        self._run_on_fail(job, exc)
        for key, waiters in list(self._inflight.items()):
            if job.id in waiters:
                waiters[:] = [w for w in waiters if w != job.id]
        for raw in list(job.pending_children):
            cid = self._by_encode.get(raw)
            child = self._jobs.get(cid) if cid is not None else None
            if child is None or child.phase == DONE:
                continue
            if job.id in child.parents:
                child.parents.remove(job.id)
            if not child.parents and not child.futures:
                self._abort_job(child, reason, exc)

    # ----------------------------------------------------------- straggler
    def _on_tick(self, jid: int) -> None:
        """One job's speculation deadline fired: duplicate its run if it is
        still (over)due.  Ticks are job-targeted — O(1) per deadline, not a
        rescan of the ever-growing job table."""
        job = self._jobs.get(jid)
        if (job is None or job.phase != RUNNING or job.duplicated
                or job.thunk is None):
            return
        if self.trace is not None:
            self.trace.emit("spec_wakeup", job=jid)
        now = self.clock.now()
        # 1e-9 slack: the wakeup fires at exactly started_at + after on a
        # virtual clock, where float round-trip must still count as due.
        if now - job.started_at < self.speculate_after_s - 1e-9:
            return  # re-placed since armed; the newer run has its own timer
        others = [n for n in self.worker_nodes() if n.id != job.node]
        if not others:
            # no duplicate target *yet*: poll again, like the seed's
            # quarter-period ticker
            job.spec_timer = self.clock.call_at(
                now + self.speculate_after_s / 4,
                lambda jid=jid: self._events.put(("tick", jid)))
            return
        job.duplicated = True
        dup = self.rng.choice(others)
        if self.trace is not None:
            self.trace.emit("spec_duplicate", job=jid, node=dup.id)
        needs, children, memo_pairs = self._step_needs(job.thunk)
        if any(self._memo.get(c.raw) is None for c in children):
            return
        for enc in children:
            res = self._memo[enc.raw]
            memo_pairs.append((enc, res))
            needs.extend(self._deep_object_handles(res))
        for enc, res in memo_pairs:
            dup.repo.memo_put(enc, res)
            dup.repo.memo_put(enc.unwrap_encode(), res)
        missing = [h for h in needs if not dup.repo.contains(h)]
        for h in missing:
            src = self._find_source_name(h, exclude=dup.id)
            if src is not None:
                self.nodes[src].repo.export(h, dup.repo)
        dup.queue.put(WorkItem(job.id, job.epoch, job.thunk))

    # ------------------------------------------------------------- lookups
    def _find_source_name(self, h: Handle, exclude: Optional[str] = None, *,
                          avoid: Optional[str] = None,
                          dst: Optional[str] = None) -> Optional[str]:
        """Best live replica holder for ``h`` (index order, deterministic).

        ``avoid`` demotes (without excluding) the source a failed attempt
        used; with fault state present and ``dst`` given, sources behind a
        downed link to ``dst`` are demoted too — both still serve as a
        last resort, since a flaky replica beats none.  No-fault callers
        see the exact pre-fault behaviour."""
        if h.is_literal:
            return "client"
        key = h.content_key()
        demoted: list[str] = []
        for name in self._locs.nodes_for(key):
            if name == exclude:
                continue
            n = self.nodes.get(name)
            if n is None or not n.alive or not n.repo.contains(h):
                continue
            if name == avoid or (self._fstate is not None and dst is not None
                                 and self._fstate.link_down(name, dst)):
                demoted.append(name)
                continue
            return name
        if demoted:
            return demoted[0]
        # Fallback scan: covers content that raced the index (and repairs it)
        for name, n in self.nodes.items():
            if name != exclude and n.alive and n.repo.contains(h):
                self._locs.add(key, name)
                return name
        return None

    def _tree_children(self, h: Handle) -> Optional[tuple]:
        src = self._find_source_name(h)
        if src is None:
            return None
        try:
            return self.nodes[src].repo.get_tree(h)
        except MissingData:
            return None

    def _deep_object_handles(self, handle: Handle) -> list[Handle]:
        """All content handles reachable as Objects (for staging a strict
        child result) — the shared closure walker over the *cluster* memo
        table and cross-node tree lookup, cached in ``self._reach``."""
        return list(walk_object_closure(
            handle, lambda h: self._memo.get(h.raw),
            self._tree_children, self._reach))

    def _deep_size(self, handle: Handle) -> int:
        return sum(h.size if h.content_type == BLOB else 32 * h.size
                   for h in self._deep_object_handles(handle))

    # -------------------------------------------------------- worker event
    def _on_worker_done(self, node: Node, item: WorkItem, result) -> None:
        self._events.put(("ran", node, item, result))
