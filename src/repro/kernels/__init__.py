"""Pallas TPU kernels for the compute hot-spots (flash attention, decode
attention, fused rmsnorm, Mamba2 SSD scan) with jnp oracles in ref.py and
platform dispatch in ops.py.  Validated in interpret mode on CPU."""
