"""Model zoo: all assigned architectures as pure-function families."""
from .base import (
    ModelConfig,
    ParamSpec,
    abstract_params,
    ce_loss,
    count_params,
    init_params,
    param_pspecs,
    param_shardings,
    ps,
)
from .registry import FAMILIES, FamilyOps, concrete_batch, input_specs, loss_mask, ops_for

__all__ = [
    "ModelConfig", "ParamSpec", "ps", "abstract_params", "init_params",
    "param_pspecs", "param_shardings", "count_params", "ce_loss",
    "FAMILIES", "FamilyOps", "ops_for", "input_specs", "concrete_batch",
    "loss_mask",
]
