"""Integration tests for the Fixpoint cluster runtime."""
import struct
import time

import pytest

from repro.core import Handle, Repository
from repro.core.stdlib import combination
from repro.runtime import Cluster, Link, Network


def _i(v: int) -> Handle:
    return Handle.blob(v.to_bytes(8, "little", signed=True))


def _int_of(repo: Repository, h: Handle) -> int:
    return int.from_bytes(repo.get_blob(h), "little", signed=True)


def make_cluster(**kw) -> Cluster:
    kw.setdefault("n_nodes", 3)
    kw.setdefault("workers_per_node", 2)
    kw.setdefault("network", Network(Link(latency_s=0.0005, gbps=10)))
    return Cluster(**kw)


class TestClusterBasics:
    def test_simple_add(self):
        c = make_cluster()
        try:
            th = combination(c.client_repo, "add", _i(20), _i(22))
            out = c.evaluate(th.strict(), timeout=30)
            repo = c.fetch_result(out)
            assert _int_of(repo, out) == 42
        finally:
            c.shutdown()

    def test_tail_call_chain_single_submission(self):
        c = make_cluster()
        try:
            th = combination(c.client_repo, "inc_chain", _i(0), _i(100))
            out = c.evaluate(th.strict(), timeout=60)
            repo = c.fetch_result(out)
            assert _int_of(repo, out) == 100
        finally:
            c.shutdown()

    def test_parallel_fanout_fib(self):
        c = make_cluster()
        try:
            th = combination(c.client_repo, "fib", _i(12))
            out = c.evaluate(th.strict(), timeout=60)
            repo = c.fetch_result(out)
            assert _int_of(repo, out) == 144
        finally:
            c.shutdown()

    def test_memoized_resubmission_is_instant(self):
        c = make_cluster()
        try:
            th = combination(c.client_repo, "add", _i(1), _i(2))
            c.evaluate(th.strict(), timeout=30)
            t0 = time.perf_counter()
            c.evaluate(th.strict(), timeout=30)
            assert time.perf_counter() - t0 < 0.05  # memo hit, no re-execution
        finally:
            c.shutdown()

    def test_lazy_branch_not_fetched(self):
        """fig 2: the untaken branch's minimum repository never moves."""
        c = make_cluster()
        try:
            repo = c.client_repo
            big = repo.put_blob(b"B" * 500_000)  # lives only on client
            bomb = combination(repo, "identity", big)
            good = combination(repo, "add", _i(5), _i(6))
            th = combination(repo, "fix_if", _i(1), good, bomb)
            out = c.evaluate(th.strict(), timeout=30)
            assert _int_of(c.fetch_result(out), out) == 11
            # the 500 kB blob never left the client
            for n in c.worker_nodes():
                assert not n.repo.contains(big)
        finally:
            c.shutdown()

    def test_selection_moves_node_not_children(self):
        """fig 4 / B+-tree property: selecting a child of a Tree ships the
        32-byte-per-child node, not the children's data."""
        c = make_cluster()
        try:
            repo = c.client_repo
            kids = [repo.put_blob(bytes([i]) * 100_000) for i in range(8)]
            tree = repo.put_tree(kids)
            pair = repo.put_tree([tree, repo.put_blob(struct.pack("<q", 2))])
            sel = pair.selection_of()
            out = c.evaluate(sel.shallow(), timeout=30)
            assert out.is_ref() and out.size == 100_000
            # selection ran without moving any 100 kB child
            moved = sum(1 for n in c.worker_nodes() for k in kids if n.repo.contains(k))
            assert moved == 0
        finally:
            c.shutdown()


class TestPlacement:
    def test_locality_places_near_data(self):
        c = make_cluster(n_nodes=4)
        try:
            # park a large shard on n2
            shard = Handle.blob(b"x" * 1_000_000)
            c.nodes["n2"].repo.put_blob(b"x" * 1_000_000)
            needle = Handle.blob(b"xx")
            th = combination(c.client_repo, "count_string", shard, needle)
            out = c.evaluate(th.strict(), timeout=30)
            assert _int_of(c.fetch_result(out), out) == 500_000
            assert c.nodes["n2"].jobs_run >= 1  # ran where the data lives
            assert c.bytes_moved < 10_000  # the shard did not move
        finally:
            c.shutdown()

    def test_random_placement_moves_data(self):
        c = make_cluster(n_nodes=4, placement="random", seed=7)
        try:
            c.nodes["n2"].repo.put_blob(b"y" * 1_000_000)
            shard = Handle.blob(b"y" * 1_000_000)
            th = combination(c.client_repo, "count_string", shard, Handle.blob(b"yy"))
            out = c.evaluate(th.strict(), timeout=30)
            assert _int_of(c.fetch_result(out), out) == 500_000
        finally:
            c.shutdown()


class TestInternalIO:
    def test_internal_mode_starves_workers(self):
        net = Network(Link(latency_s=0.02, gbps=10))
        c = make_cluster(n_nodes=2, io_mode="internal", network=net)
        try:
            c.nodes["n0"].repo.put_blob(b"z" * 100_000)
            shard = Handle.blob(b"z" * 100_000)
            # force remote work: submit several, some land off-node
            outs = []
            for i in range(8):
                th = combination(c.client_repo, "count_string", shard,
                                 Handle.blob(bytes([i % 3]) + b"zz"))
                outs.append(c.submit(th.strict()))
            for f in outs:
                f.result(timeout=30)
            starved = sum(n.starved_ns for n in c.worker_nodes())
            assert starved > 0  # slots were held during fetches
        finally:
            c.shutdown()


class TestFaultTolerance:
    def test_node_failure_reschedules(self):
        c = make_cluster(n_nodes=3)
        try:
            th = combination(c.client_repo, "inc_chain", _i(0), _i(50))
            fut = c.submit(th.strict())
            time.sleep(0.02)
            c.kill_node("n0")
            out = fut.result(timeout=60)
            assert _int_of(c.fetch_result(out), out) == 50
        finally:
            c.shutdown()

    def test_lost_data_recomputed_from_lineage(self):
        """Computational GC (paper §6): results can be deleted and
        deterministically re-derived from their producing Encode."""
        c = make_cluster(n_nodes=3)
        try:
            repo = c.client_repo
            corpus = repo.put_blob(bytes(range(256)) * 1000)
            sl = combination(repo, "slice_blob", corpus, _i(1000), _i(500))
            out1 = c.evaluate(sl.strict(), timeout=30)
            # wipe the result from every node that holds it
            for n in c.worker_nodes():
                n.repo._blobs.pop(out1.content_key(), None)
            # a consumer needing the slice forces recompute-from-lineage
            th = combination(repo, "count_string", out1.as_object(), Handle.blob(bytes([232])))
            out2 = c.evaluate(th.strict(), timeout=30)
            assert _int_of(c.fetch_result(out2), out2) >= 1
        finally:
            c.shutdown()

    def test_straggler_duplicate_execution_safe(self):
        c = make_cluster(n_nodes=3, speculate_after_s=0.05)
        try:
            th = combination(c.client_repo, "fib", _i(10))
            out = c.evaluate(th.strict(), timeout=60)
            assert _int_of(c.fetch_result(out), out) == 55
        finally:
            c.shutdown()


class TestDeterminismProperties:
    def test_same_job_same_result_across_clusters(self):
        results = []
        for seed in (0, 1):
            c = make_cluster(n_nodes=2 + seed, seed=seed)
            try:
                th = combination(c.client_repo, "fib", _i(9))
                out = c.evaluate(th.strict(), timeout=60)
                results.append(_int_of(c.fetch_result(out), out))
            finally:
                c.shutdown()
        assert results[0] == results[1] == 34
