"""Serve a small LM with batched requests + content-addressed prefix cache.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    import sys

    sys.argv = [sys.argv[0], "--arch", "qwen3_4b", "--requests", "6",
                "--prompt-len", "24", "--max-new", "8", "--batch", "3"]
    main()
