"""Fixpoint runtime: multi-node execution engine for Fix programs."""
from .clock import Clock, Timer, VirtualClock, WallClock
from .cluster import Cluster, Future, Link, Network
from .node import Node, WorkItem
from .transfers import LocationIndex, TransferManager, TransferPlan

__all__ = ["Clock", "Cluster", "Future", "Link", "Network", "Node",
           "Timer", "VirtualClock", "WallClock", "WorkItem",
           "LocationIndex", "TransferManager", "TransferPlan"]
