"""Tests for the batched transfer scheduler, location index and prefetch."""
import time

import pytest

from repro.core import Handle, Repository
from repro.core.stdlib import combination
from repro.runtime import Cluster, Link, Network


def _i(v: int) -> Handle:
    return Handle.blob(v.to_bytes(8, "little", signed=True))


def _int_of(repo: Repository, h: Handle) -> int:
    return int.from_bytes(repo.get_blob(h), "little", signed=True)


def _staging_thunk(c: Cluster, n_inputs: int = 16, size: int = 4096,
                   tag: int = 0) -> Handle:
    """A checksum_tree job whose inputs (a tree of blobs) live on s0."""
    repo = c.nodes["s0"].repo
    blobs = [repo.put_blob(bytes([tag % 251, i % 251]) + b"x" * (size - 2))
             for i in range(n_inputs)]
    tree = repo.put_tree(blobs)
    return combination(c.client_repo, "checksum_tree", tree)


class TestBatching:
    def test_batching_collapses_transfers_same_bytes(self):
        """N same-link transfers coalesce into one TransferPlan: transfer
        count drops, bytes on the wire are identical, result unchanged."""
        results = {}
        for mode in ("per_handle", "batched"):
            c = Cluster(n_nodes=1, workers_per_node=2, storage_nodes=("s0",),
                        network=Network(Link(latency_s=0.001, gbps=10)),
                        transfer_mode=mode)
            try:
                th = _staging_thunk(c, n_inputs=16)
                out = c.evaluate(th.strict(), timeout=30)
                val = _int_of(c.fetch_result(out), out)
                results[mode] = (val, c.transfers, c.bytes_moved)
            finally:
                c.shutdown()
        val_ph, tx_ph, by_ph = results["per_handle"]
        val_b, tx_b, by_b = results["batched"]
        assert val_b == val_ph
        assert by_b == by_ph            # same bytes moved
        assert tx_ph >= 17              # inputs tree + 16 blobs, one each
        assert tx_b < tx_ph
        assert tx_b <= 2                # one plan from s0, one from client

    def test_cross_job_dedup_shares_wire_transfer(self):
        """Two jobs staging the same blob to the same node join one
        in-flight wire transfer instead of fetching twice."""
        # slow link: the 500 kB transfer is still in flight when job 2 stages
        c = Cluster(n_nodes=1, workers_per_node=2, storage_nodes=("s0",),
                    network=Network(Link(latency_s=0.02, gbps=0.1)))
        try:
            payload = b"D" * 500_000
            blob = c.nodes["s0"].repo.put_blob(payload)
            th1 = combination(c.client_repo, "count_string", blob,
                              Handle.blob(b"DD"))
            th2 = combination(c.client_repo, "slice_blob", blob, _i(0), _i(8))
            f1 = c.submit(th1.strict())
            f2 = c.submit(th2.strict())
            f1.result(timeout=60)
            f2.result(timeout=60)
            # blob once (500 kB) + two small def trees; far below 2 blobs
            assert c.bytes_moved < 2 * len(payload)
        finally:
            c.shutdown()


class TestLocationIndex:
    def test_index_tracks_puts_and_kills(self):
        c = Cluster(n_nodes=3, workers_per_node=1)
        try:
            payload = b"Z" * 100_000
            h = c.nodes["n1"].repo.put_blob(payload)
            assert c._locs.nodes_for(h.content_key()) == ("n1",)
            assert c._find_source_name(h) == "n1"
            c.kill_node("n1")
            # dead node is excluded immediately (alive flag), and the index
            # entry is dropped once the scheduler processes the failure
            assert c._find_source_name(h) is None
            deadline = time.monotonic() + 5
            while c._locs.nodes_for(h.content_key()) and time.monotonic() < deadline:
                time.sleep(0.01)
            assert c._locs.nodes_for(h.content_key()) == ()
            # a new replica elsewhere re-populates via the put listener
            c.nodes["n2"].repo.put_blob(payload)
            assert c._find_source_name(h) == "n2"
        finally:
            c.shutdown()

    def test_index_survives_direct_eviction(self):
        """Index entries are hints: data wiped behind the scheduler's back
        must not produce a phantom source."""
        c = Cluster(n_nodes=2, workers_per_node=1)
        try:
            payload = b"E" * 50_000
            h = c.nodes["n0"].repo.put_blob(payload)
            c.nodes["n0"].repo._blobs.pop(h.content_key(), None)
            assert c._find_source_name(h) is None
        finally:
            c.shutdown()


class TestPrefetch:
    def _child_blocked_thunk(self, c: Cluster, payload: bytes) -> Handle:
        """count_string over a shard on s0 where the needle is a child
        Encode — the job waits on the child while the shard prefetches."""
        shard = c.nodes["s0"].repo.put_blob(payload)
        needle = combination(c.client_repo, "slice_blob",
                             Handle.blob(b"DDDD"), _i(0), _i(2))
        return combination(c.client_repo, "count_string", shard,
                           needle.strict())

    def test_prefetch_parity_with_disabled(self):
        """Prefetch overlaps child compute with staging but must not move
        extra bytes or change the result (in-flight dedup)."""
        results = {}
        payload = b"D" * 400_000
        for pf in (True, False):
            c = Cluster(n_nodes=1, workers_per_node=2, storage_nodes=("s0",),
                        network=Network(Link(latency_s=0.002, gbps=1.0)),
                        prefetch=pf)
            try:
                th = self._child_blocked_thunk(c, payload)
                out = c.evaluate(th.strict(), timeout=60)
                val = _int_of(c.fetch_result(out), out)
                results[pf] = (val, c.bytes_moved)
            finally:
                c.shutdown()
        assert results[True][0] == results[False][0] == payload.count(b"DD")
        assert results[True][1] == results[False][1]

    def test_prefetch_never_stages_to_dead_node(self):
        c = Cluster(n_nodes=2, workers_per_node=1, storage_nodes=("s0",),
                    network=Network(Link(latency_s=0.002, gbps=1.0)))
        try:
            dests = []
            orig_submit = c._xfer.submit

            def recording_submit(src, dst, items, **kw):
                dests.append(dst)
                return orig_submit(src, dst, items, **kw)

            c._xfer.submit = recording_submit
            c.kill_node("n1")
            th = self._child_blocked_thunk(c, b"D" * 200_000)
            out = c.evaluate(th.strict(), timeout=60)
            assert _int_of(c.fetch_result(out), out) > 0
            assert dests  # staging did happen
            assert "n1" not in dests
        finally:
            c.shutdown()


class TestFailover:
    def test_kill_during_staging_reroutes(self):
        """Killing the destination mid-transfer: the plan's late delivery
        is dropped (dead node) and the job re-places and completes."""
        c = Cluster(n_nodes=2, workers_per_node=1, storage_nodes=("s0",),
                    network=Network(Link(latency_s=0.02, gbps=0.05)))
        try:
            payload = b"K" * 500_000  # ~80 ms serialization at 0.05 Gb/s
            blob = c.nodes["s0"].repo.put_blob(payload)
            th = combination(c.client_repo, "count_string", blob,
                             Handle.blob(b"KK"))
            fut = c.submit(th.strict())
            time.sleep(0.04)  # transfer in flight toward the placed node
            c.kill_node("n0")
            out = fut.result(timeout=60)
            assert _int_of(c.fetch_result(out), out) == len(payload) // 2
        finally:
            c.shutdown()


class TestScopedFailure:
    def test_one_bad_job_does_not_fail_others(self):
        """A handler exception (unknown procedure definition walk) fails
        only the offending job; the scheduler loop and unrelated in-flight
        jobs keep going."""
        c = Cluster(n_nodes=2, workers_per_node=2)
        try:
            good1 = combination(c.client_repo, "inc_chain", _i(0), _i(60))
            f_good1 = c.submit(good1.strict())
            # a selection thunk over a malformed pair raises inside the
            # scheduler's _step_needs (not in a worker)
            bad_pair = c.client_repo.put_tree([_i(1)])  # not a [target, idx] pair
            f_bad = c.submit(bad_pair.selection_of().strict())
            good2 = combination(c.client_repo, "add", _i(20), _i(22))
            f_good2 = c.submit(good2.strict())
            with pytest.raises(Exception):
                f_bad.result(timeout=30)
            out1 = f_good1.result(timeout=60)
            out2 = f_good2.result(timeout=30)
            assert _int_of(c.fetch_result(out1), out1) == 60
            assert _int_of(c.fetch_result(out2), out2) == 42
        finally:
            c.shutdown()
