"""Training driver: the Fix-orchestrated loop.

Data shards are Application Thunks over a content-addressed corpus
(recompute-on-loss for free); the jitted train_step is the codelet; every
checkpoint is a content-addressed Tree whose unchanged leaves dedup.  On a
pod this same driver runs once per host with the production mesh; here it
runs real steps on CPU for the smoke/e2e examples.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint import dedup_stats, load_step, save_step
from ..configs import ARCHS, get_config
from ..core import Repository
from .. import fix
from ..data import TokenPipeline, corpus_handle
from ..models import init_params
from ..models.base import tree_map_specs
from ..optim import adafactor as _adafactor
from ..optim import adamw as _adamw
from ..parallel.steps import RunConfig, build_train_step
from .mesh import make_host_mesh


def init_state(cfg, runcfg: RunConfig, seed: int = 0):
    from ..models import ops_for

    specs = ops_for(cfg).specs(cfg)
    params = init_params(specs, cfg, seed)
    if runcfg.optimizer == "adafactor":
        o_specs = _adafactor.state_specs(specs, runcfg.adafactor)
    else:
        o_specs = _adamw.state_specs(specs, runcfg.optim)
    opt = init_params(o_specs, cfg, seed)
    return {"params": params, "opt": opt}


def train(cfg, runcfg: RunConfig, steps: int, batch: int, seq: int,
          mesh=None, checkpoint_every: int = 0, resume=None,
          repo: Repository | None = None, log_every: int = 10,
          seed: int = 0):
    """Returns (final state, losses, checkpoint roots, repo)."""
    repo = repo or Repository("train")
    backend = fix.local(repo)  # shard recipes run through the one protocol
    corpus = corpus_handle(repo, n_bytes=max(batch * (seq + 1) * 64, 1 << 20),
                           seed=seed)
    pipe = TokenPipeline(repo, corpus, seq_len=seq, batch=batch,
                        vocab=cfg.vocab)

    step_fn, state_sh, _bs, _abs = build_train_step(cfg, runcfg, mesh)
    if resume is not None:
        meta, state = load_step(repo, resume)
        start = meta["step"]
        state = jax.tree.map(jax.numpy.asarray, state)
    else:
        state = init_state(cfg, runcfg, seed)
        start = 0

    losses, roots = [], []
    t0 = time.time()
    for step in range(start, start + steps):
        batch_np = pipe.batch_for_step(backend, step)  # Fix recipe -> bytes
        state, metrics = step_fn(state, batch_np)
        loss = float(metrics["loss"])
        losses.append(loss)
        if log_every and (step % log_every == 0 or step == start + steps - 1):
            dt = time.time() - t0
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):8.3f}  "
                  f"{dt/max(step-start+1,1):.2f}s/step", flush=True)
        if checkpoint_every and (step + 1) % checkpoint_every == 0:
            roots.append(save_step(repo, state, step + 1,
                                   {"arch": cfg.name}))
    backend.close()
    return state, losses, roots, repo


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    runcfg = RunConfig(microbatches=args.microbatches, remat="none",
                       optimizer=args.optimizer)
    state, losses, roots, repo = train(
        cfg, runcfg, args.steps, args.batch, args.seq,
        checkpoint_every=args.checkpoint_every)
    print(f"\nfinal loss: {losses[-1]:.4f} (from {losses[0]:.4f})")
    if roots:
        print("checkpoints:", [r.raw[:6].hex() for r in roots],
              dedup_stats(repo, roots))


if __name__ == "__main__":
    main()
