"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines CONFIG (the exact assigned full-scale config) and
SMOKE (a reduced same-family config for CPU tests).  SHAPES defines the
assigned input-shape cells and per-arch applicability (long_500k is
skipped for pure full-attention archs — quadratic 500k-history work their
papers don't define; see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from ..models import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    mode: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

ARCHS = [
    "qwen3_8b", "deepseek_67b", "internlm2_20b", "qwen3_4b",
    "deepseek_v3_671b", "arctic_480b", "seamless_m4t_medium",
    "mamba2_780m", "internvl2_26b", "zamba2_7b",
]

# families with sub-quadratic history handling run the 500k cell
_SUBQUADRATIC = {"mamba2", "hybrid"}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f".{arch.replace('-', '_')}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG


def cells_for(arch: str) -> list[ShapeCell]:
    cfg = get_config(arch)
    out = []
    for cell in SHAPES.values():
        if cell.name == "long_500k" and cfg.family not in _SUBQUADRATIC:
            continue  # full-attention archs skip 500k decode (documented)
        out.append(cell)
    return out


def all_cells() -> list[tuple[str, ShapeCell]]:
    return [(a, c) for a in ARCHS for c in cells_for(a)]
