"""Seeded chaos property suite: the fault-injection plane's end-to-end
contract, checked across fixed seeds (tier-1) plus one rotating seed per
CI build (the ``chaos`` job exports ``FIX_CHAOS_SEED``).

Each seed derives a workload and a fault schedule scaled to the clean
run's makespan (tests/workloads.py), then asserts the recovery
invariant from the fault plane's design:

* every job either completes with the *same content key* the clean run
  produced, or fails with an attributed, typed error
  (``ALLOWED_FAILURES``) — never a raw/unattributed exception;
* the fault run's trace passes every invariant in
  ``verify_invariants``, including the fault-mode rules (every injected
  loss answered by a delivery, retry, give-up, crash, or recompute);
* re-running the identical seeded schedule yields a byte-identical
  JSONL trace (bit-exact replay under faults).

A failing seed dumps its trace under ``fuzz-artifacts/`` for CI upload;
reproduce locally with::

    FIX_CHAOS_SEED=<seed> PYTHONPATH=src python -m pytest \
        tests/test_chaos_properties.py -k rotating
"""
import os
from pathlib import Path

import pytest

from repro.runtime import TraceRecorder

import sys
sys.path.insert(0, str(Path(__file__).resolve().parent))
from workloads import (  # noqa: E402
    make_chaos_spec, make_fault_schedule, run_chaos_case, run_workload)

pytestmark = pytest.mark.usefixtures("no_thread_leaks")

CHAOS_SEEDS = list(range(20))       # fixed "examples" tier-1 runs
REPLAY_SEEDS = [0, 4, 13]           # double-run bit-identity spot checks


def _dump_on_failure(recorders: dict, tag: str):
    """Write the failing case's trace(s) where CI can upload them."""
    out = Path(os.environ.get("FIX_FUZZ_ARTIFACTS", "fuzz-artifacts"))
    out.mkdir(parents=True, exist_ok=True)
    for name, rec in recorders.items():
        rec.save(out / f"{tag}-{name}.jsonl")


def _check_chaos_seed(seed: int) -> None:
    """One seed's full recovery-contract bundle (see module docstring)."""
    tr = TraceRecorder()
    try:
        r = run_chaos_case(seed, trace=tr)
        assert not r["mismatches"], (
            f"seed {seed}: completed jobs diverged from clean results: "
            f"{r['mismatches']}")
        assert not r["bad_failures"], (
            f"seed {seed}: unattributed failure types: {r['bad_failures']}")
        assert not r["violations"], (
            f"seed {seed}: trace invariant violations: {r['violations']}")
    except BaseException:
        _dump_on_failure({"fault-run": tr}, f"chaos-seed{seed}")
        raise


def _check_chaos_replay(seed: int) -> None:
    """Two runs of the identical seeded fault schedule must emit
    byte-identical traces — the replay half of the fault-plane invariant."""
    spec = make_chaos_spec(seed)
    clean = run_workload(spec)
    horizon = max(clean["makespan"], 1e-4)
    r1, r2 = TraceRecorder(), TraceRecorder()
    try:
        o1 = run_workload(spec, faults=make_fault_schedule(seed, spec, horizon),
                          tolerate_failures=True, trace=r1)
        o2 = run_workload(spec, faults=make_fault_schedule(seed, spec, horizon),
                          tolerate_failures=True, trace=r2)
        assert r1.to_jsonl() == r2.to_jsonl(), \
            f"seed {seed}: double-run fault traces differ"
        assert o1["outcomes"] == o2["outcomes"], \
            f"seed {seed}: double-run outcomes differ"
    except BaseException:
        _dump_on_failure({"run1": r1, "run2": r2}, f"chaos-replay-seed{seed}")
        raise


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_recovery_contract(seed):
    _check_chaos_seed(seed)


@pytest.mark.parametrize("seed", REPLAY_SEEDS)
def test_chaos_replay_bit_identical(seed):
    _check_chaos_replay(seed)


def test_rotating_seed_chaos(capsys):
    """CI-only: one fresh seed per build, printed for reproduction.  Local
    runs (no FIX_CHAOS_SEED in the environment) skip."""
    raw = os.environ.get("FIX_CHAOS_SEED")
    if raw is None:
        pytest.skip("rotating chaos seed not set (CI chaos job exports "
                    "FIX_CHAOS_SEED)")
    seed = int(raw)
    with capsys.disabled():
        print(f"\n[chaos] rotating seed: {seed}  (repro: FIX_CHAOS_SEED={seed} "
              f"PYTHONPATH=src python -m pytest "
              f"tests/test_chaos_properties.py -k rotating)")
    _check_chaos_seed(seed)
    _check_chaos_replay(seed)
