"""Per-tenant admission control: weighted fair queuing + inflight caps.

When demand exceeds decode slots, *who waits* is policy.  The policy here
is stride scheduling — the classic weighted-fair discipline: each tenant
carries a virtual time that advances by ``1 / weight`` per admission, and
the next slot goes to the backlogged tenant with the smallest virtual
time.  Over any busy window, tenant admissions converge to the weight
ratio, and a newly arriving tenant joins at the current virtual floor
(``max`` with its own clock), so it can neither starve nor bank credit
while idle.

``max_inflight`` bounds how many of one tenant's requests may occupy
decode slots at once — the knob that keeps one tenant's long generations
from monopolizing the batch even when the queue discipline is fair.

The queue is deliberately engine-agnostic: ``push`` / ``pop`` /
``release`` with no clock and no threads, so the same policy drives the
host-level :class:`~repro.serving.engine.ServeEngine` and the Fix-backed
:class:`~repro.serving.fixserve.FixServeEngine`, and unit tests can drive
it directly.
"""
from __future__ import annotations

from collections import deque
from typing import Optional


class _Tenant:
    __slots__ = ("name", "weight", "vtime", "queue", "inflight", "admitted")

    def __init__(self, name: str, weight: float, vtime: float):
        self.name = name
        self.weight = weight
        self.vtime = vtime
        self.queue: deque = deque()
        self.inflight = 0
        self.admitted = 0


class TenantQueue:
    """Stride-scheduled weighted fair queue with per-tenant inflight caps.

    ``weights`` maps tenant name -> share (default ``default_weight``);
    ``max_inflight`` (None = unlimited) caps a tenant's concurrently
    admitted requests.  Deterministic: ties break on tenant name, FIFO
    within a tenant.
    """

    def __init__(self, weights: Optional[dict] = None,
                 default_weight: float = 1.0,
                 max_inflight: Optional[int] = None):
        if default_weight <= 0:
            raise ValueError("default_weight must be > 0")
        if weights and any(w <= 0 for w in weights.values()):
            raise ValueError("tenant weights must be > 0")
        if max_inflight is not None and max_inflight < 1:
            raise ValueError("max_inflight must be >= 1 (or None)")
        self._weights = dict(weights or {})
        self._default_weight = default_weight
        self.max_inflight = max_inflight
        self._tenants: dict[str, _Tenant] = {}
        self._vfloor = 0.0  # virtual time of the last admission

    def _tenant(self, name: str) -> _Tenant:
        t = self._tenants.get(name)
        if t is None:
            w = self._weights.get(name, self._default_weight)
            t = _Tenant(name, w, self._vfloor)
            self._tenants[name] = t
        return t

    def __len__(self) -> int:
        return sum(len(t.queue) for t in self._tenants.values())

    def tenants(self) -> list:
        """Every tenant this queue has ever seen, sorted (stable for
        metric labels and stats snapshots)."""
        return sorted(self._tenants)

    def queued(self, tenant: str) -> int:
        t = self._tenants.get(tenant)
        return 0 if t is None else len(t.queue)

    def inflight(self, tenant: str) -> int:
        t = self._tenants.get(tenant)
        return 0 if t is None else t.inflight

    def admitted(self, tenant: str) -> int:
        t = self._tenants.get(tenant)
        return 0 if t is None else t.admitted

    def push(self, req) -> None:
        t = self._tenant(req.tenant)
        # an idle tenant rejoins at the floor: no banked credit from the
        # past, no starvation penalty for having been away
        if not t.queue and t.inflight == 0:
            t.vtime = max(t.vtime, self._vfloor)
        t.queue.append(req)

    def pop(self):
        """Admit the fair-queue choice, or None if nothing is eligible
        (empty, or every backlogged tenant is at its inflight cap)."""
        best: Optional[_Tenant] = None
        for t in self._tenants.values():
            if not t.queue:
                continue
            if self.max_inflight is not None and t.inflight >= self.max_inflight:
                continue
            if best is None or (t.vtime, t.name) < (best.vtime, best.name):
                best = t
        if best is None:
            return None
        self._vfloor = best.vtime
        best.vtime += 1.0 / best.weight
        best.inflight += 1
        best.admitted += 1
        return best.queue.popleft()

    def release(self, tenant: str) -> None:
        """A previously popped request finished — frees its inflight slot."""
        t = self._tenants.get(tenant)
        if t is not None and t.inflight > 0:
            t.inflight -= 1
