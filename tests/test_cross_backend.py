"""Cross-backend equivalence: one program, three execution substrates.

The portability claim the Backend protocol exists for — a compiled Fix
program produces byte-identical result content keys on the in-process
evaluator (``fix.local()``), the VirtualClock simulated cluster
(``fix.on(Cluster(...))``), and real worker processes
(``fix.remote(n_workers=2)``).  Content addressing makes this a strong
check: equal raws mean equal results *and* equal computation structure.
"""
import pytest

import repro.fix as fix
from repro.core.stdlib import add, checksum_tree, fib, fix_if, inc_chain
from repro.runtime import Cluster, VirtualClock

pytestmark = pytest.mark.usefixtures("no_thread_leaks")

BACKENDS = ["local", "simulated", "remote"]


def _open_backend(kind: str):
    if kind == "local":
        return fix.local(), None
    if kind == "simulated":
        clk = VirtualClock()
        c = Cluster(n_nodes=3, workers_per_node=1, clock=clk, seed=0)
        return fix.on(c), clk
    return fix.remote(n_workers=2), None


@pytest.fixture(params=BACKENDS)
def backend(request):
    be, clk = _open_backend(request.param)
    try:
        yield be
    finally:
        be.close()
        if clk is not None:
            clk.close()


def _programs(repo):
    """The equivalence mix: arithmetic, recursion fan-out, tail-call
    chain, lazy branch elision, and a tree-consuming staged job."""
    tree = repo.put_tree([repo.put_blob(bytes([i]) * 2048) for i in range(4)])
    t = add(1, 2).strict()
    f = add(10, 20).strict()
    return [
        add(40, 2),
        fib(10),
        inc_chain(5, 6),
        fix.lit(fix_if(True, t.compile(repo), f.compile(repo))),
        checksum_tree(tree),
    ]


def _run_all(be):
    futs = [be.submit(p) for p in _programs(be.repo)]
    return [f.result(timeout=300).raw for f in futs]


def test_results_and_keys_identical_across_backends():
    reference = None
    for kind in BACKENDS:
        be, clk = _open_backend(kind)
        try:
            raws = _run_all(be)
        finally:
            be.close()
            if clk is not None:
                clk.close()
        if reference is None:
            reference = raws
        else:
            assert raws == reference, f"{kind} diverged from local"


def test_fetch_decodes_identically(backend):
    assert backend.run(add(40, 2), timeout=300) == 42
    assert backend.run(fib(9), timeout=300) == 34


def test_memo_hit_resubmission(backend):
    h1 = backend.evaluate(inc_chain(0, 5), timeout=300)
    h2 = backend.evaluate(inc_chain(0, 5), timeout=300)
    assert h1.raw == h2.raw


def test_remote_uses_at_least_two_worker_processes():
    """The acceptance bar: a real fan-out actually lands on ≥2 OS
    processes (not one worker doing everything serially)."""
    with fix.remote(n_workers=2) as be:
        futs = [be.submit(fib(n)) for n in (9, 10, 11, 12)]
        for f in futs:
            f.result(timeout=300)
        pids = {w.proc.pid for w in be._workers.values() if w.alive}
        assert len(pids) >= 2
        busy = {wid for wid, w in be._workers.items()}
        assert len(busy) >= 2
        # per-worker log files prove both processes ran jobs
        ran = [wid for wid, w in be._workers.items()
               if "job=" in open(w.log_path).read()]
        assert len(ran) >= 2
