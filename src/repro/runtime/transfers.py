"""Batched, pipelined network transfers + the scheduler's location index.

The seed runtime shipped every missing handle as its own thread-per-handle
transfer: each one paid link latency, took the source NIC lock, slept for
its own (often microscopic) serialization share, and posted its own
scheduler event.  For a job staging K inputs that is K thread spawns,
K latency charges and K events — the per-transfer *fixed* costs dominate
and the scheduler re-walks the object graph to find a source for every
handle.

This module externalizes that work into a proper subsystem (paper §4.2:
the platform owns network I/O, so it can schedule it):

* :class:`TransferPlan` — all handles a job (or prefetch pass) needs moved
  across one (src → dst) link, coalesced into a single wire transfer that
  pays link latency **once** and serializes bandwidth for the summed
  payload.
* :class:`TransferManager` — a small pool of *persistent* per-link worker
  threads executing plans.  Serialization holds the source NIC; propagation
  latency is handed to a shared delivery timer so consecutive plans on a
  link pipeline (plan N+1 serializes while plan N is in flight).
  ``mode="per_handle"`` reproduces the seed's thread-per-handle behaviour
  for A/B benchmarking (see ``benchmarks --fig staging``).
* :class:`LocationIndex` — content key → node-id set, maintained from
  repository put notifications and transfer deliveries, so source lookup
  and locality placement are O(needs) instead of O(nodes × graph walk).

Cross-job dedup (two jobs staging the same blob to the same node share one
wire transfer) lives in the scheduler's in-flight table; this module only
ever sees already-deduplicated batches.
"""
from __future__ import annotations

import heapq
import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from ..core import Handle


# ----------------------------------------------------------- location index
class LocationIndex:
    """Which nodes hold which content (content key → set of node ids).

    Entries are *hints*: data can vanish under us (node failure, explicit
    eviction), so readers must verify residency with the node's repository
    before trusting a hit.  Writers are repository put listeners (worker
    and transfer threads) plus the scheduler, hence the lock.
    """

    def __init__(self):
        self._locs: dict[bytes, set[str]] = {}
        self._lock = threading.Lock()

    def add(self, key: bytes, node_id: str) -> None:
        with self._lock:
            self._locs.setdefault(key, set()).add(node_id)

    def drop_node(self, node_id: str) -> None:
        """A node died (fail-stop): forget everything it held."""
        with self._lock:
            empty = []
            for key, nodes in self._locs.items():
                nodes.discard(node_id)
                if not nodes:
                    empty.append(key)
            for key in empty:
                del self._locs[key]

    def nodes_for(self, key: bytes) -> tuple[str, ...]:
        with self._lock:
            nodes = self._locs.get(key)
            return tuple(nodes) if nodes else ()

    def __len__(self) -> int:
        with self._lock:
            return len(self._locs)


# ------------------------------------------------------------ transfer plan
@dataclass
class TransferPlan:
    """One coalesced wire transfer: every handle moving src → dst together.

    Payloads are captured eagerly (on the scheduler thread, while the
    source is known to hold them) so a source failing mid-flight cannot
    corrupt the batch — mirroring the seed's eager ``raw_payload`` grab.
    """

    src: str
    dst: str
    items: list = field(default_factory=list)  # (Handle, payload, size)

    @property
    def total_bytes(self) -> int:
        return sum(size for _, _, size in self.items)

    @property
    def raws(self) -> tuple[bytes, ...]:
        return tuple(h.raw for h, _, _ in self.items)


# ------------------------------------------------------------ delivery timer
class _DeliveryTimer:
    """Single thread firing callbacks at deadlines (propagation latency).

    Link workers hand completed serializations here so the *next* plan can
    start serializing while the previous one is still propagating — the
    pipelining that makes batched latency per-plan instead of per-handle
    without giving up wall-clock overlap.
    """

    def __init__(self):
        self._heap: list = []
        self._cv = threading.Condition()
        self._seq = itertools.count()
        self._stopped = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="fix-xfer-timer")
        self._thread.start()

    def schedule(self, when: float, fn: Callable[[], None]) -> None:
        with self._cv:
            heapq.heappush(self._heap, (when, next(self._seq), fn))
            self._cv.notify()

    def stop(self) -> None:
        with self._cv:
            self._stopped = True
            self._cv.notify()

    def _run(self) -> None:
        while True:
            with self._cv:
                if self._stopped:
                    return
                if not self._heap:
                    self._cv.wait()
                    continue
                when, _, fn = self._heap[0]
                now = time.monotonic()
                if when > now:
                    self._cv.wait(when - now)
                    continue
                heapq.heappop(self._heap)
            try:
                fn()
            except Exception:  # noqa: BLE001 — a delivery must never kill the clock
                pass


# -------------------------------------------------------------- link worker
class _LinkWorker:
    """Persistent worker serializing plans over one (src → dst) link."""

    def __init__(self, manager: "TransferManager", src: str, dst: str):
        self.manager = manager
        self.src = src
        self.dst = dst
        self.q: "queue.Queue[Optional[TransferPlan]]" = queue.Queue()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"fix-xfer-{src}-{dst}")
        self._thread.start()

    def stop(self) -> None:
        self.q.put(None)

    def _run(self) -> None:
        mgr = self.manager
        while True:
            plan = self.q.get()
            if plan is None:
                return
            link = mgr.network.link(plan.src, plan.dst)
            src_node = mgr.nodes.get(plan.src)
            nic = src_node.nic_lock if src_node is not None else threading.Lock()
            with nic:  # the source NIC serializes the summed payload once
                time.sleep(link.serialized_s(plan.total_bytes))
            mgr._timer.schedule(time.monotonic() + link.latency_s,
                                lambda p=plan: mgr._deliver(p))


# ---------------------------------------------------------- transfer manager
class TransferManager:
    """Executes :class:`TransferPlan`s with per-link persistent workers.

    ``submit`` is called from the scheduler thread only; completions are
    posted back as ``("transfer_done", dst_id, raws)`` events.  ``account``
    is invoked synchronously on submit with (transfer_count, bytes) so the
    cluster's public counters stay scheduler-thread-owned.
    """

    def __init__(self, network, nodes: dict, post_event: Callable,
                 account: Optional[Callable] = None, mode: str = "batched"):
        if mode not in ("batched", "per_handle"):
            raise ValueError(f"unknown transfer mode {mode!r}")
        self.network = network
        self.nodes = nodes
        self.mode = mode
        self._post = post_event
        self._account = account or (lambda n, b: None)
        self._timer = _DeliveryTimer()
        self._workers: dict[tuple[str, str], _LinkWorker] = {}

    # ---------------------------------------------------------------- submit
    def submit(self, src_id: str, dst_id: str, items: list) -> None:
        """Move ``items`` = [(handle, payload, size), ...] src → dst."""
        if not items:
            return
        plan = TransferPlan(src_id, dst_id, list(items))
        if self.mode == "per_handle":
            # Seed behaviour: one thread, one latency charge, one NIC grab
            # and one scheduler event *per handle* — kept for A/B runs.
            self._account(len(plan.items), plan.total_bytes)
            for h, payload, size in plan.items:
                threading.Thread(
                    target=self._per_handle_xfer,
                    args=(plan.src, plan.dst, h, payload, size),
                    daemon=True,
                ).start()
            return
        self._account(1, plan.total_bytes)
        key = (src_id, dst_id)
        worker = self._workers.get(key)
        if worker is None:
            worker = self._workers[key] = _LinkWorker(self, src_id, dst_id)
        worker.q.put(plan)

    # -------------------------------------------------------------- delivery
    def _deliver(self, plan: TransferPlan) -> None:
        try:
            dst = self.nodes.get(plan.dst)
            if dst is not None and dst.alive:
                for h, payload, _size in plan.items:
                    dst.repo.put_handle_data(h, payload)
        finally:
            # ALWAYS post, even toward a dead node or past a failed install:
            # waiting jobs must unblock (an undelivered handle re-misses and
            # fails the job with the real error) and the scheduler's
            # in-flight table must be reaped.
            self._post(("transfer_done", plan.dst, plan.raws))

    def _per_handle_xfer(self, src_id: str, dst_id: str, h: Handle,
                         payload, size: int) -> None:
        link = self.network.link(src_id, dst_id)
        src_node = self.nodes.get(src_id)
        time.sleep(link.latency_s)
        nic = src_node.nic_lock if src_node is not None else threading.Lock()
        with nic:
            time.sleep(link.serialized_s(size))
        try:
            dst = self.nodes.get(dst_id)
            if dst is not None and dst.alive:
                dst.repo.put_handle_data(h, payload)
        finally:
            self._post(("transfer_done", dst_id, (h.raw,)))

    # ------------------------------------------------------------- lifecycle
    def stop(self) -> None:
        for w in self._workers.values():
            w.stop()
        self._timer.stop()
