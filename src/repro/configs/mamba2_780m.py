"""Mamba2-780m [arXiv:2405.21060]: 48L d1536 attn-free, ssm_state=128,
d_inner=3072, 48 SSD heads (headdim 64), v50280."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="mamba2", n_layers=48, d_model=1536, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab=50280, ssm_state=128, ssm_headdim=64,
    expand=2, conv_width=4, ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="mamba2", n_layers=2, d_model=64, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab=512, ssm_state=16, ssm_headdim=16,
    ssm_chunk=8,
)
