"""Paper-figure benchmarks (one function per table/figure of §5).

Each returns a dict of measurements; run.py prints CSV.  Workloads are
written against the ``repro.fix`` frontend (typed codelets + lazy graphs +
the Backend protocol) — which compiles to combination trees byte-identical
to the hand-built ones, so numbers are comparable across the migration.
Comparator baselines are honest analogs implemented on our own runtime:

* "subprocess"          — fig 7a's Linux vfork+exec comparator.
* "client-driven"       — fig 7b/9's Ray-like mode: the client performs a
  round trip per dependency resolution (dependencies coupled to the client).
* "blocking-style"      — fig 9: one coarse invocation that faults in every
  node's full data level by level (Ray blocking-get analog).
* "internal I/O"        — fig 8a/8b ablation: worker slots are bound before
  dependencies arrive (status-quo serverless).
* "no locality"         — fig 8b ablation: random placement.
"""
from __future__ import annotations

import subprocess
import sys
import time

import numpy as np

import repro.fix as fix
from repro.core import Evaluator, Handle, Repository
from repro.core.stdlib import (
    add,
    checksum_tree,
    combination,
    count_string,
    inc_chain,
    merge_counts,
)
from repro.runtime import (
    Cluster,
    Link,
    Network,
    TraceRecorder,
    VirtualClock,
    link_utilization,
    starvation_intervals,
    verify_invariants,
    waterfall,
)


def _i(v: int) -> Handle:
    return Handle.blob(v.to_bytes(8, "little", signed=True))


# ------------------------------------------------------------------ fig 7a
def fig7a_invocation(n: int = 4096) -> dict:
    """Invocation overhead of add(i8, i8): static call / Fix (raw and
    frontend spellings) / subprocess."""
    # static python call
    f = lambda a, b: a + b
    t0 = time.perf_counter_ns()
    acc = 0
    for i in range(n):
        acc = f(acc & 0xFF, i & 0xFF)
    static_ns = (time.perf_counter_ns() - t0) / n

    # Fix evaluation, raw Table-1 spelling (fresh thunk each time)
    repo = Repository()
    ev = Evaluator(repo)
    ev.evaluate(combination(repo, "add", _i(1), _i(2)).strict())  # warm
    t0 = time.perf_counter_ns()
    for i in range(n):
        ev.evaluate(combination(repo, "add", _i(i), _i(i + 1)).strict())
    fix_ns = (time.perf_counter_ns() - t0) / n

    # frontend spelling: typed call -> compile -> evaluate (same thunks,
    # so the delta over fix_us is the marshalling layer's cost)
    be = fix.local()
    be.run(add(1, 2))  # warm
    t0 = time.perf_counter_ns()
    for i in range(n):
        be.evaluate(add(i, i + 1), timeout=None)  # synchronous fast path
    frontend_ns = (time.perf_counter_ns() - t0) / n
    be.close()

    # memo-hit path (pay-for-results: repeated work is free)
    th = combination(repo, "add", _i(7), _i(8)).strict()
    ev.evaluate(th)
    t0 = time.perf_counter_ns()
    for _ in range(n):
        ev.evaluate(th)
    memo_ns = (time.perf_counter_ns() - t0) / n

    # subprocess (vfork+exec analog) — fewer reps, it's slow
    reps = 64
    t0 = time.perf_counter_ns()
    for i in range(reps):
        subprocess.run([sys.executable, "-c", "import sys;sys.exit(0)"],
                       check=True, capture_output=True)
    proc_ns = (time.perf_counter_ns() - t0) / reps

    return {
        "static_us": static_ns / 1e3,
        "fix_us": fix_ns / 1e3,
        "fix_frontend_us": frontend_ns / 1e3,
        "fix_memo_us": memo_ns / 1e3,
        "subprocess_us": proc_ns / 1e3,
        "slowdown_subprocess_vs_fix": proc_ns / fix_ns,
    }


# ------------------------------------------------------------------ fig 7b
def fig7b_chain(length: int = 500) -> dict:
    """500-deep chain: one self-describing submission vs a client round
    trip per call, near (0.2 ms) and far (5 ms) client."""
    out = {}
    for label, lat in (("near", 0.0002), ("far", 0.005)):
        net = Network(Link(latency_s=0.0002, gbps=10),
                      overrides={("client", f"n{i}"): Link(lat, 10) for i in range(2)}
                      | {(f"n{i}", "client"): Link(lat, 10) for i in range(2)})
        c = Cluster(n_nodes=2, workers_per_node=2, network=net)
        try:
            be = fix.on(c)
            # Fix: the whole chain is one thunk (tail calls stay server-side)
            t0 = time.perf_counter()
            r = be.fetch(inc_chain(0, length), timeout=120)
            fix_s = time.perf_counter() - t0
            assert r == length
            # client-driven: one submission per step, client latency each way
            t0 = time.perf_counter()
            v = 0
            for _ in range(length):
                time.sleep(lat)  # request leaves the client
                v = be.fetch(add(v, 1), timeout=120)
            client_s = time.perf_counter() - t0
            assert v == length
            out[f"fix_{label}_s"] = fix_s
            out[f"client_driven_{label}_s"] = client_s
            out[f"speedup_{label}"] = client_s / fix_s
        finally:
            c.shutdown()
    return out


# ------------------------------------------------------------------ fig 8a
def fig8a_late_binding(n_jobs: int = 256, storage_latency: float = 0.15,
                       workers: int = 16) -> dict:
    """Jobs depend on remote-storage inputs (150 ms).  Externalized I/O
    fetches before binding a slot; internal I/O holds the slot while
    fetching (CPU-starved, like status-quo serverless)."""
    out = {}
    for mode, oversub in (("external", 1), ("internal", 2)):
        net = Network(Link(latency_s=0.0002, gbps=10),
                      overrides={("s0", "n0"): Link(storage_latency, 10)})
        c = Cluster(n_nodes=1, workers_per_node=workers, io_mode=mode,
                    oversubscribe=oversub, storage_nodes=("s0",), network=net)
        try:
            be = fix.on(c)
            inputs = []
            for i in range(n_jobs):
                payload = i.to_bytes(8, "little", signed=True) + b"\x00" * 56
                h = c.nodes["s0"].repo.put_blob(payload)
                inputs.append(h)
            c.reset_accounting()
            t0 = time.perf_counter()
            futs = [be.submit(count_string(h, b"\x00")) for h in inputs]
            for f in futs:
                f.result(timeout=300)
            dt = time.perf_counter() - t0
            util = c.utilization(dt)
            out[f"{mode}_s"] = dt
            out[f"{mode}_starved_frac"] = round(util["starved_frac"], 3)
            out[f"{mode}_idle_iowait_frac"] = round(util["idle_iowait_frac"], 3)
        finally:
            c.shutdown()
    out["speedup"] = out["internal_s"] / out["external_s"]
    return out


# ------------------------------------------------------------------ fig 8b
def fig8b_wordcount(n_shards: int = 48, shard_mb: float = 16.0,
                    n_nodes: int = 10, workers: int = 4) -> dict:
    """Count a 3-char needle over shards scattered across the cluster, then
    binary-reduce.  locality vs no-locality vs no-locality+internal-I/O."""
    rng = np.random.default_rng(0)
    shard_bytes = [rng.integers(97, 123, int(shard_mb * 1e6)).astype(np.uint8).tobytes()
                   for _ in range(n_shards)]
    needle = b"abc"
    expected = sum(s.count(needle) for s in shard_bytes)

    results = {}
    cases = [("fix", "locality", "external"),
             ("no_locality", "random", "external"),
             ("internal_io", "random", "internal")]
    for label, placement, io_mode in cases:
        net = Network(Link(latency_s=0.001, gbps=0.5))  # 0.5 Gb/s: moving a
        # shard costs ~128 ms — locality matters, like the paper's cluster
        c = Cluster(n_nodes=n_nodes, workers_per_node=workers,
                    placement=placement, io_mode=io_mode,
                    oversubscribe=2 if io_mode == "internal" else 1,
                    network=net, seed=1)
        try:
            be = fix.on(c)
            handles = []
            for i, sb in enumerate(shard_bytes):  # scatter round-robin
                node = c.nodes[f"n{i % n_nodes}"]
                handles.append(node.repo.put_blob(sb))
            c.reset_accounting()
            t0 = time.perf_counter()
            # map + binary reduction: one lazy DAG, one submission
            level = [count_string(h, needle) for h in handles]
            while len(level) > 1:
                nxt = [merge_counts(level[i], level[i + 1])
                       for i in range(0, len(level) - 1, 2)]
                if len(level) % 2:
                    nxt.append(level[-1])
                level = nxt
            got = be.fetch(level[0], timeout=600)
            dt = time.perf_counter() - t0
            assert got == expected, (got, expected)
            util = c.utilization(dt)
            results[f"{label}_s"] = dt
            results[f"{label}_starved_frac"] = round(util["starved_frac"], 3)
            results[f"{label}_idle_iowait_frac"] = round(util["idle_iowait_frac"], 3)
            results[f"{label}_bytes_moved_mb"] = round(c.bytes_moved / 1e6, 1)
        finally:
            c.shutdown()
    results["locality_speedup"] = results["no_locality_s"] / results["fix_s"]
    return results


# ------------------------------------------------------------------- fig 9
def fig9_btree(n_keys: int = 20_000, lookups: int = 50) -> dict:
    """B+-tree traversal granularity: Fix selections vs blocking-style
    (fetch whole node data per level) vs client-driven fine-grained."""
    import bisect

    sys.path.insert(0, "examples")
    from btree_kv import build_btree, fix_lookup

    keys = [f"key{i:08d}".encode() for i in range(n_keys)]
    values = [f"value-{i}".encode() * 3 for i in range(n_keys)]
    out = {}
    for arity in (64, 256):
        be = fix.local()
        repo = be.repo
        root, depth = build_btree(repo, keys, values, arity)

        t0 = time.perf_counter()
        for i in range(0, n_keys, max(n_keys // lookups, 1)):
            val, _steps = fix_lookup(be, root, keys[i])
            assert val == values[i]
        fix_us = (time.perf_counter() - t0) / lookups * 1e6

        # blocking-style: materialize every child's data at each level
        def blocking_lookup(root, key):
            node = root
            while True:
                kids = repo.get_tree(node)
                _ = [repo.raw_payload(k) for k in kids]  # fetch ALL children
                ks = repo.get_blob(kids[0]).split(b"\x00")
                idx = max(bisect.bisect_right(ks, key) - 1, 0)
                child = kids[idx + 1]
                if child.content_type == 0:
                    return repo.get_blob(child)
                node = child

        t0 = time.perf_counter()
        for i in range(0, n_keys, max(n_keys // lookups, 1)):
            assert blocking_lookup(root, keys[i]) == values[i]
        blocking_us = (time.perf_counter() - t0) / lookups * 1e6
        be.close()

        out[f"arity{arity}_fix_us"] = round(fix_us, 1)
        out[f"arity{arity}_blocking_us"] = round(blocking_us, 1)
        out[f"arity{arity}_depth"] = depth
    return out


# ----------------------------------------------------------------- staging
def fig_staging(n_jobs: int = 32, inputs_per_job: int = 24, blob_kb: int = 8,
                n_nodes: int = 3, workers: int = 2) -> dict:
    """Fan-out staging: each job's minimum repository is a private tree of
    small input blobs parked on a storage node behind a 3 ms link.

    ``per_handle`` reproduces the seed scheduler: one thread, one latency
    charge, one NIC serialization and one scheduler event per handle.
    ``batched`` is the transfer scheduler under test: one TransferPlan per
    (src → dst) per job, link latency paid once per plan, summed payload
    serialized once.  Same bytes move either way; wall clock is the
    per-transfer fixed costs."""
    rng = np.random.default_rng(0)
    out = {}
    for mode in ("per_handle", "batched"):
        net = Network(Link(latency_s=0.003, gbps=10))
        c = Cluster(n_nodes=n_nodes, workers_per_node=workers,
                    storage_nodes=("s0",), network=net, transfer_mode=mode)
        try:
            be = fix.on(c)
            store = c.nodes["s0"].repo
            jobs = []
            for _ in range(n_jobs):
                blobs = [store.put_blob(rng.integers(0, 255, blob_kb * 1024)
                                        .astype(np.uint8).tobytes())
                         for _ in range(inputs_per_job)]
                tree = store.put_tree(blobs)
                jobs.append(checksum_tree(tree))
            c.reset_accounting()
            t0 = time.perf_counter()
            futs = [be.submit(j) for j in jobs]
            for f in be.as_completed(futs, timeout=600):
                f.result(timeout=0)
            dt = time.perf_counter() - t0
            out[f"{mode}_s"] = dt
            out[f"{mode}_transfers"] = c.transfers
            out[f"{mode}_bytes_moved"] = c.bytes_moved
        finally:
            c.shutdown()
    out["speedup"] = out["per_handle_s"] / out["batched_s"]
    out["bytes_moved_equal"] = out["per_handle_bytes_moved"] == out["batched_bytes_moved"]
    return out


# ------------------------------------------------------------------- sweep
def _sweep_workload(c: Cluster, n_jobs: int, inputs_per_job: int,
                    blob_kb: int, anchored: bool = False):
    """Per-job private trees of ``checksum_tree`` input blobs.

    ``anchored=False``: everything parks on the storage node — bytes moved
    are placement-independent (all payloads ship from s0), so wall and
    virtual runs are byte-comparable however they schedule.

    ``anchored=True``: one input per job additionally lives on a *thin-pipe*
    worker (round-robin over odd nodes) — the bait that makes bytes-missing
    placement run the job behind the congested link, while seconds-to-stage
    pays the small anchor transfer to reach an idle fat pipe.
    """
    store = c.nodes["s0"].repo
    thin = [n for n in c.worker_nodes() if int(n.id[1:]) % 2] if anchored else []
    jobs = []
    for j in range(n_jobs):
        blobs = [store.put_blob(j.to_bytes(4, "little") + i.to_bytes(4, "little")
                                + b"\x5a" * (blob_kb * 1024 - 8))
                 for i in range(inputs_per_job)]
        if thin:
            anchor = thin[j % len(thin)].repo.put_blob(
                j.to_bytes(4, "little") + b"\xa5" * (blob_kb * 1024 - 4))
            blobs.append(anchor)
        jobs.append(checksum_tree(store.put_tree(blobs)))
    return jobs


def _run_sweep_cluster(n_nodes: int, jobs_spec: tuple, *, clock=None,
                       placement: str = "locality", anchored: bool = False,
                       network: Network) -> dict:
    c = Cluster(n_nodes=n_nodes, workers_per_node=1, storage_nodes=("s0",),
                network=network, placement=placement, clock=clock)
    try:
        be = fix.on(c)
        jobs = _sweep_workload(c, *jobs_spec, anchored=anchored)
        c.reset_accounting()
        real0 = time.perf_counter()
        sim0 = c.clock.now()
        futs = [be.submit(j) for j in jobs]
        for f in futs:
            f.result(timeout=600)
        makespan = c.clock.now() - sim0
        real = time.perf_counter() - real0
        util = c.utilization(makespan)
        return {
            "real_s": real,
            "makespan_s": makespan,
            "transfers": c.transfers,
            "bytes_moved": c.bytes_moved,
            "starved_frac": round(util["starved_frac"], 4),
        }
    finally:
        c.shutdown()
        if clock is not None:  # we made it for this run, we close it
            clock.close()


def _hetero_network(n_nodes: int) -> Network:
    """Odd workers are edge sites behind thin 0.2 Gb/s / 5 ms pipes (to and
    from everyone); even workers and storage share fat 10 Gb/s / 1 ms
    links.  Bytes-missing placement is blind to the difference; seconds-
    to-stage routes the bulk bytes around the congestion."""
    thin = Link(latency_s=0.005, gbps=0.2)
    overrides = {}
    names = [f"n{i}" for i in range(n_nodes)] + ["s0", "client"]
    for i in range(1, n_nodes, 2):
        for other in names:
            if other == f"n{i}":
                continue
            overrides[(f"n{i}", other)] = thin
            overrides[(other, f"n{i}")] = thin
    return Network(Link(latency_s=0.001, gbps=10.0), overrides=overrides)


def fig_sweep(wall_nodes: int = 64, sweep_sizes: tuple = (8, 16, 32, 64, 128, 256),
              jobs_per_node: int = 2, inputs_per_job: int = 8,
              blob_kb: int = 32) -> dict:
    """The PR-3 acceptance figure, two halves:

    (a) **virtual vs wall** — the same ``wall_nodes``-node staging workload
        under ``WallClock`` and ``VirtualClock``: identical bytes on the
        wire and identical transfer counts, makespans measured on each
        cluster's own clock, and the virtual run completing ≥ 20× faster
        in *real* seconds (every modeled sleep is free; what remains is
        the payload hashing and Python the simulation actually does).

    (b) **seconds-to-stage vs bytes-missing** — heterogeneous-link
        topologies swept 8 → 256 nodes entirely under the virtual clock
        (a sweep wall clock could never afford), A/Bing the two placement
        cost models on simulated makespan.
    """
    out = {}

    # -- (a) wall vs virtual: slow homogeneous links (0.02 Gb/s) make the
    # modeled network time ~13 s of wall sleeping on ~32 MB of payload,
    # which the virtual clock skips entirely.
    net = Network(Link(latency_s=0.003, gbps=0.02))
    spec = (wall_nodes, inputs_per_job, blob_kb * 2)
    wall = _run_sweep_cluster(wall_nodes, spec, network=net)
    virt = _run_sweep_cluster(wall_nodes, spec, network=net,
                              clock=VirtualClock())
    out["wall_real_s"] = round(wall["real_s"], 3)
    out["virtual_real_s"] = round(virt["real_s"], 3)
    out["virtual_makespan_s"] = round(virt["makespan_s"], 4)
    out["wall_makespan_s"] = round(wall["makespan_s"], 4)
    out["virtual_wall_speedup"] = round(wall["real_s"] / virt["real_s"], 1)
    out["bytes_moved_equal"] = wall["bytes_moved"] == virt["bytes_moved"]
    out["transfers_equal"] = wall["transfers"] == virt["transfers"]
    out["bytes_moved"] = virt["bytes_moved"]

    # -- (b) placement A/B over heterogeneous topologies, virtual only
    for n in sweep_sizes:
        net = _hetero_network(n)
        spec = (n * jobs_per_node, inputs_per_job, blob_kb)
        for placement in ("bytes", "locality"):
            r = _run_sweep_cluster(n, spec, network=net, placement=placement,
                                   anchored=True, clock=VirtualClock())
            tag = "seconds" if placement == "locality" else "bytes"
            out[f"n{n}_{tag}_makespan_s"] = round(r["makespan_s"], 4)
            out[f"n{n}_{tag}_transfers"] = r["transfers"]
        out[f"n{n}_placement_speedup"] = round(
            out[f"n{n}_bytes_makespan_s"] / out[f"n{n}_seconds_makespan_s"], 2)
    biggest = max(sweep_sizes)
    out["placement_speedup"] = out[f"n{biggest}_placement_speedup"]
    return out


# --------------------------------------------------------------- waterfall
def _ascii_waterfall(lanes: dict, horizon: float, width: int = 64) -> str:
    """Tiny terminal rendering: one row per lane, '#'=run '.'=stage
    '='=transfer, so a schedule is eyeballable without leaving the CLI."""
    rows = []
    glyph = {"run": "#", "stage": ".", "xfer": "="}
    for lane in sorted(lanes):
        cells = [" "] * width
        for iv in lanes[lane]:
            a = int(iv["start"] / horizon * (width - 1))
            b = max(int(iv["end"] / horizon * (width - 1)), a)
            g = glyph.get(iv["phase"], "?")
            for x in range(a, b + 1):
                cells[x] = g
        rows.append(f"{lane:>12s} |{''.join(cells)}|")
    return "\n".join(rows)


def fig_waterfall(n_jobs: int = 16, inputs_per_job: int = 6, blob_kb: int = 64,
                  n_nodes: int = 4) -> dict:
    """Trace-derived schedule analysis (the PR-4 artifact): record the
    staging workload's full event stream under the virtual clock, then
    reduce it to per-node waterfall lanes, per-link utilization and —
    in the internal-I/O ablation — starvation intervals attributed to
    the blob arrival that ended each one.  The trace also re-verifies
    the schedule invariants on every benchmark run."""
    rng = np.random.default_rng(0)
    out = {}
    for label, io_mode in (("external", "external"), ("internal", "internal")):
        rec = TraceRecorder()
        clk = VirtualClock()
        net = Network(Link(latency_s=0.002, gbps=0.5))
        c = Cluster(n_nodes=n_nodes, workers_per_node=1,
                    storage_nodes=("s0",), io_mode=io_mode, network=net,
                    clock=clk, trace=rec)
        try:
            be = fix.on(c)
            store = c.nodes["s0"].repo
            jobs = []
            for _ in range(n_jobs):
                blobs = [store.put_blob(rng.integers(0, 255, blob_kb * 1024)
                                        .astype(np.uint8).tobytes())
                         for _ in range(inputs_per_job)]
                jobs.append(checksum_tree(store.put_tree(blobs)))
            t0 = clk.now()
            futs = [be.submit(j) for j in jobs]
            for f in futs:
                f.result(timeout=600)
            makespan = clk.now() - t0
        finally:
            c.shutdown()
            clk.close()
        violations = verify_invariants(rec.events)
        assert not violations, violations
        lanes = waterfall(rec.events)
        util = link_utilization(rec.events, makespan)
        ivs = starvation_intervals(rec.events)
        attributed = [iv for iv in ivs if iv["attributed"] is not None]
        print(f"--- {label} I/O waterfall ({makespan:.3f}s simulated) ---",
              file=sys.stderr)
        print(_ascii_waterfall(lanes, makespan), file=sys.stderr)
        out[f"{label}_events"] = len(rec.events)
        out[f"{label}_makespan_s"] = round(makespan, 4)
        out[f"{label}_busiest_link_frac"] = round(max(util.values()), 4)
        out[f"{label}_starve_intervals"] = len(ivs)
        out[f"{label}_starve_attributed"] = len(attributed)
        out[f"{label}_starved_s"] = round(
            sum(iv["end"] - iv["start"] for iv in ivs), 4)
    out["invariants_ok"] = True  # asserted above, per mode
    return out


# ------------------------------------------------------------------ fig 10
@fix.codelet(name="compile_unit")
def compile_unit(src: bytes) -> int:
    """A "compile one translation unit" stand-in: real local work over a
    source blob fetched from storage."""
    a = np.frombuffer(src[:4096], dtype=np.uint8).astype(np.float64)
    a = np.tanh(a.reshape(64, 64) @ a.reshape(64, 64).T / 500.0)
    return int(a.sum() * 1000) & 0x7FFFFFFF


def fig10_burst_compile(n_units: int = 24, fetch_latency: float = 0.1) -> dict:
    """Burst-parallel compilation analog: every unit depends on a source
    blob behind a 100 ms storage link (paper: C files + headers), plus a
    small local codegen step.  The container has ONE core, so the contrast
    under test is I/O orchestration (the paper's, too):

    * fix           — externalized I/O: the platform prefetches all inputs
                      before binding slots; latencies fully overlap.
    * internal_io   — slots are held during each fetch (status-quo FaaS).
    * client_serial — one submission at a time (no platform visibility).
    """
    def make_cluster(io_mode):
        net = Network(Link(latency_s=0.001, gbps=10),
                      overrides={("s0", f"n{i}"): Link(fetch_latency, 10)
                                 for i in range(4)})
        return Cluster(n_nodes=4, workers_per_node=2, io_mode=io_mode,
                       oversubscribe=2 if io_mode == "internal" else 1,
                       storage_nodes=("s0",), network=net)

    rng = np.random.default_rng(0)
    out = {}
    for label, io_mode, serial in (("fix", "external", False),
                                   ("internal_io", "internal", False),
                                   ("client_serial", "external", True)):
        c = make_cluster(io_mode)
        try:
            be = fix.on(c)
            srcs = [c.nodes["s0"].repo.put_blob(
                rng.integers(0, 255, 8192).astype(np.uint8).tobytes())
                for _ in range(n_units)]
            t0 = time.perf_counter()
            if serial:
                for h in srcs:
                    be.evaluate(compile_unit(h), timeout=600)
            else:
                futs = [be.submit(compile_unit(h)) for h in srcs]
                for f in futs:
                    f.result(timeout=600)
            out[f"{label}_s"] = time.perf_counter() - t0
        finally:
            c.shutdown()
    out["speedup_vs_internal"] = out["internal_io_s"] / out["fix_s"]
    out["speedup_vs_client_serial"] = out["client_serial_s"] / out["fix_s"]
    return out


def fig_chaos(n_seeds: int = 12) -> dict:
    """Recovery overhead under the PR-6 fault-injection plane: each seed
    runs its chaos workload clean, derives an injection schedule scaled
    to the clean makespan (node churn, link flaps, drops, corruption),
    and re-runs it with recovery enabled — all on the virtual clock.

    Reported per sweep: how many jobs completed vs failed-attributed,
    and the makespan overhead the recovery machinery pays (retries,
    failover, recompute) relative to each seed's clean run.  The
    correctness half — completed results bit-identical to clean, every
    failure typed, zero trace-invariant violations — is asserted here
    too, so a regression fails the benchmark rather than skewing it."""
    sys.path.insert(0, "tests")
    from workloads import run_chaos_case

    overheads, completed, failed = [], 0, 0
    injected = 0
    for seed in range(n_seeds):
        r = run_chaos_case(seed)
        assert not r["mismatches"], (seed, r["mismatches"])
        assert not r["bad_failures"], (seed, r["bad_failures"])
        assert not r["violations"], (seed, r["violations"])
        injected += r["n_faults"]
        for kind, _val in r["outcomes"]:
            if kind == "ok":
                completed += 1
            else:
                failed += 1
        overheads.append(r["fault_makespan"] / max(r["clean_makespan"], 1e-9))
    overheads.sort()
    return {
        "seeds": n_seeds,
        "faults_injected": injected,
        "jobs_completed": completed,
        "jobs_failed_attributed": failed,
        "recovery_overhead_median": overheads[len(overheads) // 2],
        "recovery_overhead_max": overheads[-1],
        "all_traces_clean": True,
    }


def fig_remote_chaos(n_seeds: int = 6) -> dict:
    """Recovery overhead on the *real* multi-process backend: the same
    workload runs once clean and then under ``seeded_chaos`` schedules
    (worker SIGKILLs, control-frame truncation, at-rest store rot,
    heartbeat stalls) injected into live sockets and processes.

    Reported per sweep: clean vs faulted wall time, plus what the
    recovery machinery actually spent — worker respawns, job resubmits,
    quarantines and store dup-puts (the at-least-once re-execution tax,
    absorbed by content addressing).  Correctness is asserted, not
    sampled: every job either returns bytes identical to the clean run
    or raises one of the attributed typed errors."""
    from repro.core.repository import CorruptData, MissingData
    from repro.core.stdlib import fib
    from repro.fix.future import CancelledError, DeadlineExceeded
    from repro.remote import RemoteBackend, RemoteError, WorkerCrashed
    from repro.remote.chaos import seeded_chaos
    from repro.runtime.faults import TransferFailed

    typed = (WorkerCrashed, CorruptData, TransferFailed, DeadlineExceeded,
             CancelledError, MissingData, RemoteError)

    def programs(repo):
        tree = repo.put_tree(
            [repo.put_blob(bytes([i]) * 1024) for i in range(4)])
        return [fib(8), add(21, 21), inc_chain(0, 4), checksum_tree(tree)]

    with fix.local() as lb:
        baseline = [lb.evaluate(p).raw for p in programs(lb.repo)]

    def run_once(chaos):
        kw = dict(n_workers=2, chaos=chaos, heartbeat_s=0.1,
                  heartbeat_miss_budget=3, heartbeat_timeout_s=0.2,
                  retry_backoff_s=0.02, drain_timeout_s=15.0)
        t0 = time.perf_counter()
        ok = bad = 0
        with RemoteBackend(**kw) as be:
            futs = [be.submit(p) for p in programs(be.repo)]
            for f, want in zip(futs, baseline):
                try:
                    got = f.result(timeout=120)
                except typed:
                    bad += 1
                else:
                    assert got.raw == want, "faulted run diverged from clean"
                    ok += 1
            st = be.stats()
        return time.perf_counter() - t0, ok, bad, st

    clean_s, ok, bad, _ = run_once(None)
    assert bad == 0, "clean remote run must not fail"

    overheads, completed, failed = [], 0, 0
    respawns = resubmits = quarantines = dup_puts = 0
    for seed in range(n_seeds):
        chaos = seeded_chaos(seed, ["w0", "w1"], n_faults=2,
                             kinds=("kill", "truncate", "rot", "stall"))
        faulted_s, ok, bad, st = run_once(chaos)
        completed += ok
        failed += bad
        rec = st["recovery"]
        respawns += rec["respawns"]
        resubmits += rec["resubmits"]
        quarantines += rec["quarantines"]
        dup_puts += st["store"]["dup_puts"]
        overheads.append(faulted_s / max(clean_s, 1e-9))
    overheads.sort()
    return {
        "seeds": n_seeds,
        "clean_s": clean_s,
        "jobs_completed": completed,
        "jobs_failed_attributed": failed,
        "respawns": respawns,
        "resubmits": resubmits,
        "quarantines": quarantines,
        "dup_puts": dup_puts,
        "faulted_overhead_median": overheads[len(overheads) // 2],
        "faulted_overhead_max": overheads[-1],
    }


def fig_serving(n_requests: int = 2000, seed: int = 0) -> dict:
    """Production serving on the Fix core: continuous batching with
    memoized-prefix KV reuse vs the no-memo ablation, on the simulated
    cluster under a virtual clock.

    Traffic is the seeded generator from ``tests/workloads.py`` — Zipf
    popularity over a shared-prefix pool, multi-tenant tags, ragged tails
    and budgets.  The memoized run and the ablation (every request's
    chain salted by a per-request nonce, so identical prefixes stop
    folding) must produce **bit-identical token streams** — the ablation
    differs only in placement/recompute, never in values — and the memo
    run must convert > 0 prefill bytes into cache hits while the
    ablation converts exactly 0.  Both are asserted, so a correctness
    regression fails the benchmark instead of skewing it.

    Latencies are virtual-clock seconds (queueing + staging + compute in
    the seconds-to-stage model); per-tenant attribution comes from the
    tenant-tagged trace (``tenant_report``), with starvation seconds
    from the same ``starvation_intervals`` analysis PR 4 introduced."""
    sys.path.insert(0, "tests")
    from workloads import make_serving_spec, run_serving

    from repro.runtime import TraceRecorder
    from repro.runtime.trace import tenant_report, verify_invariants

    spec = make_serving_spec(seed, n_requests=n_requests)
    tr = TraceRecorder()
    t0 = time.perf_counter()
    memo = run_serving(spec, backend="simulated", trace=tr)
    memo_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    abl = run_serving(spec, backend="simulated", prefix_memo=False)
    abl_wall = time.perf_counter() - t0

    assert memo["errors"] == [] and abl["errors"] == []
    assert memo["streams"] == abl["streams"], \
        "memoized streams diverged from the no-memo ablation"
    rm, ra = memo["report"], abl["report"]
    assert rm["prefill_bytes_hit"] > 0, "memo run never hit a prefix block"
    assert ra["prefill_bytes_hit"] == 0, "ablation must never hit"
    assert verify_invariants(tr.events) == []

    tenants = tenant_report(tr.events)
    tagged = {t: s for t, s in tenants.items() if t != "-"}
    return {
        "requests": n_requests,
        "tenants": len(tagged),
        "streams_bit_identical": True,
        "hit_ratio": rm["hit_ratio"],
        "prefill_bytes_total": rm["prefill_bytes_total"],
        "prefill_bytes_hit_memo": rm["prefill_bytes_hit"],
        "prefill_bytes_hit_ablation": ra["prefill_bytes_hit"],
        "p50_latency_s": rm["p50_latency_s"],
        "p99_latency_s": rm["p99_latency_s"],
        "p99_latency_s_ablation": ra["p99_latency_s"],
        "p99_queue_wait_s": rm["p99_queue_wait_s"],
        "tail_starved_s": sum(s["starved_s"] for s in tenants.values()),
        "max_tenant_p99_s": max(s["p99_latency_s"] for s in tagged.values()),
        "memo_jobs": sum(s["jobs"] for s in tagged.values()),
        "memo_wall_s": memo_wall,
        "ablation_wall_s": abl_wall,
        "per_tenant": {
            t: {"jobs": s["jobs"], "finished": s["finished"],
                "p50_latency_s": s["p50_latency_s"],
                "p99_latency_s": s["p99_latency_s"],
                "starved_s": s["starved_s"]}
            for t, s in sorted(tagged.items())},
    }


# ------------------------------------------------------------------- obs
def fig_obs(n_jobs: int = 64, inputs_per_job: int = 16, blob_kb: int = 8,
            reps: int = 7) -> dict:
    """Telemetry overhead: the same VirtualClock staging workload with the
    metrics registry on vs off.

    Two claims, both load-bearing for always-on telemetry: the simulated
    makespan is *identical* either way (metrics are pure arithmetic and
    never touch the clock — the golden-trace guarantee measured rather
    than asserted), and the wall-clock cost of keeping them on is small
    (<5%, pinned by the CI obs-smoke job).  Reps interleave the two modes
    (warmup and machine drift hit both equally) and wall time is
    min-of-reps — the noise floor, not the noise.
    """
    rng = np.random.default_rng(0)
    payloads = [[rng.integers(0, 255, blob_kb * 1024).astype(np.uint8)
                 .tobytes() for _ in range(inputs_per_job)]
                for _ in range(n_jobs)]
    walls = {"off": float("inf"), "on": float("inf")}
    makespans: dict = {}
    for rep in range(reps):
        for mode in ("off", "on"):
            net = Network(Link(latency_s=0.003, gbps=10))
            clk = VirtualClock()
            c = Cluster(n_nodes=3, workers_per_node=2,
                        storage_nodes=("s0",), network=net, clock=clk,
                        metrics=(mode == "on"))
            try:
                be = fix.on(c)
                store = c.nodes["s0"].repo
                jobs = [checksum_tree(store.put_tree(
                    [store.put_blob(b) for b in blobs]))
                    for blobs in payloads]
                t0 = time.perf_counter()
                futs = [be.submit(j) for j in jobs]
                for f in be.as_completed(futs, timeout=600):
                    f.result(timeout=0)
                walls[mode] = min(walls[mode],
                                  time.perf_counter() - t0)
                makespans[mode] = clk.now()
            finally:
                c.shutdown()
                clk.close()
    out: dict = {}
    for mode in ("off", "on"):
        out[f"{mode}_wall_s"] = walls[mode]
        out[f"{mode}_makespan_s"] = makespans[mode]
    out["makespan_equal"] = out["on_makespan_s"] == out["off_makespan_s"]
    out["overhead_frac"] = out["on_wall_s"] / out["off_wall_s"] - 1.0
    return out
