"""Pallas kernel sweeps: interpret-mode execution vs pure-jnp oracles
across shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels import ops as kops
from repro.kernels.ref import (
    decode_attention_ref,
    flash_attention_ref,
    rmsnorm_ref,
    ssd_scan_ref,
)


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("B,S,H,hd", [(1, 128, 1, 64), (2, 256, 2, 64),
                                      (1, 512, 4, 128), (2, 128, 2, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, hd, dtype, causal):
    q = _rand(0, (B, S, H, hd), dtype)
    k = _rand(1, (B, S, H, hd), dtype)
    v = _rand(2, (B, S, H, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_asymmetric_v_dim():
    """MLA: v head dim != q/k head dim."""
    q = _rand(0, (1, 128, 2, 64), jnp.float32)
    k = _rand(1, (1, 128, 2, 64), jnp.float32)
    v = _rand(2, (1, 128, 2, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_blocked_attention_matches_ref():
    """The jnp twin used by the dry-run must match the oracle too."""
    q = _rand(0, (2, 256, 2, 64), jnp.float32)
    k = _rand(1, (2, 256, 2, 64), jnp.float32)
    v = _rand(2, (2, 256, 2, 64), jnp.float32)
    for causal in (True, False):
        out = kops.blocked_attention(q, k, v, causal=causal, block_k=96)
        ref = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,T,H,hd,length", [(1, 256, 2, 64, 100),
                                             (2, 512, 1, 128, 512),
                                             (2, 128, 4, 32, 1)])
def test_decode_attention_sweep(B, T, H, hd, length):
    q = _rand(0, (B, 1, H, hd), jnp.float32)
    k = _rand(1, (B, T, H, hd), jnp.float32)
    v = _rand(2, (B, T, H, hd), jnp.float32)
    out = decode_attention(q, k, v, length, block_k=64, interpret=True)
    ref = decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [(4, 64), (2, 8, 128), (3, 5, 7, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = _rand(0, shape, dtype)
    w = 1.0 + 0.1 * _rand(1, shape[-1:], jnp.float32)
    out = rmsnorm_kernel(x, w, block_rows=8, interpret=True)
    ref = rmsnorm_ref(x, w)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [(1, 64, 1, 16, 16, 16),
                                             (2, 128, 2, 32, 32, 32),
                                             (1, 96, 3, 16, 64, 32)])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    x = _rand(0, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(1, (B, S, H), jnp.float32))
    A = -jnp.exp(_rand(2, (H,), jnp.float32) * 0.3)
    B_ = _rand(3, (B, S, N), jnp.float32) * 0.5
    C_ = _rand(4, (B, S, N), jnp.float32) * 0.5
    y, state = ssd_scan(x, dt, A, B_, C_, chunk, interpret=True)
    y_ref, state_ref = ssd_scan_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(y, y_ref, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(state, state_ref, atol=5e-4, rtol=5e-4)


def test_ssd_chunked_model_path_matches_oracle():
    """models.mamba2.ssd_chunked (the jnp path the dry-run lowers) vs the
    sequential recurrence."""
    from repro.models.mamba2 import ssd_chunked

    B, S, H, P, N = 2, 80, 2, 16, 24
    x = _rand(0, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(1, (B, S, H), jnp.float32))
    A = -jnp.exp(_rand(2, (H,), jnp.float32) * 0.3)
    B_ = _rand(3, (B, S, N), jnp.float32) * 0.5
    C_ = _rand(4, (B, S, N), jnp.float32) * 0.5
    y, state = ssd_chunked(x, dt, A, B_, C_, chunk=32)
    y_ref, state_ref = ssd_scan_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(y, y_ref, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(state, state_ref, atol=5e-4, rtol=5e-4)
