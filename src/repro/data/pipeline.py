"""Content-addressed data pipeline: shards as Fix thunks.

A training corpus is a content-addressed Blob; shards are *derived values*
— ``slice_blob(corpus, offset, len)`` Application Thunks — so a shard's
identity is its recipe, not its bytes.  Consequences the trainer exploits:

* **Recompute-over-transfer** (paper §1's sixth strategy, §6 computational
  GC): a lost shard is re-derived from its thunk instead of re-fetched; the
  Fixpoint cluster does this automatically through lineage.
* **Deterministic global order**: shard k of epoch e is a pure function of
  (corpus hash, k, e) — any worker can re-produce any other worker's batch,
  which is what makes elastic rescale and straggler duplication exact.

Tokenization is byte-level (deterministic, dependency-free); real
deployments would register their tokenizer as another codelet.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from ..core import Handle, Repository
from ..core.stdlib import slice_blob
from ..fix import Backend, Lazy


def synth_corpus(n_bytes: int, seed: int = 0) -> bytes:
    """Deterministic synthetic corpus (zipf-ish byte text)."""
    rng = np.random.default_rng(seed)
    words = [bytes(rng.integers(97, 123, rng.integers(2, 9)).astype(np.uint8))
             for _ in range(512)]
    probs = 1.0 / np.arange(1, 513)
    probs /= probs.sum()
    out = bytearray()
    idx = rng.choice(512, size=n_bytes // 5 + 16, p=probs)
    for i in idx:
        out += words[i] + b" "
        if len(out) >= n_bytes:
            break
    return bytes(out[:n_bytes])


@dataclass
class TokenPipeline:
    """Byte-level LM batches derived from a content-addressed corpus."""

    repo: Repository
    corpus: Handle
    seq_len: int
    batch: int
    vocab: int = 256

    def shard_expr(self, step: int) -> Lazy:
        """The Fix recipe for step ``step``'s bytes (pure function), as a
        typed frontend expression — submit it to any Backend."""
        need = self.batch * (self.seq_len + 1)
        total = self.corpus.size
        offset = (step * need) % max(total - need, 1)
        return slice_blob(self.corpus, offset, need)

    def shard_thunk(self, step: int) -> Handle:
        """The recipe compiled to its Table-1 Application Thunk handle
        (byte-identical to the hand-built ``combination`` tree)."""
        return self.shard_expr(step).compile(self.repo)

    def materialize(self, shard_bytes: bytes):
        """bytes -> {tokens, labels} int32 arrays (numpy; cast on device)."""
        need = self.batch * (self.seq_len + 1)
        arr = np.frombuffer(shard_bytes[:need], dtype=np.uint8).astype(np.int32)
        arr = arr % self.vocab
        arr = arr.reshape(self.batch, self.seq_len + 1)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def batch_for_step(self, engine, step: int):
        """Shard bytes -> arrays via a Backend or a bare local Evaluator."""
        if isinstance(engine, Backend):
            return self.materialize(engine.fetch(self.shard_expr(step),
                                                 as_type=bytes))
        th = self.shard_thunk(step)
        out = engine.evaluate(th.strict())
        return self.materialize(self.repo.get_blob(out))


def corpus_handle(repo: Repository, n_bytes: int = 1 << 20, seed: int = 0) -> Handle:
    return repo.put_blob(synth_corpus(n_bytes, seed))
