"""Roofline terms from a compiled dry-run artifact (no real hardware).

compute   = per-device HLO FLOPs / peak bf16 FLOP/s
memory    = per-device HLO bytes accessed / HBM bandwidth
collective= per-device collective payload bytes / ICI link bandwidth
            (all-reduce counted 2x: bidirectional-ring cost 2(n-1)/n ~ 2;
             all-gather / reduce-scatter / all-to-all / permute counted 1x)

``cost_analysis()`` on the SPMD-partitioned executable reports *per-device*
numbers (verified in tests), so terms divide by per-chip peaks — identical
to global/(chips * peak).  Collective payloads are parsed from the
partitioned HLO text: shapes on collective ops are per-device shard shapes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e (assignment constants)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (effective, one direction)
DCN_BW = 25e9                 # bytes/s per chip across pods (assumed)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[\w\[\],\s]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device payload bytes by collective kind (result-shape accounting)."""
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_text, kind, _start = m.group(1), m.group(2), m.group(3)
        nbytes = _shape_bytes(shape_text)
        out[kind] = out.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes": out, "counts": counts}


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll: dict = field(default_factory=dict)
    model_flops: float = 0.0
    xla_raw: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        b = self.coll.get("bytes", {})
        weighted = sum(v * (2.0 if k == "all-reduce" else 1.0) for k, v in b.items())
        return weighted / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at the
        dominant term's speed: useful_model_time / bound_time."""
        ideal = self.model_flops / PEAK_FLOPS_BF16
        return ideal / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collectives": self.coll,
            "model_flops_per_device": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
            "xla_raw_body_once": self.xla_raw,
        }


def from_compiled(compiled, model_flops_per_device: float = 0.0) -> Roofline:
    """Loop-aware rollup (see hlo_cost): XLA's cost_analysis counts while
    bodies once, so scanned models undercount by the trip count.  We parse
    the partitioned HLO and multiply by static trip counts; the raw XLA
    numbers ride along as a cross-check."""
    from .hlo_cost import HloModuleCost

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    hc = HloModuleCost(compiled.as_text())
    rf = Roofline(
        flops=hc.flops(),
        hbm_bytes=hc.hbm_bytes(),
        coll=hc.collective_bytes(),
        model_flops=model_flops_per_device,
    )
    rf.xla_raw = {"flops_body_once": float(ca.get("flops", 0.0)),
                  "bytes_body_once": float(ca.get("bytes accessed", 0.0))}
    return rf


# --------------------------------------------------------- model FLOPs (6ND)
def model_flops_per_step(cfg, mode: str, batch: int, seq: int, n_devices: int) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference; N = active params.
    For decode, D = batch tokens (one step); attention/KV-history FLOPs are
    excluded by convention (this is the *useful compute* yardstick)."""
    from ..models import count_params, ops_for

    import numpy as np

    specs = ops_for(cfg).specs(cfg)
    n_params = count_params(specs)
    if cfg.n_experts:
        # active = non-expert params + top_k/E of expert params; in the moe
        # family, w_gate/w_up/w_down under "layers" ARE the stacked expert
        # tensors (shared/residual paths have distinct names)
        from ..models.base import _leaf_paths

        expert_params = sum(
            int(np.prod(s.shape))
            for p, s in _leaf_paths(specs)
            if "layers" in p and p[-1] in ("w_gate", "w_up", "w_down")
        )
        n_params = n_params - expert_params + expert_params * cfg.top_k / cfg.n_experts
    tokens = batch * (seq if mode in ("train", "prefill") else 1)
    per_param = 6.0 if mode == "train" else 2.0
    return per_param * n_params * tokens / n_devices
