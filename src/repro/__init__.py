"""repro: the Fix computation model + Fixpoint runtime + a TPU-pod-scale
ML framework built on its principles.  See README.md."""
__version__ = "1.0.0"
