"""Quickstart: the Fix computation model in five minutes.

Programs are written against ``repro.fix`` — typed codelets, lazy
expression graphs, one Backend protocol — and compile down to the paper's
Table-1 representation (handles, combination trees, Encodes).  Section 5
shows the compiled form next to the hand-built one: byte-identical.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import repro.fix as fix
from repro.core import Handle
from repro.core.stdlib import add, combination, fib, fix_if
from repro.runtime import Cluster, Link, Network


def main() -> None:
    # --- 1. typed codelets + a local backend ------------------------------
    # add(40, 2) runs nothing: it builds a lazy expression.  The backend
    # compiles it to a thunk, evaluates, and decodes the result type.
    with fix.local() as be:
        print("40 + 2 =", be.run(add(40, 2)))

        # memoization: the compiled thunk IS the cache key
        before = be.evaluator.applications
        be.run(add(40, 2))
        print("re-evaluation ran", be.evaluator.applications - before,
              "codelets (memo hit)")

        # --- 2. laziness: the untaken branch never evaluates --------------
        # fix_if's branches are Handle-typed, so they stay *names*: the bomb
        # (adding non-integers — raw Handles pass through the typed layer
        # unchecked, exactly like hand-built trees) is never run.
        bomb = add(Handle.blob(b"not-an-int"), Handle.blob(b"x"))
        out = be.fetch(fix_if(True, add(1, 2), bomb), as_type=int)
        print("lazy if ->", out)

        # --- 3. selection sugar: touch one child of a big tree ------------
        kids = tuple(bytes([i]) * 1000 for i in range(100))
        sel = fix.lit(kids)[42]
        print("selected child 42, first byte:", be.run(sel)[0])

        # deep composition is still ONE submission: a whole expression DAG
        total = add(add(1, 2), add(add(3, 4), 5))
        print("nested adds =", be.run(total))

    # --- 4. the same program on a 3-node cluster ---------------------------
    cluster = Cluster(n_nodes=3, workers_per_node=2,
                      network=Network(Link(latency_s=0.001, gbps=10)))
    with fix.on(cluster) as be:
        print("fib(15) on the cluster =", be.run(fib(15), timeout=60))
        print("bytes moved:", cluster.bytes_moved,
              " transfers:", cluster.transfers)

    # --- 5. the same program on real worker processes ----------------------
    # fix.remote() forks OS processes speaking a framed socket protocol;
    # every inter-worker byte routes through a content-addressed object
    # store.  Same Backend protocol, byte-identical result content keys.
    with fix.local() as be:
        local_key = be.evaluate(fib(15)).raw
    with fix.remote(n_workers=2) as be:
        print("fib(15) on", len(be._workers), "worker processes =",
              be.run(fib(15), timeout=60))
        print("remote == local content key:",
              be.evaluate(fib(15)).raw == local_key)
        st = be.stats()
        print("store objects:", st["store"]["objects"],
              " transfers:", st["transfers"])

    # --- 6. what it compiles to: the shared Table-1 representation ---------
    # A typed call lowers to the combination tree [limits, procedure, args]
    # — byte-identical to building it by hand against the raw core.  Users,
    # programs and the platform share one representation of the computation.
    from repro.core import Repository
    repo = Repository()
    typed = add(40, 2).compile(repo)
    hand = combination(repo, "add",
                       Handle.blob((40).to_bytes(8, "little", signed=True)),
                       Handle.blob((2).to_bytes(8, "little", signed=True)))
    print("typed call == hand-built combination:", typed.raw == hand.raw)
    print("compiled form:", typed)


if __name__ == "__main__":
    main()
