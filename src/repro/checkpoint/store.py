"""Content-addressed checkpointing with structural dedup + elastic restore.

A checkpoint is a Fix Tree: each array leaf serializes to a Blob (dtype +
shape header + bytes), nested dicts become Trees.  Content addressing gives
three properties production trainers pay for separately:

* **Dedup across steps**: unchanged leaves (frozen embeddings, the shared
  Zamba2 attention block, optimizer scalars) hash identically — a save
  writes only deltas.
* **Integrity**: a handle *is* a checksum; partial/corrupt writes are
  unrepresentable.
* **Elastic restore**: arrays are stored unsharded-logical; a restore onto
  a different mesh re-shards by simply device_put'ing with the new step's
  NamedShardings (the Fix view: placement is the platform's business, the
  checkpoint names only the values).
"""
from __future__ import annotations

import json
from typing import Optional

import jax
import numpy as np

from ..core import Handle, Repository


def _encode_array(arr: np.ndarray) -> bytes:
    hdr = json.dumps({"dtype": str(arr.dtype), "shape": list(arr.shape)}).encode()
    return len(hdr).to_bytes(4, "little") + hdr + arr.tobytes()


def _decode_array(raw: bytes) -> np.ndarray:
    n = int.from_bytes(raw[:4], "little")
    meta = json.loads(raw[4 : 4 + n])
    return np.frombuffer(raw[4 + n:], dtype=meta["dtype"]).reshape(meta["shape"])


_KEY_PREFIX = b"k:"


def save_tree(repo: Repository, tree) -> Handle:
    """Pytree (nested dicts of arrays/scalars) -> content-addressed Tree.

    Dict nodes become Trees of [key-blob, value, key-blob, value, ...] in
    sorted key order (deterministic canonical form).
    """
    if isinstance(tree, dict):
        children = []
        for k in sorted(tree):
            children.append(repo.put_blob(_KEY_PREFIX + k.encode()))
            children.append(save_tree(repo, tree[k]))
        return repo.put_tree(children)
    arr = np.asarray(jax.device_get(tree))
    return repo.put_blob(_encode_array(arr))


def load_tree(repo: Repository, handle: Handle, shardings=None):
    """Tree handle -> pytree.  With ``shardings`` (a matching pytree of
    NamedShardings) arrays are placed directly onto the (possibly new) mesh."""
    if handle.content_type == 1:  # TREE
        kids = repo.get_tree(handle)
        out = {}
        for i in range(0, len(kids), 2):
            key = repo.get_blob(kids[i])[len(_KEY_PREFIX):].decode()
            sub = None
            if isinstance(shardings, dict):
                sub = shardings.get(key)
            out[key] = load_tree(repo, kids[i + 1], sub)
        return out
    arr = _decode_array(repo.get_blob(handle))
    if shardings is not None and not isinstance(shardings, dict):
        return jax.device_put(arr, shardings)
    return arr


def save_step(repo: Repository, state, step: int,
              manifest: Optional[dict] = None) -> Handle:
    """Checkpoint = Tree [meta, state-tree].  Returns the root handle —
    32 bytes that name the entire training state."""
    meta = dict(manifest or {}, step=step)
    meta_h = repo.put_blob(json.dumps(meta, sort_keys=True).encode())
    state_h = save_tree(repo, state)
    return repo.put_tree([meta_h, state_h])


def load_step(repo: Repository, root: Handle, shardings=None):
    meta_h, state_h = repo.get_tree(root)
    meta = json.loads(repo.get_blob(meta_h))
    return meta, load_tree(repo, state_h, shardings)


def dedup_stats(repo: Repository, roots: list) -> dict:
    """How much a chain of checkpoints shares (the content-address dividend)."""
    total_refs = 0
    unique: set = set()
    for root in roots:
        stack = [root]
        while stack:
            h = stack.pop()
            if h.content_type == 1 and repo.contains(h):
                stack.extend(repo.get_tree(h))
            else:
                total_refs += 1
                if not h.is_literal:
                    unique.add(h.content_key())
    return {"leaf_refs": total_refs, "unique_leaves": len(unique)}
