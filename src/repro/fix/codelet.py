"""Typed codelets: Python signatures compiled to Table-1 shims.

``@fix.codelet`` reads a function's annotations and generates both halves
of the boundary:

* an **unmarshal shim**, registered in the ordinary procedure registry
  under ``fix/proc/<name>`` — at apply time it decodes the combination's
  argument handles into real Python values through the sealed
  :class:`~repro.core.api.FixAPI` (still the only I/O path), calls the
  body, and marshals the return value back to a Handle.  A body may also
  return a Handle directly, or a :class:`~repro.fix.lazy.Lazy` expression —
  the latter compiles through the same capability into a tail-call Thunk,
  so typed codelets recurse exactly like hand-written ones.
* a **client-side constructor**: calling the decorated object builds a
  :class:`~repro.fix.lazy.Lazy` call node, not an invocation.

Because the shim is a plain registered procedure, hand-built
``combination(repo, name, ...)`` trees keep working unchanged and evaluate
through the very same code — one representation, two spellings.
"""
from __future__ import annotations

import inspect
import typing
from typing import Any, Callable, Optional

from ..core.handle import BLOB, TREE, Handle
from ..core.procedures import make_limits, procedure_blob, register
from .lazy import _CALL, Lazy
from .marshal import (
    ApiEmitter,
    ApiReader,
    MarshalError,
    marshal,
    unmarshal,
    validate_hint,
)

#: Default resource-limit blob for typed calls — identical bytes to the raw
#: helper's default (``stdlib.LIMITS_SMALL``), so typed and hand-built
#: combinations share content keys.
DEFAULT_LIMITS = make_limits(ram_bytes=1 << 16)


def _is_default(value, default) -> bool:
    """True when ``value`` can be elided from the combination because the
    shim's default reproduces it.  Conservative: anything that can't prove
    equality (Lazy refuses ``__bool__``, numpy returns arrays, ...) travels
    explicitly."""
    if value is default:
        return True
    try:
        return bool(value == default)
    except Exception:  # noqa: BLE001 — equality probe only
        return False


class TypedCodelet:
    """A registered procedure plus its typed client-side constructor."""

    def __init__(self, fn: Callable, name: str, limits: bytes):
        self.fn = fn
        self.name = name
        self.limits = limits
        self.proc_payload = procedure_blob(name)
        self.__name__ = fn.__name__
        self.__doc__ = fn.__doc__
        self.__wrapped__ = fn

        self._sig = inspect.signature(fn)
        hints = typing.get_type_hints(fn)
        self.param_hints: list[Any] = []
        # Parameters without defaults are *required* and always travel
        # positionally in the combination; parameters with defaults are
        # *optional* and travel — only when overridden — in a trailing
        # kwargs Tree, so adding a defaulted parameter never changes the
        # content keys of existing call sites.
        self.required: list[tuple[str, Any]] = []
        self.optional: list[tuple[str, Any, Any]] = []
        for p in self._sig.parameters.values():
            if p.kind in (inspect.Parameter.VAR_POSITIONAL,
                          inspect.Parameter.VAR_KEYWORD):
                raise MarshalError(
                    f"codelet {name!r}: *args/**kwargs are not marshallable — "
                    f"take a list/tuple parameter instead")
            if p.kind is inspect.Parameter.POSITIONAL_ONLY:
                raise MarshalError(
                    f"codelet {name!r}: positional-only parameters are not "
                    f"supported (kwargs travel by name)")
            if p.name not in hints:
                raise MarshalError(
                    f"codelet {name!r}: parameter {p.name!r} needs a type "
                    f"annotation (int, bytes, str, bool, tuple/list, Handle)")
            hint = hints[p.name]
            validate_hint(hint)
            self.param_hints.append(hint)
            if p.default is inspect.Parameter.empty:
                if self.optional:
                    raise MarshalError(
                        f"codelet {name!r}: required parameter {p.name!r} "
                        f"follows a defaulted one")
                self.required.append((p.name, hint))
            else:
                self.optional.append((p.name, hint, p.default))
        self._opt_hints = {n: h for n, h, _ in self.optional}
        self.return_hint = hints.get("return")
        if self.return_hint is not None:
            validate_hint(self.return_hint)

        def _registered(api, comb, _self=self):  # plain function: the
            return _self._shim(api, comb)        # registry tags attributes
        _registered.__name__ = f"{name}.shim"
        _registered.__qualname__ = f"TypedCodelet({name}).shim"
        register(name)(_registered)
        self.shim = _registered

    # ------------------------------------------------------- server side
    def _shim(self, api, comb: Handle) -> Handle:
        kids = api.read_tree(comb)
        arg_handles = list(kids[2:])  # [limits, procedure, arg...]
        n_req = len(self.required)
        overrides: dict[str, Handle] = {}
        if self.optional and len(arg_handles) == n_req + 1:
            kw = self._parse_kwargs_tree(api, arg_handles[-1])
            if kw is not None:
                overrides = kw
                arg_handles = arg_handles[:-1]
        if (self.optional and not overrides
                and len(arg_handles) == len(self.param_hints)):
            # Legacy spelling: a combination minted before these parameters
            # grew defaults carries them positionally.  Same shim, same key.
            for (pname, _h, _d), h in zip(self.optional, arg_handles[n_req:]):
                overrides[pname] = h
            arg_handles = arg_handles[:n_req]
        if len(arg_handles) != n_req:
            raise MarshalError(
                f"codelet {self.name!r} takes {n_req} required "
                f"argument(s), combination supplies {len(arg_handles)}")
        reader = ApiReader(api)
        values = {pname: unmarshal(reader, h, hint)
                  for (pname, hint), h in zip(self.required, arg_handles)}
        for pname, hint, default in self.optional:
            h = overrides.get(pname)
            values[pname] = default if h is None else unmarshal(reader, h, hint)
        out = self.fn(**values)
        if isinstance(out, Handle):
            return out  # raw handle (data, or a hand-rolled tail call)
        if isinstance(out, Lazy):
            return out.compile(ApiEmitter(api))  # typed tail call
        return marshal(ApiEmitter(api), out, self.return_hint)

    def _parse_kwargs_tree(self, api, h: Handle) -> Optional[dict]:
        """``{name: value-handle}`` if ``h`` is a kwargs Tree, else None.

        A kwargs Tree is a non-empty Tree of ``[utf8-name-blob, value]``
        pairs whose names are all (distinct) optional parameters of this
        codelet.  Anything else — including the pathological value that
        happens to be pair-shaped but names no known parameter — reads as
        an ordinary positional argument.
        """
        if h.content_type != TREE or not h.is_data():
            return None
        try:
            pairs = api.read_tree(h)
        except Exception:  # noqa: BLE001 — shape probe, not a read path
            return None
        if not pairs:
            return None
        out: dict[str, Handle] = {}
        for pair in pairs:
            if pair.content_type != TREE or not pair.is_data():
                return None
            try:
                pk = api.read_tree(pair)
            except Exception:  # noqa: BLE001
                return None
            if len(pk) != 2:
                return None
            name_h, val_h = pk
            if name_h.content_type != BLOB or not name_h.is_data():
                return None
            try:
                pname = api.read_blob(name_h).decode("utf-8")
            except Exception:  # noqa: BLE001
                return None
            if pname not in self._opt_hints or pname in out:
                return None
            out[pname] = val_h
        return out

    # ------------------------------------------------------- client side
    def __call__(self, *args, **kwargs) -> Lazy:
        try:
            bound = self._sig.bind(*args, **kwargs)
        except TypeError as e:
            raise MarshalError(f"codelet {self.name!r}: {e}") from None
        ordered = []
        overrides = []
        for pname, p in self._sig.parameters.items():
            if p.default is inspect.Parameter.empty:
                ordered.append(bound.arguments[pname])
            elif pname in bound.arguments:
                v = bound.arguments[pname]
                if not _is_default(v, p.default):
                    overrides.append((pname, v))
        return Lazy(_CALL, codelet=self, args=ordered, kwargs=overrides,
                    out_type=self.return_hint)

    def __repr__(self) -> str:
        params = ", ".join(
            f"{p}: {getattr(h, '__name__', h)}"
            for p, h in zip(self._sig.parameters, self.param_hints))
        return f"<fix.codelet {self.name}({params})>"


def codelet(fn: Optional[Callable] = None, *, name: Optional[str] = None,
            limits: bytes = DEFAULT_LIMITS):
    """Decorator: turn an annotated function into a :class:`TypedCodelet`.

    ``@codelet`` and ``@codelet(name="add", limits=...)`` both work.
    ``limits`` is the resource-limit blob placed first in every combination
    this codelet's calls compile to.
    """
    def deco(f: Callable) -> TypedCodelet:
        return TypedCodelet(f, name or f.__name__, limits)

    return deco(fn) if fn is not None else deco
