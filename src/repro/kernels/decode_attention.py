"""Single-query (decode) attention Pallas kernel.

Decode is memory-bound: one query row attends over a long KV history.  The
kernel streams KV blocks through VMEM with an online-softmax carry, so the
[T, hd] cache is read exactly once per step — the roofline for decode —
and masked slots (beyond ``length``) never contribute.  This is the
fine-grained "selection thunk" view of a KV cache: the step's minimum
repository is the valid prefix, fetched block by block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int, kv_blocks: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                     # [1, hd] single query row
    k = k_ref[0]                                     # [block_k, hd]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k, v, length, *, block_k: int = 512,
                     interpret: bool = False):
    """q: [B,1,H,hd]  k,v: [B,T,H,hd]  length: [] int32 (valid prefix)."""
    B, _, H, hd = q.shape
    T = k.shape[1]
    block_k = min(block_k, T)
    assert T % block_k == 0
    kv_blocks = T // block_k
    scale = 1.0 / np.sqrt(hd)

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, 1, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                          kv_blocks=kv_blocks),
        grid=(B * H, kv_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(length, qt, kt, vt)
    return out.reshape(B, H, 1, hd).transpose(0, 2, 1, 3)
