"""Integration tests for the Fixpoint cluster runtime.

Written against the ``repro.fix`` frontend (typed codelets + Backend) —
which compiles to byte-identical Table-1 submissions, so these exercise
exactly the same scheduler paths as the raw spelling.  The raw-core
spelling stays pinned in tests/test_core.py and tests/test_transfers.py.
"""
import math
import time

import repro.fix as fix
from repro.core import Handle
from repro.core.stdlib import add, count_string, fib, fix_if, identity, inc_chain, slice_blob
from repro.runtime import Cluster, Link, Network, VirtualClock


def make_cluster(**kw) -> Cluster:
    kw.setdefault("n_nodes", 3)
    kw.setdefault("workers_per_node", 2)
    kw.setdefault("network", Network(Link(latency_s=0.0005, gbps=10)))
    return Cluster(**kw)


class TestClusterBasics:
    def test_simple_add(self):
        c = make_cluster()
        try:
            assert fix.on(c).run(add(20, 22), timeout=30) == 42
        finally:
            c.shutdown()

    def test_tail_call_chain_single_submission(self):
        c = make_cluster()
        try:
            assert fix.on(c).run(inc_chain(0, 100), timeout=60) == 100
        finally:
            c.shutdown()

    def test_parallel_fanout_fib(self):
        c = make_cluster()
        try:
            assert fix.on(c).run(fib(12), timeout=60) == 144
        finally:
            c.shutdown()

    def test_memoized_resubmission_is_instant(self):
        c = make_cluster()
        try:
            be = fix.on(c)
            be.evaluate(add(1, 2), timeout=30)
            t0 = time.perf_counter()
            be.evaluate(add(1, 2), timeout=30)
            assert time.perf_counter() - t0 < 0.05  # memo hit, no re-execution
        finally:
            c.shutdown()

    def test_lazy_branch_not_fetched(self):
        """fig 2: the untaken branch's minimum repository never moves."""
        c = make_cluster()
        try:
            be = fix.on(c)
            big = be.repo.put_blob(b"B" * 500_000)  # lives only on client
            bomb = identity(big)
            out = be.fetch(fix_if(True, add(5, 6), bomb),
                           as_type=int, timeout=30)
            assert out == 11
            # the 500 kB blob never left the client
            for n in c.worker_nodes():
                assert not n.repo.contains(big)
        finally:
            c.shutdown()

    def test_selection_moves_node_not_children(self):
        """fig 4 / B+-tree property: selecting a child of a Tree ships the
        32-byte-per-child node, not the children's data."""
        c = make_cluster()
        try:
            be = fix.on(c)
            kids = [be.repo.put_blob(bytes([i]) * 100_000) for i in range(8)]
            tree = be.repo.put_tree(kids)
            out = be.evaluate(fix.lit(tree)[2].shallow(), timeout=30)
            assert out.is_ref() and out.size == 100_000
            # selection ran without moving any 100 kB child
            moved = sum(1 for n in c.worker_nodes() for k in kids if n.repo.contains(k))
            assert moved == 0
        finally:
            c.shutdown()


class TestPlacement:
    def test_locality_places_near_data(self):
        c = make_cluster(n_nodes=4)
        try:
            be = fix.on(c)
            # park a large shard on n2
            shard = Handle.blob(b"x" * 1_000_000)
            c.nodes["n2"].repo.put_blob(b"x" * 1_000_000)
            assert be.run(count_string(shard, b"xx"), timeout=30) == 500_000
            assert c.nodes["n2"].jobs_run >= 1  # ran where the data lives
            assert c.bytes_moved < 10_000  # the shard did not move
        finally:
            c.shutdown()

    def test_random_placement_moves_data(self):
        c = make_cluster(n_nodes=4, placement="random", seed=7)
        try:
            c.nodes["n2"].repo.put_blob(b"y" * 1_000_000)
            shard = Handle.blob(b"y" * 1_000_000)
            out = fix.on(c).run(count_string(shard, b"yy"), timeout=30)
            assert out == 500_000
        finally:
            c.shutdown()


class TestInternalIO:
    def test_internal_mode_starves_workers(self):
        net = Network(Link(latency_s=0.02, gbps=10))
        c = make_cluster(n_nodes=2, io_mode="internal", network=net)
        try:
            be = fix.on(c)
            c.nodes["n0"].repo.put_blob(b"z" * 100_000)
            shard = Handle.blob(b"z" * 100_000)
            # force remote work: submit several, some land off-node
            futs = [be.submit(count_string(shard, bytes([i % 3]) + b"zz"))
                    for i in range(8)]
            for f in futs:
                f.result(timeout=30)
            starved = sum(n.starved_ns for n in c.worker_nodes())
            assert starved > 0  # slots were held during fetches
        finally:
            c.shutdown()


class TestInternalIOFetchFailure:
    def test_unsourceable_fetch_fails_job_not_worker(self):
        """A blocking fetch with no surviving source must surface as the
        job's error — the worker slot survives and keeps serving."""
        c = make_cluster(n_nodes=1, io_mode="internal")
        try:
            be = fix.on(c)
            ghost = Handle.blob(b"never-put-anywhere" * 100)  # no replica
            fut = be.submit(count_string(ghost, b"x"))
            exc = fut.exception(timeout=30)
            assert exc is not None  # MissingData reported, not a dead thread
            # the slot that hit the failure still runs new work
            assert be.run(add(1, 2), timeout=30) == 3
        finally:
            c.shutdown()


class TestFaultTolerance:
    def test_node_failure_reschedules(self):
        c = make_cluster(n_nodes=3)
        try:
            fut = fix.on(c).submit(inc_chain(0, 50))
            time.sleep(0.02)
            c.kill_node("n0")
            out = fut.result(timeout=60)
            assert fix.on(c).fetch(out, as_type=int) == 50
        finally:
            c.shutdown()

    def test_lost_data_recomputed_from_lineage(self):
        """Computational GC (paper §6): results can be deleted and
        deterministically re-derived from their producing Encode."""
        c = make_cluster(n_nodes=3)
        try:
            be = fix.on(c)
            corpus = be.repo.put_blob(bytes(range(256)) * 1000)
            out1 = be.evaluate(slice_blob(corpus, 1000, 500), timeout=30)
            # wipe the result from every node that holds it
            for n in c.worker_nodes():
                n.repo._blobs.pop(out1.content_key(), None)
            # a consumer needing the slice forces recompute-from-lineage
            out2 = be.run(count_string(out1.as_object(), bytes([232])),
                          timeout=30)
            assert out2 >= 1
        finally:
            c.shutdown()

    def test_straggler_duplicate_execution_safe(self):
        c = make_cluster(n_nodes=3, speculate_after_s=0.05)
        try:
            assert fix.on(c).run(fib(10), timeout=60) == 55
        finally:
            c.shutdown()


def _assert_fractions_sane(util: dict) -> None:
    for key in ("busy_frac", "starved_frac", "idle_iowait_frac"):
        frac = util[key]
        assert not math.isnan(frac), f"{key} is NaN"
        assert 0.0 <= frac <= 1.0, f"{key}={frac} outside [0, 1]"
    assert (util["busy_frac"] + util["starved_frac"]
            + util["idle_iowait_frac"]) <= 1.0 + 1e-9


class TestUtilizationAccounting:
    """Edge cases surfaced by tracing: degenerate windows must yield
    well-defined fractions, never NaN, negatives or >1 blowups."""

    def test_zero_window_reports_all_idle(self):
        c = make_cluster()
        try:
            assert fix.on(c).run(add(1, 2), timeout=30) == 3
            util = c.utilization(0.0)
            _assert_fractions_sane(util)
            assert util["busy_frac"] == 0.0
            assert util["starved_frac"] == 0.0
            assert util["idle_iowait_frac"] == 1.0
        finally:
            c.shutdown()

    def test_negative_window_reports_all_idle(self):
        c = make_cluster()
        try:
            _assert_fractions_sane(c.utilization(-1.0))
        finally:
            c.shutdown()

    def test_window_smaller_than_busy_time_clamps(self):
        """A window much shorter than accumulated busy time (measurement
        slop, or resetting mid-run) must clamp to 1.0, not report a
        1e9× 'fraction'."""
        c = make_cluster(n_nodes=1)
        try:
            be = fix.on(c)
            corpus = be.repo.put_blob(bytes(range(256)) * 4000)
            assert be.run(count_string(corpus, bytes([7])), timeout=30) == 4000
            util = c.utilization(1e-12)
            _assert_fractions_sane(util)
            assert util["busy_frac"] == 1.0
            assert util["idle_iowait_frac"] == 0.0
        finally:
            c.shutdown()

    def test_busy_plus_starved_clamp_partitions_window(self):
        """Even when the window undercounts accumulated busy AND starved
        slot-time, the three fractions must still partition it (sum 1.0),
        not clamp independently to 2.0."""
        net = Network(Link(latency_s=0.02, gbps=10))
        c = make_cluster(n_nodes=2, io_mode="internal", network=net)
        try:
            be = fix.on(c)
            c.nodes["n0"].repo.put_blob(b"z" * 100_000)
            shard = Handle.blob(b"z" * 100_000)
            futs = [be.submit(count_string(shard, bytes([i % 3]) + b"zz"))
                    for i in range(8)]
            for f in futs:
                f.result(timeout=30)
            assert sum(n.starved_ns for n in c.worker_nodes()) > 0
            util = c.utilization(1e-9)  # window ≪ accumulated slot-time
            _assert_fractions_sane(util)
            assert util["busy_frac"] + util["starved_frac"] \
                + util["idle_iowait_frac"] == 1.0
        finally:
            c.shutdown()

    def test_instant_virtual_job_zero_makespan_window(self):
        """Under a virtual clock a job over literal inputs starts and
        finishes in the same simulated instant: makespan is exactly 0.0
        and utilization over it must stay well-defined."""
        clk = VirtualClock()
        c = Cluster(n_nodes=2, clock=clk)
        try:
            be = fix.on(c)
            be.evaluate(add(20, 22), timeout=60)  # warm: stages + memoizes
            t0 = clk.now()
            assert be.run(add(20, 22), timeout=60) == 42
            makespan = clk.now() - t0
            assert makespan == 0.0  # memo hit: zero simulated seconds
            _assert_fractions_sane(c.utilization(makespan))
        finally:
            c.shutdown()
            clk.close()


class TestDeterminismProperties:
    def test_same_job_same_result_across_clusters(self):
        results = []
        for seed in (0, 1):
            c = make_cluster(n_nodes=2 + seed, seed=seed)
            try:
                results.append(fix.on(c).run(fib(9), timeout=60))
            finally:
                c.shutdown()
        assert results[0] == results[1] == 34
