"""Seeded workload + topology generator for the trace/fuzz test harness.

Two things live here:

* :func:`run_quickstart` — the fixed 4-node quickstart workload behind the
  committed golden trace (tests/fixtures/quickstart_trace.jsonl).  When a
  scheduler change *intentionally* alters the schedule, regenerate with::

      PYTHONPATH=src python tests/workloads.py --regen

* a randomized generator (:func:`make_spec` / :func:`run_workload`): from
  one integer seed it derives a heterogeneous topology (fat/thin links),
  a blob layout (inputs scattered across storage and worker nodes, some
  replicated), and a job mix (fan-out ``checksum_tree`` trees, optionally
  fan-in merged pairwise) — then runs it under a ``VirtualClock`` with
  tracing on.  tests/test_trace_properties.py fuzzes schedules with it and
  checks the invariants in :mod:`repro.runtime.trace`.

Everything is derived from the seed with ``random.Random`` — no ambient
entropy — so any failing seed reproduces exactly (the CI fuzz job prints
its rotating seed for this reason).
"""
from __future__ import annotations

import random
import sys
from dataclasses import dataclass
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import repro.fix as fix  # noqa: E402
from repro.core.stdlib import add, checksum_tree, fib, inc_chain, merge_counts  # noqa: E402
from repro.runtime import (  # noqa: E402
    Cluster,
    FaultSchedule,
    Link,
    Network,
    TraceRecorder,
    VirtualClock,
    verify_invariants,
)

FIXTURE = str(Path(__file__).resolve().parent / "fixtures"
              / "quickstart_trace.jsonl")


# ------------------------------------------------------------- quickstart
def run_quickstart(trace: TraceRecorder | None = None) -> dict:
    """The golden-trace workload: a fixed 4-node topology (one slow edge
    node, one storage node) running the quickstart mix — a staged
    checksum over storage-resident blobs, a parallel fib fan-out, a
    tail-call chain and a memo-hit resubmission."""
    net = Network(Link(latency_s=0.001, gbps=1.0),
                  overrides={("s0", "n3"): Link(0.01, 0.1),
                             ("n3", "s0"): Link(0.01, 0.1)})
    clk = VirtualClock()
    c = Cluster(n_nodes=4, workers_per_node=1, storage_nodes=("s0",),
                network=net, clock=clk, seed=0, trace=trace)
    try:
        be = fix.on(c)
        store = c.nodes["s0"].repo
        blobs = [store.put_blob(bytes([i]) * 16384) for i in range(6)]
        tree = store.put_tree(blobs)
        futs = [be.submit(checksum_tree(tree)),
                be.submit(fib(8)),
                be.submit(inc_chain(0, 10)),
                be.submit(add(20, 22))]
        results = [f.result(timeout=300) for f in futs]
        be.submit(add(20, 22)).result(timeout=300)  # memo-hit path
        return {
            "makespan": clk.now(),
            "transfers": c.transfers,
            "bytes_moved": c.bytes_moved,
            "results": tuple(h.raw.hex() for h in results),
        }
    finally:
        c.shutdown()
        clk.close()


# -------------------------------------------------------------- generator
@dataclass(frozen=True)
class WorkloadSpec:
    """Everything a randomized case needs, derived from one seed."""

    seed: int
    n_nodes: int
    workers_per_node: int
    n_jobs: int
    inputs_per_job: int
    blob_kb: int
    fanin: bool          # merge checksum results pairwise (fan-in trees)
    replica_p: float     # probability an input blob gets a second replica
    io_mode: str = "external"
    transfer_mode: str = "batched"


def make_spec(seed: int, io_mode: str = "external") -> WorkloadSpec:
    rng = random.Random(seed * 9176 + 11)
    return WorkloadSpec(
        seed=seed,
        n_nodes=rng.randint(2, 5),
        workers_per_node=rng.randint(1, 2),
        n_jobs=rng.randint(4, 8),
        inputs_per_job=rng.randint(2, 5),
        blob_kb=rng.choice((4, 16, 40)),
        fanin=rng.random() < 0.5,
        replica_p=rng.random() * 0.5,
        io_mode=io_mode,
        transfer_mode="per_handle" if rng.random() < 0.2 else "batched",
    )


def build_network(spec: WorkloadSpec, rng: random.Random) -> Network:
    """Heterogeneous links: each node draws a NIC class; a pair's link is
    the slower of the two ends (a thin edge node is thin to everyone)."""
    classes = [(0.005, 0.1), (0.002, 0.5), (0.001, 2.0), (0.0005, 10.0)]
    names = [f"n{i}" for i in range(spec.n_nodes)] + ["s0", "client"]
    draw = {name: rng.choice(classes) for name in names}
    overrides = {}
    for a in names:
        for b in names:
            if a == b:
                continue
            lat_a, g_a = draw[a]
            lat_b, g_b = draw[b]
            overrides[(a, b)] = Link(latency_s=max(lat_a, lat_b),
                                     gbps=min(g_a, g_b))
    return Network(Link(latency_s=0.001, gbps=1.0), overrides=overrides)


def _blob_payload(spec: WorkloadSpec, j: int, i: int) -> bytes:
    head = bytes([spec.seed % 251, j % 251, i % 251, 17])
    return head + b"\x5a" * (spec.blob_kb * 1024 - len(head))


def run_workload(spec: WorkloadSpec, *, placement: str = "locality",
                 trace: TraceRecorder | None = None,
                 faults: FaultSchedule | None = None,
                 tolerate_failures: bool = False,
                 first_deadline_s: float | None = None) -> dict:
    """Run one generated case under a ``VirtualClock``; returns the
    schedule summary (and fills ``trace`` when given).  Internal-I/O
    specs park every input on storage so each job's fetches are
    guaranteed remote (that is the starvation being measured).

    ``faults`` installs a seeded injection schedule; with
    ``tolerate_failures`` each future resolves independently and the
    summary gains ``outcomes`` — ``("ok", result_hex)`` or
    ``("fail", exception_type_name)`` per job, in submission order.
    ``first_deadline_s`` puts a deadline on the first submission only
    (the chaos suite's cancellation-path coverage)."""
    rng = random.Random(spec.seed)
    net = build_network(spec, rng)
    clk = VirtualClock()
    c = Cluster(n_nodes=spec.n_nodes, workers_per_node=spec.workers_per_node,
                storage_nodes=("s0",), network=net, placement=placement,
                io_mode=spec.io_mode, transfer_mode=spec.transfer_mode,
                clock=clk, seed=spec.seed, trace=trace, faults=faults)
    try:
        be = fix.on(c)
        store = c.nodes["s0"]
        homes = [store] if spec.io_mode == "internal" else (
            [store] + c.worker_nodes())
        jobs = []
        for j in range(spec.n_jobs):
            blobs = []
            for i in range(spec.inputs_per_job):
                payload = _blob_payload(spec, j, i)
                home = rng.choice(homes)
                blobs.append(home.repo.put_blob(payload))
                if rng.random() < spec.replica_p:
                    rng.choice(homes).repo.put_blob(payload)  # a replica
            jobs.append(checksum_tree(store.repo.put_tree(blobs)))
        if spec.fanin:
            merged = [merge_counts(jobs[i], jobs[i + 1])
                      for i in range(0, len(jobs) - 1, 2)]
            if len(jobs) % 2:
                merged.append(jobs[-1])
            jobs = merged
        t0 = clk.now()
        futs = [be.submit(j, deadline_s=first_deadline_s if i == 0 else None)
                for i, j in enumerate(jobs)]
        outcomes: list[tuple[str, str]] = []
        results = []
        for f in futs:
            if tolerate_failures:
                try:
                    h = f.result(timeout=600)
                    results.append(h)
                    outcomes.append(("ok", h.raw.hex()))
                except Exception as e:  # noqa: BLE001 — outcome, not crash
                    outcomes.append(("fail", type(e).__name__))
            else:
                h = f.result(timeout=600)
                results.append(h)
                outcomes.append(("ok", h.raw.hex()))
        makespan = clk.now() - t0
        util = c.utilization(makespan)
    finally:
        c.shutdown()
        clk.close()
    # summary AFTER shutdown: teardown may still fail/cancel stragglers,
    # and the stats snapshot must cover the same window as the trace.
    # The codelet profile is wall-time measurement, not schedule output —
    # drop it so double-run summaries stay comparable for determinism.
    stats = c.stats()
    stats.pop("codelets", None)
    return {
        "makespan": makespan,
        "transfers": c.transfers,
        "bytes_moved": c.bytes_moved,
        "busy_frac": util["busy_frac"],
        "starved_frac": util["starved_frac"],
        "results": tuple(h.raw.hex() for h in results),
        "outcomes": tuple(outcomes),
        "stats": stats,
    }


# ------------------------------------------------------------ chaos cases
#: failure types the recovery plane is allowed to surface — anything else
#: (KeyError, RuntimeError, a bare Exception) is an unattributed bug.
ALLOWED_FAILURES = frozenset({
    "TransferFailed", "DataUnrecoverable", "DeadlineExceeded",
    "CancelledError", "MissingData"})


def make_chaos_spec(seed: int) -> WorkloadSpec:
    """A workload tuned for fault runs: enough replication that failover
    has somewhere to go, always externalized I/O (the mode the recovery
    plane schedules for)."""
    rng = random.Random(seed * 6691 + 7)
    return WorkloadSpec(
        seed=seed,
        n_nodes=rng.randint(3, 5),
        workers_per_node=rng.randint(1, 2),
        n_jobs=rng.randint(4, 8),
        inputs_per_job=rng.randint(2, 4),
        blob_kb=rng.choice((16, 40, 64)),
        fanin=rng.random() < 0.35,
        replica_p=0.3 + rng.random() * 0.5,
        io_mode="external",
        transfer_mode="per_handle" if rng.random() < 0.15 else "batched",
    )


def make_fault_schedule(seed: int, spec: WorkloadSpec,
                        horizon: float) -> FaultSchedule:
    """Derive a seeded injection schedule scaled to ``horizon`` (the
    clean run's makespan): node churn (never all workers at once, so the
    cluster always has somewhere to run), link flaps and degradation,
    transfer drops, wire and at-rest corruption."""
    rng = random.Random(seed * 5077 + 29)
    fs = FaultSchedule()
    workers = [f"n{i}" for i in range(spec.n_nodes)]
    sites = workers + ["s0"]
    n_crash = rng.randint(0, spec.n_nodes - 1)  # >= 1 worker survives
    for victim in rng.sample(workers, n_crash):
        t = rng.uniform(0.05, 0.9) * horizon
        fs.crash(t, victim)
        if rng.random() < 0.6:
            fs.join(t + rng.uniform(0.05, 0.3) * horizon, victim)
    if rng.random() < 0.25:  # storage loss: only lineage saves its data
        fs.crash(rng.uniform(0.3, 0.9) * horizon, "s0")
    for _ in range(rng.randint(0, 3)):
        src, dst = rng.sample(sites, 2)
        fs.link_down(rng.uniform(0.0, 0.8) * horizon, src, dst,
                     for_s=rng.uniform(0.05, 0.4) * horizon)
    for _ in range(rng.randint(0, 2)):
        src, dst = rng.sample(sites, 2)
        fs.degrade(rng.uniform(0.0, 0.8) * horizon, src, dst,
                   factor=rng.uniform(2.0, 10.0),
                   for_s=rng.uniform(0.1, 0.5) * horizon)
    for _ in range(rng.randint(0, 3)):
        src, dst = rng.sample(sites, 2)
        fs.drop(rng.uniform(0.0, 0.8) * horizon, src, dst,
                count=rng.randint(1, 3))
    for _ in range(rng.randint(0, 2)):
        src, dst = rng.sample(sites, 2)
        fs.corrupt_wire(rng.uniform(0.0, 0.8) * horizon, src, dst,
                        count=rng.randint(1, 2))
    if rng.random() < 0.5:
        fs.corrupt_blob(rng.uniform(0.1, 0.6) * horizon, rng.choice(sites),
                        index=rng.randint(0, 5))
    return fs


def run_chaos_case(seed: int, trace: TraceRecorder | None = None) -> dict:
    """One seeded chaos case: a clean baseline run fixes the expected
    results and the fault horizon, then the same workload re-runs under
    the derived injection schedule.  Returns the comparison — completed
    jobs must match the clean results bit-for-bit, failures must carry an
    allowed (attributed) exception type; violations of either land in
    ``mismatches`` / ``bad_failures``."""
    spec = make_chaos_spec(seed)
    clean = run_workload(spec)
    horizon = max(clean["makespan"], 1e-4)
    rng = random.Random(seed * 3559 + 13)
    deadline = (horizon * rng.uniform(0.1, 1.5)
                if rng.random() < 0.2 else None)
    faults = make_fault_schedule(seed, spec, horizon)
    tr = trace if trace is not None else TraceRecorder()
    res = run_workload(spec, faults=faults, tolerate_failures=True,
                       first_deadline_s=deadline, trace=tr)
    mismatches, bad_failures = [], []
    for i, (kind, val) in enumerate(res["outcomes"]):
        if kind == "ok":
            if val != clean["results"][i]:
                mismatches.append((i, val, clean["results"][i]))
        elif val not in ALLOWED_FAILURES:
            bad_failures.append((i, val))
    return {
        "spec": spec,
        "n_faults": len(faults),
        "deadline": deadline,
        "clean_makespan": clean["makespan"],
        "fault_makespan": res["makespan"],
        "outcomes": res["outcomes"],
        "mismatches": mismatches,
        "bad_failures": bad_failures,
        "violations": verify_invariants(tr.events),
        "fault_stats": res["stats"],
    }


# ------------------------------------------------------- placement A/B gen
def run_ab_case(seed: int, placement: str,
                trace: TraceRecorder | None = None) -> dict:
    """One anchored heterogeneous case for the bytes-vs-locality A/B: odd
    worker nodes sit behind thin pipes and hold each job's small "anchor"
    input — bait that bytes-missing placement chases behind the congested
    link while seconds-to-stage routes the bulk bytes around it (the PR-3
    result, pinned as a property across seeds)."""
    rng = random.Random(seed * 7919 + 3)
    n_nodes = rng.choice((3, 4, 5, 6))
    thin = Link(latency_s=0.005, gbps=rng.choice((0.05, 0.1, 0.2)))
    fat = Link(latency_s=0.001, gbps=10.0)
    names = [f"n{i}" for i in range(n_nodes)] + ["s0", "client"]
    overrides = {}
    for i in range(1, n_nodes, 2):
        for other in names:
            if other == f"n{i}":
                continue
            overrides[(f"n{i}", other)] = thin
            overrides[(other, f"n{i}")] = thin
    net = Network(fat, overrides=overrides)
    clk = VirtualClock()
    c = Cluster(n_nodes=n_nodes, workers_per_node=1, storage_nodes=("s0",),
                network=net, placement=placement, clock=clk, seed=seed,
                trace=trace)
    try:
        be = fix.on(c)
        store = c.nodes["s0"].repo
        thin_nodes = [c.nodes[f"n{i}"] for i in range(1, n_nodes, 2)]
        n_jobs = rng.randint(3, 2 * n_nodes)
        inputs = rng.randint(3, 6)
        blob_kb = rng.choice((32, 64, 128))
        jobs = []
        for j in range(n_jobs):
            blobs = [store.put_blob(bytes([seed % 251, j % 251, i % 251, 9])
                                    + b"\xa5" * (blob_kb * 1024 - 4))
                     for i in range(inputs)]
            anchor = thin_nodes[j % len(thin_nodes)].repo.put_blob(
                bytes([seed % 251, j % 251, 201]) + b"\x3c" * (8 * 1024 - 3))
            blobs.append(anchor)
            jobs.append(checksum_tree(store.put_tree(blobs)))
        t0 = clk.now()
        futs = [be.submit(j) for j in jobs]
        [f.result(timeout=600) for f in futs]
        return {"makespan": clk.now() - t0, "transfers": c.transfers,
                "bytes_moved": c.bytes_moved}
    finally:
        c.shutdown()
        clk.close()


# ---------------------------------------------------------- serving traffic
@dataclass(frozen=True)
class ServingSpec:
    """A seeded serving-traffic case: Zipf-shared prefixes over tenants."""

    seed: int
    n_requests: int
    n_tenants: int
    block: int           # prefix-block size in tokens
    vocab: int
    n_prefixes: int      # shared-prefix pool size
    zipf_a: float        # popularity skew over the pool (rank^-a)
    prefix_blocks: tuple # (min, max) whole blocks per pool prefix
    tail_tokens: tuple   # (min, max) per-request unique tail tokens
    max_new: tuple       # (min, max) decode budget
    batch: int           # engine decode width


def make_serving_spec(seed: int, n_requests: int = 64) -> ServingSpec:
    rng = random.Random(seed * 6271 + 7)
    return ServingSpec(
        seed=seed,
        n_requests=n_requests,
        n_tenants=rng.randint(2, 4),
        block=8,
        vocab=64,
        n_prefixes=rng.randint(4, 8),
        zipf_a=rng.uniform(0.8, 1.4),
        prefix_blocks=(1, 3),
        tail_tokens=(0, 12),
        max_new=(1, 6),
        batch=rng.choice((2, 4)),
    )


def make_serving_requests(spec: ServingSpec) -> list:
    """Seeded traffic: each request draws a pool prefix Zipf-style, adds a
    unique tail, lands on a random tenant.  Shared prefixes are whole
    blocks, so block-level memoization has something to find."""
    import numpy as np  # heavy import kept local: workloads.py is also a CLI

    from repro.serving import Request

    rng = random.Random(spec.seed * 517 + 29)
    pool = []
    for _ in range(spec.n_prefixes):
        nb = rng.randint(*spec.prefix_blocks)
        pool.append([rng.randrange(1, spec.vocab)
                     for _ in range(nb * spec.block)])
    zipf = [1.0 / (r ** spec.zipf_a) for r in range(1, spec.n_prefixes + 1)]
    reqs = []
    for i in range(spec.n_requests):
        prefix = pool[rng.choices(range(spec.n_prefixes), zipf)[0]]
        tail = [rng.randrange(1, spec.vocab)
                for _ in range(rng.randint(*spec.tail_tokens))]
        reqs.append(Request(
            rid=i,
            prompt=np.asarray(prefix + tail, np.int32),
            max_new=rng.randint(*spec.max_new),
            tenant=f"t{rng.randrange(spec.n_tenants)}"))
    return reqs


def run_serving(spec: ServingSpec, *, backend: str = "simulated",
                prefix_memo: bool = True, trace: TraceRecorder | None = None,
                max_inflight: int | None = 2,
                tenant_weights: dict | None = None,
                n_workers: int = 2) -> dict:
    """Serve one seeded traffic case end to end on a backend.

    ``backend``: "local" | "simulated" (VirtualClock cluster, traceable) |
    "remote" (real worker processes).  Returns the engine report, the
    per-request token streams (the cross-backend / ablation equivalence
    oracle) and any typed per-request errors.
    """
    from repro.serving import FixServeEngine, TenantQueue, make_weights

    weights = make_weights(seed=0, vocab=spec.vocab, eos=0)
    reqs = make_serving_requests(spec)
    admission = TenantQueue(weights=tenant_weights, max_inflight=max_inflight)
    cluster = None
    clock = None
    be = None
    try:
        if backend == "simulated":
            clock = VirtualClock()
            cluster = Cluster(n_nodes=3, workers_per_node=2, clock=clock,
                              seed=spec.seed, trace=trace)
            if trace is not None:
                trace.bind(clock)
            be = fix.on(cluster)
            now = clock.now
        elif backend == "local":
            be = fix.local()
            now = None
        elif backend == "remote":
            be = fix.remote(n_workers=n_workers)
            now = None
        else:
            raise ValueError(f"unknown backend {backend!r}")
        kw = {} if now is None else {"now": now}
        engine = FixServeEngine(be, weights, batch=spec.batch,
                                block=spec.block, prefix_memo=prefix_memo,
                                admission=admission, **kw)
        engine.serve(reqs)
        return {
            "report": engine.report(),
            "streams": {r.rid: list(r.out_tokens) for r in engine.finished},
            "errors": sorted((r.rid, type(r.error).__name__)
                             for r in engine.finished
                             if getattr(r, "error", None) is not None),
        }
    finally:
        if cluster is not None:
            cluster.shutdown()
        elif be is not None:
            be.close()
        if clock is not None:
            clock.close()


# -------------------------------------------------------------------- CLI
def main(argv: list[str]) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="regenerate the golden quickstart trace fixture")
    ap.add_argument("--regen", nargs="?", const=FIXTURE, default=None,
                    metavar="PATH",
                    help=f"record the quickstart workload twice, verify the "
                         f"traces are bit-identical, and write the fixture "
                         f"(default: {FIXTURE})")
    args = ap.parse_args(argv)
    if args.regen is None:
        ap.print_help()
        return 2
    rec1, rec2 = TraceRecorder(), TraceRecorder()
    run_quickstart(rec1)
    run_quickstart(rec2)
    if rec1.to_jsonl() != rec2.to_jsonl():
        print("FATAL: two recordings disagree — schedule is nondeterministic",
              file=sys.stderr)
        return 1
    Path(args.regen).parent.mkdir(parents=True, exist_ok=True)
    rec1.save(args.regen)
    print(f"wrote {len(rec1)} events to {args.regen}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
