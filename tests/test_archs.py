"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, shape + finiteness assertions (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import ce_loss, concrete_batch, init_params, loss_mask, ops_for
from repro.parallel import Sharder
from repro.parallel.steps import RunConfig, build_train_step

SH = Sharder(None)
B, S = 2, 16


def _smoke_cfg(arch):
    cfg = get_config(arch, smoke=True)
    # f32 end-to-end on CPU for numeric checks
    return cfg.__class__(**{**cfg.__dict__, "param_dtype": jnp.float32,
                            "compute_dtype": jnp.float32})


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _smoke_cfg(arch)
    ops = ops_for(cfg)
    params = init_params(ops.specs(cfg), cfg)
    batch = concrete_batch(cfg, "train", B, S)
    out = ops.forward(params, batch, cfg, SH)
    if isinstance(out, tuple):
        out = out[0]
    assert out.shape[0] == B and out.shape[-1] == cfg.vocab_padded
    assert bool(jnp.isfinite(out).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_improves_loss(arch):
    cfg = _smoke_cfg(arch)
    runcfg = RunConfig(microbatches=1, remat="none",
                       optimizer="adafactor" if cfg.n_experts else "adamw")
    step_fn, *_ = build_train_step(cfg, runcfg, None)
    from repro.launch.train import init_state

    state = init_state(cfg, runcfg)
    batch = {k: np.asarray(v) for k, v in concrete_batch(cfg, "train", B, S).items()}
    losses = []
    for _ in range(3):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1]), f"{arch}: loss diverged"
    assert losses[-1] < losses[0], f"{arch}: loss did not improve {losses}"


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "internvl2_26b"])
def test_decode_step_runs(arch):
    cfg = _smoke_cfg(arch)
    ops = ops_for(cfg)
    if ops.decode_step is None:
        pytest.skip("family has no decode step")
    params = init_params(ops.specs(cfg), cfg)
    cache = init_params(ops.cache_specs(cfg, B, S), cfg)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = ops.decode_step(params, cache, tok, cfg, SH)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    logits3, _ = ops.decode_step(params, cache2, tok, cfg, SH)
    assert bool(jnp.isfinite(logits3).all())


def test_full_configs_match_assignment():
    """The full-scale configs carry the exact assigned hyperparameters."""
    expect = {
        "qwen3_8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
                         d_ff=12288, vocab=151936, qk_norm=True),
        "deepseek_67b": dict(n_layers=95, d_model=8192, n_heads=64,
                             n_kv_heads=8, d_ff=22016, vocab=102400),
        "internlm2_20b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=92544),
        "qwen3_4b": dict(n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
                         d_ff=9728, vocab=151936, qk_norm=True),
        "deepseek_v3_671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 d_ff=2048, vocab=129280, n_experts=256,
                                 top_k=8, mla=True),
        "arctic_480b": dict(n_layers=35, d_model=7168, n_heads=56,
                            n_kv_heads=8, d_ff=4864, vocab=32000,
                            n_experts=128, top_k=2, dense_residual=True),
        "seamless_m4t_medium": dict(d_model=1024, n_heads=16, d_ff=4096,
                                    vocab=256206, n_enc_layers=12,
                                    n_dec_layers=12),
        "mamba2_780m": dict(n_layers=48, d_model=1536, vocab=50280,
                            ssm_state=128),
        "internvl2_26b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=92553,
                              n_patches=1024),
        "zamba2_7b": dict(n_layers=81, d_model=3584, n_heads=32,
                          n_kv_heads=32, d_ff=14336, vocab=32000,
                          ssm_state=64),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_vlm_loss_mask_excludes_patches():
    cfg = _smoke_cfg("internvl2_26b")
    labels = jnp.zeros((2, 16), jnp.int32)
    mask = loss_mask(cfg, labels)
    assert mask is not None
    assert float(mask[:, : cfg.n_patches].sum()) == 0.0
    assert float(mask[:, cfg.n_patches:].sum()) == 2 * (16 - cfg.n_patches)
