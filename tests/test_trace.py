"""Trace subsystem tests: recorder semantics, serialization stability,
diff/replay verification against the committed golden fixture, and the
derived analysis layer (waterfall, link utilization, starvation
attribution).

The golden fixture is tests/fixtures/quickstart_trace.jsonl; when a
scheduler change intentionally alters the schedule, regenerate it with::

    PYTHONPATH=src python tests/workloads.py --regen
"""
import json
import sys
from pathlib import Path

import pytest

import repro.fix as fix
from repro.core.stdlib import add, checksum_tree
from repro.runtime import (
    Cluster,
    Link,
    Network,
    TraceRecorder,
    VirtualClock,
    diff_traces,
    link_utilization,
    load_trace,
    replay_check,
    starvation_intervals,
    verify_invariants,
    waterfall,
)

sys.path.insert(0, str(Path(__file__).resolve().parent))
from workloads import FIXTURE, run_quickstart  # noqa: E402

pytestmark = pytest.mark.usefixtures("no_thread_leaks")


class TestRecorder:
    def test_emit_orders_and_timestamps(self):
        clk = VirtualClock()
        clk.register_current()
        rec = TraceRecorder()
        rec.bind(clk)
        rec.emit("a", x=1)
        clk.sleep(2.5)
        rec.emit("b", y="z")
        assert [e.kind for e in rec.events] == ["a", "b"]
        assert [e.seq for e in rec.events] == [0, 1]
        assert rec.events[0].t == 0.0
        assert rec.events[1].t == pytest.approx(2.5)
        clk.close()

    def test_unbound_recorder_timestamps_zero(self):
        rec = TraceRecorder()
        rec.emit("a")
        assert rec.events[0].t == 0.0

    def test_jsonl_round_trip(self, tmp_path):
        rec = TraceRecorder()
        rec.emit("put", node="n0", key="ab", nbytes=7)
        rec.emit("job_submit", job=0, encode="cd", strict=True,
                 parent=None, recompute=False)
        path = tmp_path / "t.jsonl"
        rec.save(path)
        loaded = load_trace(str(path))
        assert loaded == [e.to_dict() for e in rec.events]
        assert diff_traces(rec.events, loaded).identical

    def test_serialization_is_byte_stable(self):
        rec = TraceRecorder()
        rec.emit("put", node="n0", key="ab", nbytes=7)
        assert rec.to_jsonl() == rec.to_jsonl()
        # keys sorted, no whitespace: canonical form
        line = rec.to_jsonl().splitlines()[0]
        assert line == json.dumps(json.loads(line), sort_keys=True,
                                  separators=(",", ":"))


class TestTraceDiff:
    def test_identical(self):
        a = [{"seq": 0, "t": 0.0, "kind": "x"}]
        d = diff_traces(a, list(a))
        assert d.identical and not d and "identical" in d.explain()

    def test_first_divergence_reported(self):
        a = [{"seq": 0, "kind": "x"}, {"seq": 1, "kind": "y"}]
        b = [{"seq": 0, "kind": "x"}, {"seq": 1, "kind": "z"}]
        d = diff_traces(a, b)
        assert d and d.index == 1
        assert d.left["kind"] == "y" and d.right["kind"] == "z"

    def test_length_mismatch(self):
        a = [{"seq": 0, "kind": "x"}]
        d = diff_traces(a, a + [{"seq": 1, "kind": "y"}])
        assert d.index == 1 and d.left is None and d.right["kind"] == "y"


class TestGoldenTrace:
    def test_double_record_bit_identical(self):
        r1, r2 = TraceRecorder(), TraceRecorder()
        o1 = run_quickstart(r1)
        o2 = run_quickstart(r2)
        assert r1.to_jsonl() == r2.to_jsonl()
        assert o1 == o2
        assert len(r1) > 0

    def test_replay_matches_committed_fixture(self):
        """The regression net: today's scheduler reproduces the recorded
        schedule event for event.  An intentional schedule change must
        regenerate the fixture (see module docstring) — an accidental one
        fails here with the first diverging event."""
        diff = replay_check(run_quickstart, FIXTURE)
        assert diff.identical, diff.explain()

    def test_fixture_passes_invariants(self):
        assert verify_invariants(load_trace(FIXTURE)) == []

    def test_tracing_off_is_default_and_recorded_run_matches(self):
        """trace=None leaves no recorder attached anywhere (the zero-cost
        path) and does not change the schedule: an untraced quickstart
        run reports the same makespan/transfers as the traced fixture."""
        c = Cluster(n_nodes=1)
        try:
            assert c.trace is None
            assert c.nodes["n0"].trace is None
            assert c._xfer.trace is None
        finally:
            c.shutdown()
        untraced = run_quickstart(None)
        traced_rec = TraceRecorder()
        traced = run_quickstart(traced_rec)
        assert untraced == traced


class TestAnalysis:
    def _traced_run(self, io_mode="external"):
        rec = TraceRecorder()
        clk = VirtualClock()
        net = Network(Link(latency_s=0.002, gbps=0.5))
        c = Cluster(n_nodes=2, workers_per_node=1, storage_nodes=("s0",),
                    io_mode=io_mode, network=net, clock=clk, trace=rec)
        try:
            be = fix.on(c)
            store = c.nodes["s0"].repo
            jobs = []
            for j in range(4):
                blobs = [store.put_blob(bytes([j, i]) + b"v" * 20_000)
                         for i in range(4)]
                jobs.append(checksum_tree(store.put_tree(blobs)))
            futs = [be.submit(j) for j in jobs]
            [f.result(timeout=300) for f in futs]
            makespan = clk.now()
        finally:
            c.shutdown()
            clk.close()
        return rec, makespan

    def test_waterfall_intervals_well_formed(self):
        rec, makespan = self._traced_run()
        lanes = waterfall(rec.events)
        assert any(lane in lanes for lane in ("n0", "n1"))
        run_ivs = [iv for lane in lanes.values() for iv in lane]
        assert run_ivs
        for iv in run_ivs:
            assert 0.0 <= iv["start"] <= iv["end"] <= makespan + 1e-9
        # staging shows up: some job waited on a transfer before running
        assert any(iv["phase"] == "stage" for iv in run_ivs)
        assert any(iv["phase"] == "xfer" for iv in run_ivs)

    def test_link_utilization_fractions(self):
        rec, makespan = self._traced_run()
        util = link_utilization(rec.events, makespan)
        assert util, "expected at least one active link"
        for frac in util.values():
            assert 0.0 <= frac <= 1.0
        assert any(k.startswith("s0->") for k in util)
        # degenerate horizon is well-defined
        assert all(v == 0.0 for v in
                   link_utilization(rec.events, 0.0).values())

    def test_starvation_attribution_internal_mode(self):
        rec, _ = self._traced_run(io_mode="internal")
        ivs = starvation_intervals(rec.events)
        assert ivs, "internal mode with remote inputs must starve"
        for iv in ivs:
            assert iv["end"] >= iv["start"]
            if iv["end"] > iv["start"]:
                # the paper's claim, checkable per interval: the slot was
                # released by the arrival of a blob the job declared
                assert iv["attributed"] in iv["declared"]

    def test_no_starvation_events_in_external_mode(self):
        rec, _ = self._traced_run(io_mode="external")
        assert starvation_intervals(rec.events) == []

    def test_verify_invariants_flags_redundant_transfer(self):
        """The checker itself must catch a violation when shown one."""
        events = [
            {"seq": 0, "t": 0.0, "kind": "put", "node": "n1", "key": "aa",
             "nbytes": 8},
            {"seq": 1, "t": 0.0, "kind": "stage_request", "job": 0,
             "dst": "n1", "key": "aa", "nbytes": 8, "action": "enqueue",
             "src": "n0"},
        ]
        violations = verify_invariants(events)
        assert any("already resident" in v for v in violations)
        assert any("bytes delivered" in v for v in violations)

    def test_memo_hit_traced(self):
        rec = TraceRecorder()
        clk = VirtualClock()
        c = Cluster(n_nodes=1, clock=clk, trace=rec)
        try:
            be = fix.on(c)
            assert be.run(add(1, 2), timeout=60) == 3
            assert be.run(add(1, 2), timeout=60) == 3
        finally:
            c.shutdown()
            clk.close()
        kinds = [e.kind for e in rec.events]
        assert kinds.count("job_memo_hit") >= 1
        assert kinds.count("job_submit") >= 1
