"""Serving engine: batched decode with a content-addressed prefix cache.

The Fix view of a KV cache: a prompt's KV state is a *deterministic product
of (weights-handle, prompt-handle)* — so prefill results are memoizable and
shareable across requests exactly like any other Encode.  The engine keys
prefill work by the prompt's content hash (per-block, so common prefixes
dedup block-wise — the B+-tree trick applied to token streams) and performs
all "I/O" (prefill compute, cache fetch) before binding a decode slot: late
binding again, at the request level.

This is a host-level engine driving the jitted serve steps; the batching
discipline is continuous: finished rows are refilled from the queue each
step without stopping the batch.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # int32 [prompt_len]
    max_new: int
    out_tokens: list = field(default_factory=list)
    done: bool = False


def prompt_key(tokens: np.ndarray, block: int = 16) -> list:
    """Content-addressed prefix-block keys (block-wise prefix identity)."""
    keys = []
    h = hashlib.blake2b(digest_size=16)
    for i in range(0, len(tokens), block):
        h.update(tokens[i : i + block].tobytes())
        keys.append(h.copy().digest())
    return keys


class PrefixCache:
    """LRU of per-sequence KV states keyed by prefix-block hash chains."""

    def __init__(self, capacity: int = 16):
        self.capacity = capacity
        self._lru: "OrderedDict[bytes, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def lookup(self, keys: list):
        """Longest cached prefix: returns (n_blocks_covered, state or None)."""
        for n in range(len(keys), 0, -1):
            st = self._lru.get(keys[n - 1])
            if st is not None:
                self._lru.move_to_end(keys[n - 1])
                self.hits += 1
                return n, st
        self.misses += 1
        return 0, None

    def insert(self, keys: list, state) -> None:
        # register every block boundary so future prompts sharing any
        # prefix length find the longest match (block-wise prefix identity)
        for k in keys:
            self._lru[k] = state
            self._lru.move_to_end(k)
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)


class ServeEngine:
    """Continuous batching over a fixed-width decode step.

    ``prefill_fn(tokens[B,S]) -> per-row cache states`` and
    ``decode_fn(states, tokens[B,1]) -> (logits[B,1,V], states)`` come from
    parallel.steps; here they're small-model callables in tests/examples.
    """

    def __init__(self, prefill_fn: Callable, decode_fn: Callable,
                 batch: int, eos: int = 0, prefix_cache: Optional[PrefixCache] = None):
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.batch = batch
        self.eos = eos
        self.cache = prefix_cache or PrefixCache()
        self.queue: list[Request] = []
        self.active: list[Optional[Request]] = [None] * batch
        self.steps = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.batch):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                keys = prompt_key(req.prompt)
                _n, _st = self.cache.lookup(keys)  # counted; state reuse is
                # exercised at the block level in tests
                state = self.prefill_fn(req.prompt)
                self.cache.insert(keys, state)
                req._state = state  # type: ignore[attr-defined]
                req._last = int(req.prompt[-1])  # type: ignore[attr-defined]
                self.active[slot] = req

    def step(self) -> int:
        """One decode step for the whole batch; returns #finished."""
        self._admit()
        live = [(i, r) for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        finished = 0
        for i, req in live:
            tok, req._state = self.decode_fn(req._state, req._last)
            req._last = tok
            req.out_tokens.append(tok)
            if tok == self.eos or len(req.out_tokens) >= req.max_new:
                req.done = True
                self.active[i] = None
                finished += 1
        self.steps += 1
        return finished

    def run(self, max_steps: int = 10_000) -> None:
        while (self.queue or any(r is not None for r in self.active)) \
                and self.steps < max_steps:
            self.step()
