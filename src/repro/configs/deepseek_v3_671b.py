"""DeepSeek-V3-671B [arXiv:2412.19437]: 61L d7168 128H MLA, 1 shared + 256
routed top-8 experts (moe d_ff 2048), v129280, MTP head available.

Deviations (documented in DESIGN.md): all 61 layers are MoE (the real model
keeps the first 3 dense) so the layer stack scans uniformly; router is
softmax-top-k (V3 uses sigmoid + bias-corrected grouping)."""
import jax.numpy as jnp

from ..models import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, d_ff=2048, vocab=129280,
    n_experts=256, top_k=8, d_ff_expert=2048, n_shared_experts=1,
    mla=True, q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
    nope_head_dim=128, v_head_dim=128, rope_theta=1e4, mtp=False,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=64, vocab=512, n_experts=8, top_k=2, d_ff_expert=64,
    n_shared_experts=1, mla=True, q_lora_rank=32, kv_lora_rank=16,
    rope_head_dim=8, nope_head_dim=16, v_head_dim=16, mtp=True,
)

# dry-run step configuration for the full-scale cells
DRYRUN = dict(microbatches=16, remat="full", optimizer="adafactor")
