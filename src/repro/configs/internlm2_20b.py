"""InternLM2-20B [arXiv:2403.17297]: 48L d6144 48H GQA(kv=8) ff16384 v92544."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense", n_layers=48, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=16384, vocab=92544, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="internlm2-20b-smoke", family="dense", n_layers=2, d_model=96,
    n_heads=6, n_kv_heads=2, d_ff=256, vocab=512,
)
