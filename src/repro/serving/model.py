"""A tiny deterministic "language model" whose whole state is 32 bytes.

Serving correctness here is about *plumbing*, not quality: what must hold
is that a KV state is a pure function of (weights, token prefix) and a
decode step a pure function of (weights, state, last token) — then prefix
states are content-addressed, memoizable, and bit-identical wherever they
are computed.  A blake2b chain gives exactly those properties at zero
model cost, so the same token streams fall out of the host engine, the
local backend, the simulated cluster and real worker processes — the
property every serving test pins.

Layout of a weights blob (``make_weights``)::

    b"TLM1" | vocab:u16 | eos:u16 | 32 bytes of seeded key material

State chain::

    state_0   = H(weights || 0^32        || block_0_token_bytes)
    state_j   = H(weights || state_{j-1} || block_j_token_bytes)
    tok, st'  = decode:  d = H(weights || state || last:i64);
                tok = d[:4] % vocab;  st' = d

Token ``eos`` therefore appears with probability ~1/vocab per step —
some generations end early, most run to budget, deterministically.

The ``@fix.codelet`` forms (``serve/prefill_block``, ``serve/decode_step``)
make each prefill block / decode step an ordinary Fix application: the
weights travel as a content-addressed blob handle, states as blobs, and
the strict-memo table does cross-request prefix sharing.
``serve/nonce_state`` is the ablation device: identity on the state but
salted by a nonce, so wrapping each request's chain in it gives the
*same values* with *distinct content keys* — memoization off, semantics
unchanged.
"""
from __future__ import annotations

import hashlib

import numpy as np

from ..fix import codelet

_MAGIC = b"TLM1"
_STATE0 = b"\x00" * 32


def make_weights(seed: int = 0, vocab: int = 64, eos: int = 0) -> bytes:
    """A content-addressed weights blob for the toy LM."""
    if not 0 <= eos < vocab <= 0xFFFF:
        raise ValueError(f"need 0 <= eos < vocab <= 65535, got {eos}/{vocab}")
    key = hashlib.blake2b(b"toy-lm-%d" % seed, digest_size=32).digest()
    return (_MAGIC + vocab.to_bytes(2, "big") + eos.to_bytes(2, "big") + key)


def weights_meta(weights: bytes) -> tuple[int, int]:
    """(vocab, eos) parsed back out of a weights blob."""
    if weights[:4] != _MAGIC or len(weights) != 40:
        raise ValueError("not a toy-LM weights blob")
    return (int.from_bytes(weights[4:6], "big"),
            int.from_bytes(weights[6:8], "big"))


def token_block_bytes(tokens) -> bytes:
    """Canonical byte form of a token block — must match ``prompt_key``'s
    hashing (int32, contiguous) so host and codelet chains agree."""
    return np.ascontiguousarray(tokens, np.int32).tobytes()


def lm_prefill_block(weights: bytes, state: bytes, block: bytes) -> bytes:
    """Fold one token block into the running prefix state (b"" starts)."""
    h = hashlib.blake2b(digest_size=32)
    h.update(weights)
    h.update(state if state else _STATE0)
    h.update(block)
    return h.digest()


def lm_decode(weights: bytes, state: bytes, last: int) -> tuple[int, bytes]:
    """One greedy decode step: (token, next state)."""
    vocab, _eos = weights_meta(weights)
    h = hashlib.blake2b(digest_size=32)
    h.update(weights)
    h.update(state if state else _STATE0)
    h.update(int(last).to_bytes(8, "big", signed=True))
    d = h.digest()
    return int.from_bytes(d[:4], "big") % vocab, d


# --------------------------------------------------------------- codelets
@codelet(name="serve/prefill_block")
def prefill_block(weights: bytes, state: bytes, block: bytes) -> bytes:
    """One prefill block as a Fix application — the unit of prefix memo."""
    return lm_prefill_block(weights, state, block)


@codelet(name="serve/decode_step")
def decode_step(weights: bytes, state: bytes, last: int) -> tuple[int, bytes]:
    """One decode step as a Fix application: (token, next-state blob)."""
    return lm_decode(weights, state, last)


@codelet(name="serve/nonce_state")
def nonce_state(state: bytes, nonce: int) -> bytes:
    """Identity on ``state``, distinct content key per ``nonce`` — the
    no-memo ablation threads each request's chain through a fresh nonce so
    identical prefixes stop folding without changing any value."""
    del nonce
    return state


# ------------------------------------------------------- host-level fns
def toy_fns(weights: bytes):
    """(prefill_fn, decode_fn) over the toy LM, in the ServeEngine
    contract: resumable block prefill + batched decode with one-hot
    logits.  Streams are bit-identical to the codelet path."""
    vocab, _eos = weights_meta(weights)

    def prefill_fn(tokens, state=None):
        return lm_prefill_block(weights, state if state else b"",
                                token_block_bytes(tokens))

    def decode_fn(states, tokens):
        tokens = np.asarray(tokens)
        out_states = []
        logits = np.zeros((len(states), 1, vocab), np.float32)
        for b, (st, last) in enumerate(zip(states, tokens[:, 0])):
            tok, st2 = lm_decode(weights, st, int(last))
            logits[b, 0, tok] = 1.0
            out_states.append(st2)
        return logits, out_states

    return prefill_fn, decode_fn
