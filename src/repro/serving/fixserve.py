"""FixServeEngine: continuous batching where serving *is* a Fix workload.

Every prefill block and every decode step is an ordinary Fix application
(:mod:`repro.serving.model` codelets) submitted through the
:class:`~repro.fix.backend.Backend` protocol — so the same engine runs
unchanged on ``fix.local()``, a simulated ``fix.on(cluster)`` under
``VirtualClock``, and real processes via ``fix.remote()``.

What "KV cache" means here:

* a prefix state is a **content-addressed blob** in the backend's
  repository universe, produced by the deterministic chain
  ``state_j = prefill_block(weights, state_{j-1}, block_j)``;
* the cross-request index is the repository's **strict-memo table**:
  boundary ``j``'s canonical strict Encode (the fully-lazy chain from the
  empty state — a pure function of weights + token blocks, independent of
  where any request resumed) maps to its state handle via
  ``strict_memo_get/put``.  A client-side :class:`PrefixCache` of
  ``prompt_key`` chains fronts it so the common case never recompiles;
* a cache **hit is a placement decision**: the engine passes the state
  *handle* to the next codelet and never localizes state bytes — the
  scheduler decides whether the holding node computes, or the blob is
  staged over a link (the seconds-to-stage model), exactly like any other
  dependency.  Decode reads use ``fetch_stream`` to pull only the token
  child; state blobs stay wherever they were produced.

The no-memo ablation (``prefix_memo=False``) threads each request's chain
through ``serve/nonce_state`` — identity on values, unique content keys —
so identical prefixes genuinely recompute per request while token streams
stay bit-identical (the benchmark's correctness check).

Per-tenant admission is a :class:`~repro.serving.admission.TenantQueue`;
every submission carries ``tenant=`` so the PR-4 trace plane
(``tenant_report`` / ``starvation_intervals`` / ``link_utilization``)
doubles as the SLO report.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from ..fix.backend import Backend
from ..fix.future import Future
from .admission import TenantQueue
from .engine import PrefixCache, Request, prompt_key, validate_request
from .model import (
    decode_step,
    nonce_state,
    prefill_block,
    token_block_bytes,
    weights_meta,
)


class FixServeEngine:
    """Continuous batching + memoized-prefix reuse over a Fix backend.

    ``backend`` is any :class:`~repro.fix.backend.Backend`; ``weights`` a
    toy-LM blob (:func:`repro.serving.model.make_weights`).  ``batch`` is
    the decode width (slots), ``block`` the prefix-block size in tokens.
    ``prefix_cache`` (a :class:`PrefixCache`) holds *(canonical encode,
    state handle)* pairs per boundary — handles, never bytes.  ``now``
    lets simulated runs report virtual-clock latencies
    (``now=cluster.clock.now``).
    """

    def __init__(self, backend: Backend, weights: bytes, *,
                 batch: int = 4, block: int = 16,
                 prefix_memo: bool = True,
                 prefix_cache: Optional[PrefixCache] = None,
                 admission: Optional[TenantQueue] = None,
                 timeout_s: Optional[float] = 600.0,
                 now: Callable[[], float] = time.monotonic):
        self.be = backend
        self.weights = weights
        self.vocab, self.eos = weights_meta(weights)
        self.w_h = backend.repo.put_blob(weights)
        self.batch = batch
        self.block = block
        self.prefix_memo = prefix_memo
        self.chain = (PrefixCache(capacity=4096) if prefix_cache is None
                      else prefix_cache)
        self.admission = admission
        self.timeout_s = timeout_s
        self._now = now
        self._lock = threading.Lock()  # chain map vs. completion callbacks
        self.queue: list[Request] = []
        self.active: list[Optional[Request]] = [None] * batch
        self.finished: list[Request] = []
        self.steps = 0
        # ---- block-level accounting (the ablation's comparison axis)
        self.blocks_total = 0
        self.blocks_hit = 0
        self.prefill_bytes_total = 0
        self.prefill_bytes_hit = 0
        self.decode_steps = 0

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        """Same typed validation as the host engine (shared helper)."""
        validate_request(req)
        req.t_submit = self._now()
        if req.max_new == 0:
            req.t_admit = req.t_done = req.t_submit
            req.done = True
            self.finished.append(req)
            return
        if self.admission is not None:
            self.admission.push(req)
        else:
            self.queue.append(req)

    def pending(self) -> int:
        return (len(self.admission) if self.admission is not None
                else len(self.queue))

    def _next_request(self) -> Optional[Request]:
        if self.admission is not None:
            return self.admission.pop()
        return self.queue.pop(0) if self.queue else None

    # ----------------------------------------------------------- prefill
    def _canonical_encode(self, prompt, j: int):
        """Boundary ``j``'s canonical strict Encode: the fully-lazy chain
        from the empty state — same content key regardless of where any
        particular request resumed, so it is *the* memo identity."""
        expr = None
        for i in range(j + 1):
            seg = token_block_bytes(
                prompt[i * self.block: (i + 1) * self.block])
            expr = prefill_block(self.w_h,
                                 expr if expr is not None else b"", seg)
        enc, _ = self.be._compile(expr)
        return enc

    def _record_boundary(self, chain_keys: tuple, enc, fut: Future) -> None:
        """Completion callback: index the boundary's state handle in the
        chain map and the repo's strict-memo table."""
        try:
            state_h = fut.result(0)
        except Exception:  # noqa: BLE001 — failed prefills just don't cache
            return
        with self._lock:
            self.chain.insert(list(chain_keys), (enc, state_h))
            self.be.repo.strict_memo_put(enc, state_h)

    def _admit(self) -> None:
        for slot in range(self.batch):
            if self.active[slot] is not None:
                continue
            req = self._next_request()
            if req is None:
                break
            self._start_prefill(req)
            req.t_admit = self._now()
            self.active[slot] = req

    def _start_prefill(self, req: Request) -> None:
        keys = prompt_key(req.prompt, self.block)
        seg_bytes = [len(token_block_bytes(
            req.prompt[j * self.block: (j + 1) * self.block]))
            for j in range(len(keys))]
        n, state_h = 0, None
        if self.prefix_memo:
            with self._lock:
                n, ent = self.chain.lookup(keys)
                if ent is not None:
                    state_h = ent[1]
                # extend through the strict-memo table: survives chain-map
                # eviction because the canonical encode is recomputable
                # from the prompt alone
                while n < len(keys):
                    enc = self._canonical_encode(req.prompt, n)
                    memo_h = self.be.repo.strict_memo_get(enc)
                    if memo_h is None:
                        break
                    self.chain.insert(list(keys[: n + 1]), (enc, memo_h))
                    state_h = memo_h
                    n += 1
        self.blocks_total += len(keys)
        self.blocks_hit += n
        self.prefill_bytes_total += sum(seg_bytes)
        self.prefill_bytes_hit += sum(seg_bytes[:n])
        req._last = int(req.prompt[-1])  # type: ignore[attr-defined]
        if n == len(keys):
            # full hit: decode-ready with zero prefill submissions — the
            # state handle IS the cache, wherever its bytes live
            req._state_h = state_h  # type: ignore[attr-defined]
            req._prefill_fut = None  # type: ignore[attr-defined]
            return
        # resume from the longest known boundary; submit one strict
        # expression per uncovered boundary (children dedup by content
        # key, so total work is one job per block) and index each result
        # as it lands
        prev = state_h if state_h is not None else b""
        if not self.prefix_memo:
            # ablation: thread the chain through a per-request nonce —
            # unique content keys, identical values, no folding
            prev = nonce_state(prev, int(req.rid))
        fut = None
        for j in range(n, len(keys)):
            seg = token_block_bytes(
                req.prompt[j * self.block: (j + 1) * self.block])
            expr = prefill_block(self.w_h, prev, seg)
            fut = self.be.submit(expr, tenant=req.tenant)
            if self.prefix_memo:
                enc = self._canonical_encode(req.prompt, j)
                fut.add_done_callback(
                    lambda f, c=tuple(keys[: j + 1]), e=enc:
                    self._record_boundary(c, e, f))
            prev = expr
        req._state_h = None  # type: ignore[attr-defined]
        req._prefill_fut = fut  # type: ignore[attr-defined]

    # ------------------------------------------------------------ decode
    def _promote(self) -> list:
        """Resolve finished prefills; returns decode-ready (slot, req)."""
        ready = []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            if req._state_h is None:
                fut = req._prefill_fut
                if fut is None or not fut.done():
                    continue
                try:
                    req._state_h = fut.result(0)
                except Exception as e:  # noqa: BLE001 — typed fail-fast
                    req.error = e  # type: ignore[attr-defined]
                    self._finish(i, req)
                    continue
                req._prefill_fut = None
            ready.append((i, req))
        return ready

    def _finish(self, slot: int, req: Request) -> None:
        req.done = True
        req.t_done = self._now()
        self.active[slot] = None
        self.finished.append(req)
        if self.admission is not None:
            self.admission.release(req.tenant)

    def step(self) -> int:
        """One continuous-batching step: admit, promote, one batched
        decode wave; returns the number of requests finished."""
        self._admit()
        live = self._promote()
        if not live:
            # nothing decode-ready: block on the earliest prefill so
            # simulated time advances instead of busy-spinning
            waiting = [r._prefill_fut for r in self.active
                       if r is not None and r._prefill_fut is not None]
            if waiting:
                next(iter(Backend.as_completed(waiting, self.timeout_s)))
            return 0
        # one decode wave: submit every live row's step, then read back.
        # fetch_stream pulls only the tree node + token child — the state
        # blob never crosses to the client (placement, not transfer).
        futs = []
        for i, req in live:
            expr = decode_step(self.w_h, req._state_h, req._last)
            futs.append(self.be.submit(expr, tenant=req.tenant))
        finished = 0
        for (i, req), fut in zip(live, futs):
            try:
                h = fut.result(self.timeout_s)
                gen = self.be.fetch_stream(h, as_type=tuple[int, bytes],
                                           timeout=self.timeout_s)
                tok = next(gen)
                gen.close()
                obj = h.as_object() if h.is_ref() else h
                req._state_h = self.be.repo.get_tree(obj)[1]
            except Exception as e:  # noqa: BLE001 — typed fail-fast
                req.error = e  # type: ignore[attr-defined]
                self._finish(i, req)
                finished += 1
                continue
            self.decode_steps += 1
            req._last = int(tok)
            req.out_tokens.append(int(tok))
            if req.t_first is None:
                req.t_first = self._now()
            if tok == self.eos or len(req.out_tokens) >= req.max_new:
                self._finish(i, req)
                finished += 1
        self.steps += 1
        return finished

    def run(self, max_steps: int = 1_000_000) -> None:
        while (self.pending()
               or any(r is not None for r in self.active)) \
                and self.steps < max_steps:
            self.step()

    def serve(self, requests) -> list[Request]:
        """Submit everything, run to completion, return finished order."""
        for req in requests:
            self.submit(req)
        self.run()
        return self.finished

    # ------------------------------------------------------------ report
    def stats(self) -> dict:
        """Live operational snapshot: the backend's unified stats plus
        engine counters and per-tenant admission gauges.  Cheap enough to
        poll (``repro.obs.top`` renders it); :meth:`report` is the
        end-of-run SLO summary."""
        adm = self.admission
        return {
            "backend": self.be.stats(),
            "serving": {
                "steps": self.steps,
                "decode_steps": self.decode_steps,
                "blocks_total": self.blocks_total,
                "blocks_hit": self.blocks_hit,
                "prefill_bytes_total": self.prefill_bytes_total,
                "prefill_bytes_hit": self.prefill_bytes_hit,
                "pending": self.pending(),
                "active": sum(1 for r in self.active if r is not None),
                "finished": len(self.finished),
            },
            "tenants": ({} if adm is None else {
                t: {"queued": adm.queued(t),
                    "inflight": adm.inflight(t),
                    "admitted": adm.admitted(t)}
                for t in adm.tenants()}),
        }

    def report(self) -> dict:
        """Request-level SLOs + block-level memo accounting.  The
        trace-level per-tenant view comes from
        :func:`repro.runtime.trace.tenant_report` on the backend's trace."""
        from ..runtime.trace import percentile
        lat = [r.latency_s for r in self.finished]
        wait = [r.queue_wait_s for r in self.finished]
        per_tenant: dict[str, dict] = {}
        for r in self.finished:
            d = per_tenant.setdefault(
                r.tenant, {"requests": 0, "latencies": [], "waits": []})
            d["requests"] += 1
            d["latencies"].append(r.latency_s)
            d["waits"].append(r.queue_wait_s)
        return {
            "requests": len(self.finished),
            "engine_steps": self.steps,
            "decode_steps": self.decode_steps,
            "p50_latency_s": percentile(lat, 50),
            "p99_latency_s": percentile(lat, 99),
            "p99_queue_wait_s": percentile(wait, 99),
            "blocks_total": self.blocks_total,
            "blocks_hit": self.blocks_hit,
            "hit_ratio": (self.blocks_hit / self.blocks_total
                          if self.blocks_total else 0.0),
            "prefill_bytes_total": self.prefill_bytes_total,
            "prefill_bytes_hit": self.prefill_bytes_hit,
            "per_tenant": {
                t: {"requests": d["requests"],
                    "p50_latency_s": percentile(d["latencies"], 50),
                    "p99_latency_s": percentile(d["latencies"], 99),
                    "p99_queue_wait_s": percentile(d["waits"], 99)}
                for t, d in sorted(per_tenant.items())},
        }
