"""Step builders: jitted, sharded train_step / serve_step per architecture.

This is where Fix's contract meets XLA: every input/output of a step has a
declared sharding (the step's "minimum repository" and its layout), buffers
are donated (late binding of HBM), and all data movement — FSDP gathers, TP
all-reduces, EP combines, cross-pod grad sync — is emitted by the
partitioner from those declarations rather than issued by model code.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import (
    ModelConfig,
    abstract_params,
    ce_loss,
    input_specs,
    loss_mask,
    ops_for,
    param_shardings,
)
from ..models.base import tree_map_specs
from ..optim import AdamWConfig, ef_int8_allreduce, ef_state_specs
from ..optim import adafactor as _adafactor
from ..optim import adamw as _adamw
from .sharding import RULE_VARIANTS, Sharder, compat_shard_map, make_rules


@dataclass(frozen=True)
class RunConfig:
    microbatches: int = 1
    remat: str = "dots"            # none | dots | full
    remat_group: int = 1            # checkpoint every G layers (sqrt-L saves)
    rules: str = "baseline"        # see sharding.RULE_VARIANTS
    rule_overrides: tuple = ()      # extra (logical, mesh-axis) overrides
    dp_sync: str = "auto"          # auto | int8_pod (EF-compressed DCN sync)
    optimizer: str = "adamw"       # adamw | adafactor (factored 2nd moment)
    use_kernel: bool = False        # route hot-spots through Pallas kernels
    mtp_weight: float = 0.0         # DeepSeek MTP auxiliary loss weight
    optim: AdamWConfig = field(default_factory=AdamWConfig)
    adafactor: _adafactor.AdafactorConfig = field(
        default_factory=_adafactor.AdafactorConfig)


def _resolve_remat(name: str):
    if name == "none":
        return None
    if name == "full":
        return "full"
    if name == "dots":
        return jax.checkpoint_policies.nothing_saveable  # per-layer full remat
    if name == "save_dots":
        return jax.checkpoint_policies.checkpoint_dots
    raise ValueError(name)


def make_sharder(mesh: Optional[Mesh], runcfg: RunConfig) -> Sharder:
    rules = dict(RULE_VARIANTS[runcfg.rules])
    rules.update(dict(runcfg.rule_overrides))
    return Sharder(mesh, rules)


# -------------------------------------------------------------- train step
def build_train_step(cfg: ModelConfig, runcfg: RunConfig, mesh: Optional[Mesh]):
    """Returns (jitted step, state_shardings, batch_shardings, abstract_state).

    state = {"params": ..., "opt": {mu, nu, step}[, "ef": ...]}
    step(state, batch) -> (state, metrics)
    """
    ops = ops_for(cfg)
    specs = ops.specs(cfg)
    sh = make_sharder(mesh, runcfg)
    remat = _resolve_remat(runcfg.remat)
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1) if mesh else 1
    use_ef = runcfg.dp_sync == "int8_pod" and n_pods > 1

    def loss_fn(params, mb):
        params_c = jax.tree.map(lambda p: p.astype(cfg.compute_dtype)
                                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
        fwd_kwargs = {}
        if cfg.family in ("dense", "vlm", "moe", "mamba2") and runcfg.remat_group > 1:
            fwd_kwargs["remat_group"] = runcfg.remat_group
        out = ops.forward(params_c, mb, cfg, sh, remat_policy=remat, **fwd_kwargs)
        if isinstance(out, tuple):  # MTP: (main logits, mtp logits)
            logits, mtp_logits = out
            loss, metrics = ce_loss(logits, mb["labels"], cfg, loss_mask(cfg, mb["labels"]))
            if runcfg.mtp_weight:
                mtp_loss, _ = ce_loss(mtp_logits, mb["labels"][:, 1:], cfg)
                loss = loss + runcfg.mtp_weight * mtp_loss
                metrics = {**metrics, "mtp_loss": mtp_loss}
            return loss, metrics
        loss, metrics = ce_loss(out, mb["labels"], cfg, loss_mask(cfg, mb["labels"]))
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        nmb = runcfg.microbatches
        if nmb <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        split = {k: v.reshape((nmb, v.shape[0] // nmb) + v.shape[1:])
                 for k, v in batch.items()}

        inv = 1.0 / nmb
        scaled_grad_fn = jax.value_and_grad(
            lambda p, b: loss_fn(p, b)[0] * inv)

        def micro(carry, mb):
            gsum, lsum = carry
            loss, g = scaled_grad_fn(params, mb)
            gsum = jax.tree.map(jnp.add, gsum, g)
            # barrier: stops XLA:CPU carrying an f32 twin of the bf16
            # accumulator across the loop (convert-hoisting pass)
            gsum = jax.lax.optimization_barrier(gsum)
            return (gsum, lsum + loss), None

        # accumulate in f32 for f32 masters; bf16 masters (400B+ MoE) keep
        # the accumulator in bf16 — an f32 buffer alone would blow HBM
        acc_dt = jnp.float32 if cfg.param_dtype == jnp.float32 else cfg.param_dtype
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.zeros((), jnp.float32)), split)
        # grads are pre-scaled by 1/nmb through the cotangent: no full-size
        # divide (which legalizes to an f32 copy of every stacked leaf)
        return gsum, {"loss": lsum}

    if use_ef:
        # pod-local grads via shard_map over "pod" ONLY (data/model stay
        # automatic so the model's sharding constraints keep working), then
        # EF-int8 all-reduce across the DCN link

        def synced_grads(params, batch, ef):
            def per_pod(params, batch, ef):
                grads, metrics = compute_grads(params, batch)
                out = jax.tree.map(
                    lambda g, e: ef_int8_allreduce(g, e, "pod", n_pods), grads, ef)
                grads = jax.tree.map(lambda t: t[0], out,
                                     is_leaf=lambda t: isinstance(t, tuple))
                new_ef = jax.tree.map(lambda t: t[1], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
                metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
                return grads, new_ef, metrics

            rep = jax.tree.map(lambda _: P(), params)
            efspec = jax.tree.map(lambda _: P(), ef)
            bspec = {k: P("pod") for k in batch}
            mspec = P()
            return compat_shard_map(
                per_pod, mesh=mesh,
                in_specs=(rep, bspec, efspec),
                out_specs=(rep, efspec, mspec),
                check=False, manual_axes=("pod",),
            )(params, batch, ef)
    else:
        synced_grads = None

    def train_step(state, batch):
        params = state["params"]
        if use_ef:
            grads, new_ef, metrics = synced_grads(params, batch, state["ef"])
        else:
            grads, metrics = compute_grads(params, batch)
            new_ef = None
        if runcfg.optimizer == "adafactor":
            new_params, new_opt, lr = _adafactor.apply_updates(
                params, grads, state["opt"], runcfg.adafactor)
        else:
            new_params, new_opt, lr = _adamw.apply_updates(
                params, grads, state["opt"], runcfg.optim)
        # per-leaf reduce; f32 accumulation INSIDE the contraction (an
        # elementwise astype would materialize an f32 copy of every grad —
        # measured 3.3 GiB per expert stack; a ravel/vdot would all-gather)
        def _ss(g):
            letters = "abcdefghij"[: g.ndim]
            return jnp.einsum(f"{letters},{letters}->", g, g,
                              preferred_element_type=jnp.float32)
        gnorm = jnp.sqrt(sum(_ss(g) for g in jax.tree.leaves(grads)))
        metrics = {**metrics, "lr": lr, "grad_norm": gnorm}
        new_state = {"params": new_params, "opt": new_opt}
        if new_ef is not None:
            new_state["ef"] = new_ef
        return new_state, metrics

    # shardings
    p_shard = param_shardings(specs, sh) if mesh is not None else None
    if runcfg.optimizer == "adafactor":
        o_specs = _adafactor.state_specs(specs, runcfg.adafactor)
    else:
        o_specs = _adamw.state_specs(specs, runcfg.optim)
    state_shardings = {"params": p_shard,
                       "opt": tree_map_specs(lambda _p, s: sh.named(s.axes, s.shape),
                                             o_specs) if mesh is not None else None}
    abstract = {"params": abstract_params(specs, cfg),
                "opt": abstract_params(o_specs, cfg)}
    if use_ef:
        e_specs = ef_state_specs(specs)
        state_shardings["ef"] = tree_map_specs(
            lambda _p, s: sh.named(s.axes, s.shape), e_specs)
        abstract["ef"] = abstract_params(e_specs, cfg)
    if mesh is None:
        state_shardings = None

    def batch_shardings(bspecs: dict) -> dict:
        return {k: sh.named(("batch",) + (None,) * (len(v.shape) - 1), v.shape)
                for k, v in bspecs.items()}

    metrics_sharding = None  # replicated scalars
    jitted = jax.jit(
        train_step,
        in_shardings=(state_shardings, None),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,),
    )
    return jitted, state_shardings, batch_shardings, abstract


# -------------------------------------------------------------- serve step
DECODE_RULES = dict(heads=None, kv_heads=None, seq=None)


def _serve_abstract_params(specs, cfg):
    """Inference holds weights in compute dtype — no f32 masters."""
    from ..models.base import tree_map_specs as tms

    return tms(lambda _p, s: jax.ShapeDtypeStruct(
        s.shape, cfg.compute_dtype
        if (s.dtype or cfg.param_dtype) == jnp.float32 and len(s.shape) >= 2
        else (s.dtype or cfg.param_dtype)), specs)


def build_serve_step(cfg: ModelConfig, runcfg: RunConfig, mesh: Optional[Mesh],
                     batch: int, max_seq: int, mode: str = "decode"):
    """decode: (params, cache, tokens) -> (logits, cache), cache donated.
    prefill: (params, batch) -> (logits, cache)."""
    ops = ops_for(cfg)
    specs = ops.specs(cfg)
    sh = make_sharder(mesh, runcfg)
    if mode == "prefill" and mesh is not None:
        model_ext = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        if cfg.n_heads % model_ext:
            # heads don't divide the model axis (arctic: 56 on 16) — fall
            # back to context parallelism: shard the query sequence instead
            sh = sh.with_rules(seq="model", heads=None, kv_heads=None)
    p_shard = param_shardings(specs, sh) if mesh is not None else None
    abstract_p = _serve_abstract_params(specs, cfg)

    if mode == "prefill":
        def prefill(params, b):
            return ops.prefill(params, b, cfg, sh)

        # the emitted cache leaves in decode layout (kv_seq context-parallel)
        # via constraints inside each family's prefill; unsharded it costs
        # ~16x HBM on long-prompt cells
        jitted = jax.jit(prefill, in_shardings=(p_shard, None))
        return jitted, p_shard, abstract_p, None

    dsh = sh.with_rules(**DECODE_RULES)
    c_specs = ops.cache_specs(cfg, batch, max_seq)
    c_shard = tree_map_specs(lambda _p, s: dsh.named(s.axes, s.shape),
                             c_specs) if mesh is not None else None
    abstract_c = abstract_params(c_specs, cfg)

    def decode(params, cache, tokens):
        return ops.decode_step(params, cache, tokens, cfg, dsh)

    tok_shard = dsh.named(("batch", None), (batch, 1)) if mesh is not None else None
    jitted = jax.jit(
        decode,
        in_shardings=(p_shard, c_shard, tok_shard),
        out_shardings=(None, c_shard),
        donate_argnums=(1,),
    )
    return jitted, p_shard, abstract_p, (c_shard, abstract_c)
