"""Serving on the Fix core: continuous batching + memoized-prefix reuse.

:mod:`~repro.serving.engine` is the host-level engine (callables in,
callables out); :mod:`~repro.serving.fixserve` runs the same discipline
with every prefill block / decode step as a Fix codelet through any
:class:`~repro.fix.backend.Backend`; :mod:`~repro.serving.admission` is
the per-tenant weighted-fair admission policy shared by both.
"""
from .admission import TenantQueue
from .engine import (
    BudgetError,
    EmptyPromptError,
    PrefixCache,
    Request,
    RequestError,
    ServeEngine,
    prompt_key,
    validate_request,
)
from .fixserve import FixServeEngine
from .model import make_weights, toy_fns

__all__ = [
    "BudgetError",
    "EmptyPromptError",
    "FixServeEngine",
    "PrefixCache",
    "Request",
    "RequestError",
    "ServeEngine",
    "TenantQueue",
    "make_weights",
    "prompt_key",
    "toy_fns",
    "validate_request",
]
