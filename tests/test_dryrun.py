"""Dry-run machinery tests: run in a subprocess so the 512-device XLA flag
never leaks into the other tests' single-device environment."""
import json
import subprocess
import sys

import pytest


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                         timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


@pytest.mark.slow
def test_single_cell_lowers_and_analyzes():
    code = """
from repro.launch.dryrun import run_cell
import json
r = run_cell("qwen3_4b", "decode_32k", False)
assert r["ok"], r.get("error")
rf = r["roofline"]
assert rf["flops_per_device"] > 0
assert rf["hbm_bytes_per_device"] > 0
assert rf["dominant"] in ("compute", "memory", "collective")
assert r["memory"]["fits_16GiB"]
print(json.dumps({"dom": rf["dominant"]}))
"""
    out = _run(code)
    assert "dom" in out


@pytest.mark.slow
def test_multi_pod_mesh_shards_pod_axis():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh
import jax
m = make_production_mesh(multi_pod=True)
assert m.devices.size == 512 and m.axis_names == ("pod", "data", "model")
m1 = make_production_mesh()
assert m1.devices.size == 256 and m1.axis_names == ("data", "model")
print("ok")
"""
    assert "ok" in _run(code)


@pytest.mark.slow
def test_rollup_matches_unrolled_reference():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from repro.roofline.hlo_cost import HloModuleCost
def body(x, w):
    return jnp.tanh(x @ w), None
def scanned(x, ws):
    x, _ = jax.lax.scan(body, x, ws)
    return x
def unrolled(x, ws):
    for i in range(8):
        x, _ = body(x, ws[i])
    return x
x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
fs = HloModuleCost(jax.jit(scanned).lower(x, ws).compile().as_text()).flops()
fu = HloModuleCost(jax.jit(unrolled).lower(x, ws).compile().as_text()).flops()
assert abs(fs - fu) / fu < 0.05, (fs, fu)
print("ok")
"""
    assert "ok" in _run(code)
