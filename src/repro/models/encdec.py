"""Encoder-decoder backbone (SeamlessM4T-medium's text/speech transformer,
arXiv:2308.11596).  The audio frontend is a stub per the assignment:
``input_specs`` provides precomputed fbank-frame features [B, S, 160] which
a learned projection lifts to d_model.

Decoder layers carry self-attention (causal, cached at decode) plus
cross-attention over the encoder memory (cached once at prefill).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .base import (
    apply_remat,
    ModelConfig,
    attend,
    causal_mask,
    embed_tokens,
    ps,
    repeat_kv,
    rmsnorm,
    rope,
    swiglu,
    unembed,
)
from .transformer import attn_block, dense_layer_specs, mlp_block

FRAME_DIM = 160  # stacked fbank features (stub frontend)


def encdec_specs(cfg: ModelConfig) -> dict:
    Vp, D = cfg.vocab_padded, cfg.d_model
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_eff
    Ld = cfg.n_dec_layers
    dec = dense_layer_specs(cfg, Ld)
    dec.update({
        "xattn_norm": ps((Ld, D), ("p_layers", "p_none"), init="ones"),
        "xq": ps((Ld, D, H, hd), ("p_layers", "p_embed", "p_heads", "p_none")),
        "xk": ps((Ld, D, Kv, hd), ("p_layers", "p_embed", "p_kv_heads", "p_none")),
        "xv": ps((Ld, D, Kv, hd), ("p_layers", "p_embed", "p_kv_heads", "p_none")),
        "xo": ps((Ld, H, hd, D), ("p_layers", "p_heads", "p_none", "p_embed")),
    })
    return {
        "frame_proj": ps((FRAME_DIM, D), ("p_none", "p_embed")),
        "embed": ps((Vp, D), ("p_vocab", "p_embed"), init="embed", scale=0.02),
        "enc_layers": dense_layer_specs(cfg, cfg.n_enc_layers),
        "enc_norm": ps((D,), ("p_none",), init="ones"),
        "dec_layers": dec,
        "final_norm": ps((D,), ("p_none",), init="ones"),
        "unembed": ps((D, Vp), ("p_embed", "p_vocab")),
    }


def _bidir_attn_layer(x, lp, cfg, sh, positions):
    """Encoder layer: full (non-causal) self-attention + MLP."""
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q = rope(jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(h.dtype)), positions,
             cfg.rope_theta)
    k = rope(jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(h.dtype)), positions,
             cfg.rope_theta)
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(h.dtype))
    q = sh(q, "batch", "seq", "heads", None)
    o = attend(q, repeat_kv(k, cfg.n_heads), repeat_kv(v, cfg.n_heads), None, sh,
               pattern="full")
    x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(o.dtype))
    return mlp_block(x, lp, cfg, sh)


def _cross_attn(x, lp, cfg, sh, memory=None, mem_kv=None):
    """Cross-attention over encoder memory (or its cached K/V)."""
    h = rmsnorm(x, lp["xattn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["xq"].astype(h.dtype))
    if mem_kv is None:
        k = jnp.einsum("btd,dhk->bthk", memory, lp["xk"].astype(h.dtype))
        v = jnp.einsum("btd,dhk->bthk", memory, lp["xv"].astype(h.dtype))
    else:
        k, v = mem_kv
    q = sh(q, "batch", "seq", "heads", None)
    o = attend(q, repeat_kv(k.astype(q.dtype), cfg.n_heads),
               repeat_kv(v.astype(q.dtype), cfg.n_heads), None, sh,
               pattern="full")
    out = jnp.einsum("bshk,hkd->bsd", o, lp["xo"].astype(o.dtype))
    return x + sh(out, "batch", "seq", "embed"), (k, v)


def encode(params, frames, cfg: ModelConfig, sh):
    x = jnp.einsum("bsf,fd->bsd", frames.astype(cfg.compute_dtype),
                   params["frame_proj"].astype(cfg.compute_dtype))
    x = sh(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        return _bidir_attn_layer(x, lp, cfg, sh, positions), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def encdec_forward(params, batch, cfg: ModelConfig, sh, remat_policy=None):
    """Training: encode frames, causal-decode tokens, logits over decoder."""
    memory = encode(params, batch["frames"], cfg, sh)
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), batch["tokens"], sh)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        x, _ = attn_block(x, lp, cfg, sh, positions)
        x, _ = _cross_attn(x, lp, cfg, sh, memory=memory)
        x = mlp_block(x, lp, cfg, sh)
        return x, None

    body = apply_remat(body, remat_policy)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["unembed"].astype(x.dtype), sh)


def encdec_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    L, Kv, hd = cfg.n_dec_layers, cfg.n_kv_heads, cfg.head_dim_eff
    Tm = cfg.cross_len
    kv = ps((L, batch, max_seq, Kv, hd),
            ("p_layers", "batch", "kv_seq", "kv_heads", "p_none"), init="zeros",
            dtype=cfg.compute_dtype)
    xkv = ps((L, batch, Tm, Kv, hd),
             ("p_layers", "batch", "kv_seq", "kv_heads", "p_none"), init="zeros",
             dtype=cfg.compute_dtype)
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv,
            "pos": ps((), (), init="zeros", dtype=jnp.int32)}


def encdec_decode_step(params, cache, tokens, cfg: ModelConfig, sh):
    """One decoder token against self-KV (len max_seq) + cross-KV (cross_len)."""
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), tokens, sh)
    pos = cache["pos"]
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)

    def body(x, layer):
        lp, k_all, v_all, xk, xv = layer
        x, (k2, v2) = attn_block(x, lp, cfg, sh, positions, kv_cache=(k_all, v_all, pos))
        x, _ = _cross_attn(x, lp, cfg, sh, mem_kv=(xk, xv))
        x = mlp_block(x, lp, cfg, sh)
        return x, (k2, v2)

    x, (k_s, v_s) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"].astype(x.dtype), sh)
    return logits, {"k": k_s, "v": v_s, "xk": cache["xk"], "xv": cache["xv"],
                    "pos": pos + 1}


def encdec_prefill(params, batch, cfg: ModelConfig, sh):
    """Prefill = encode the source; prime decoder caches with BOS."""
    memory = encode(params, batch["frames"], cfg, sh)
    B = memory.shape[0]
    bos = jnp.zeros((B, 1), jnp.int32)
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), bos, sh)
    positions = jnp.zeros((B, 1), jnp.int32)

    def body(x, lp):
        x, (k, v) = attn_block(x, lp, cfg, sh, positions)
        x, (xk, xv) = _cross_attn(x, lp, cfg, sh, memory=memory)
        x = mlp_block(x, lp, cfg, sh)
        return x, (k, v, xk, xv)

    x, (k_s, v_s, xk_s, xv_s) = jax.lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"].astype(x.dtype), sh)
    xk_s = sh(xk_s, None, "batch", "kv_seq", "kv_heads", None)
    xv_s = sh(xv_s, None, "batch", "kv_seq", "kv_heads", None)
    cache = {"k": k_s, "v": v_s, "xk": xk_s, "xv": xv_s,
             "pos": jnp.asarray(1, jnp.int32)}
    return logits, cache
