"""Fix graph reduction: the semantics of Thunks and Encodes.

The evaluator implements the paper's §3 semantics:

* ``think``   — one reduction step of a Thunk (identification / selection /
  application).  Application resolves the Encodes inside the definition Tree,
  seals the container (accessible set = Objects reachable from the resolved
  definition), and jumps to the codelet.  The codelet may return another
  Thunk — a tail call — which ``reduce`` trampolines, so 500-deep chains run
  in constant Python stack.
* ``reduce``  — Thunk → WHNF (first non-Thunk result).
* Encodes: ``shallow`` reduces to WHNF and returns a *Ref* (minimum work to
  make progress); ``strict`` reduces and then recursively descends Trees,
  evaluating every Thunk and turning every Ref into an accessible Object
  (maximum work).

Two invariants the runtime relies on (and our tests check):

1. **Non-blocking**: the evaluator never performs I/O.  If data is missing it
   raises :class:`MissingData`; pre-staging is the scheduler's job (late
   binding).  A codelet, once entered, always runs to completion.
2. **Determinism + memoization**: every (Thunk → result) and (Encode →
   result) pair is recorded first-write-wins in the repository's memo table,
   so duplicated (straggler/speculative) execution is free of side effects.
"""
from __future__ import annotations

import struct
import time
from typing import Optional

from .api import FixAPI
from .handle import (
    APPLICATION,
    BLOB,
    Handle,
    IDENTIFICATION,
    SELECTION,
    SHALLOW,
    STRICT,
    TREE,
)
from .procedures import resolve, name_of
from .repository import CorruptData, MissingData, Repository


class FixError(RuntimeError):
    pass


class Evaluator:
    __slots__ = ("repo", "applications", "reductions", "codelet_seconds",
                 "codelets", "last_codelet")

    def __init__(self, repo: Repository):
        self.repo = repo
        self.applications = 0  # codelet invocations
        self.reductions = 0  # total thunk reduction steps
        self.codelet_seconds = 0.0
        # per-codelet wall accounting: name -> [count, total integer ns]
        # (integer ns so remote workers can ship deltas over a wire codec
        # with no float tag, and sums merge without rounding drift)
        self.codelets: dict[str, list] = {}
        self.last_codelet: Optional[str] = None

    # ----------------------------------------------------------- evaluate
    def evaluate(self, handle: Handle) -> Handle:
        """Fully (strictly) evaluate any handle — the top-level entry."""
        if handle.is_encode():
            return self.eval_encode(handle)
        if handle.is_thunk():
            return self.strictify(self.reduce(handle))
        return self.strictify(handle)

    # ------------------------------------------------------------- encode
    def eval_encode(self, encode: Handle) -> Handle:
        memo = self.repo.memo_get(encode)
        if memo is not None:
            return memo
        thunk = encode.unwrap_encode()
        whnf = self.reduce(thunk)
        if encode.interp == STRICT:
            result = self.strictify(whnf)
        else:  # SHALLOW: minimum progress; hand back a Ref, not the bytes
            result = whnf.as_ref() if whnf.is_data() else whnf
        self.repo.memo_put(encode, result)
        return result

    # ------------------------------------------------------------- reduce
    def reduce(self, thunk: Handle) -> Handle:
        """Trampoline a Thunk to WHNF (tail calls don't grow the stack)."""
        current = thunk
        trail: list[Handle] = []
        while current.is_thunk():
            memo = self.repo.memo_get(current)
            if memo is not None:
                current = memo
                continue
            trail.append(current)
            self.reductions += 1
            current = self._think(current)
        for t in trail:  # every intermediate thunk memoizes the final WHNF
            self.repo.memo_put(t, current)
        return current

    # -------------------------------------------------------------- think
    def think(self, thunk: Handle) -> Handle:
        """One reduction step — the public single-step entry the runtime's
        workers use (a codelet runs to completion, never blocking)."""
        return self._think(thunk)

    def _think(self, thunk: Handle) -> Handle:
        interp = thunk.interp
        if interp == IDENTIFICATION:
            return thunk.unwrap_thunk().as_object()
        if interp == SELECTION:
            return self._select(thunk)
        if interp == APPLICATION:
            return self._apply(thunk)
        raise FixError(f"not a thunk: {thunk!r}")

    def _select(self, thunk: Handle) -> Handle:
        pair = self.repo.get_tree(thunk.unwrap_thunk())
        if len(pair) != 2:
            raise FixError("selection thunks take a [target, index] pair")
        target, idx_h = pair
        idx_raw = self.repo.get_blob(idx_h)
        if target.is_encode():
            target = self.eval_encode(target)
        if target.is_thunk():
            target = self.reduce(target)
        if len(idx_raw) == 8:  # single-element selection
            (i,) = struct.unpack("<q", idx_raw)
            if target.content_type == TREE:
                kids = self.repo.get_tree(target)
                if not (0 <= i < len(kids)):
                    raise FixError(f"selection index {i} out of range {len(kids)}")
                return kids[i]
            payload = self.repo.get_blob(target)
            return Handle.blob(payload[i : i + 1])
        if len(idx_raw) == 16:  # subrange selection [start, count)
            start, count = struct.unpack("<qq", idx_raw)
            if target.content_type == TREE:
                kids = self.repo.get_tree(target)
                return self.repo.put_tree(kids[start : start + count])
            payload = self.repo.get_blob(target)
            return self.repo.put_blob(payload[start : start + count])
        raise FixError("selection index must be 8 (index) or 16 (range) bytes")

    def _apply(self, thunk: Handle) -> Handle:
        definition = thunk.unwrap_thunk()
        resolved = self._resolve_encodes(definition)
        kids = self.repo.get_tree(resolved)
        if len(kids) < 2:
            raise FixError("combination needs [limits, procedure, ...]")
        proc = kids[1]
        if proc.content_type != BLOB:
            raise FixError("procedure must be a blob")
        fn = resolve(proc)
        if fn is None:
            raise FixError(f"unknown procedure {proc!r}")
        # Seal the container: everything reachable as Objects from the
        # resolved definition — and nothing else — is readable.
        fp = self.repo.footprint(resolved)
        api = FixAPI(self.repo, set(fp.data))
        self.applications += 1
        t0 = time.perf_counter_ns()
        try:
            out = fn(api, resolved)
        except (MissingData, CorruptData, FixError):
            raise  # runtime faults pass through for the scheduler to handle
        except Exception as e:  # noqa: BLE001 — codelet fault, not runtime fault
            raise FixError(f"codelet {name_of(proc)!r} failed: {e!r}") from e
        dt_ns = time.perf_counter_ns() - t0
        self.codelet_seconds += dt_ns * 1e-9
        name = name_of(proc) or proc.content_key().hex()[:12]
        ent = self.codelets.get(name)
        if ent is None:
            self.codelets[name] = [1, dt_ns]
        else:
            ent[0] += 1
            ent[1] += dt_ns
        self.last_codelet = name
        if not isinstance(out, Handle):
            raise FixError(f"codelet {name_of(proc)!r} returned {type(out)}")
        return out

    def _resolve_encodes(self, tree_handle: Handle) -> Handle:
        """Replace every Encode inside the definition Tree with its result."""
        kids = self.repo.get_tree(tree_handle)
        changed = False
        new_kids = []
        for k in kids:
            if k.is_encode():
                nk = self.eval_encode(k)
            elif k.content_type == TREE and k.is_object():
                nk = self._resolve_encodes(k)
            else:
                nk = k
            changed |= nk.raw != k.raw
            new_kids.append(nk)
        if not changed:
            return tree_handle
        return self.repo.put_tree(new_kids)

    # ---------------------------------------------------------- strictify
    def strictify(self, handle: Handle) -> Handle:
        """Strict evaluation of data: Trees descended, Thunks run, Refs
        promoted to Objects (their bytes must be / become resident)."""
        if handle.is_encode():
            return self.strictify(self.eval_encode(handle))
        if handle.is_thunk():
            return self.strictify(self.reduce(handle))
        if handle.content_type == BLOB:
            if not self.repo.contains(handle):
                raise MissingData(handle)
            return handle.as_object()
        cached = self.repo.strict_memo_get(handle)
        if cached is not None:
            return cached
        kids = self.repo.get_tree(handle)
        new_kids = [self.strictify(k) for k in kids]
        if all(nk.raw == k.raw for nk, k in zip(new_kids, kids)):
            out = handle.as_object()
        else:
            out = self.repo.put_tree(new_kids)
        self.repo.strict_memo_put(handle, out)
        return out

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "applications": self.applications,
            "reductions": self.reductions,
            "codelet_seconds": self.codelet_seconds,
            "codelets": {name: {"count": ent[0], "total_ns": ent[1]}
                         for name, ent in sorted(self.codelets.items())},
        }
