from .engine import PrefixCache, Request, ServeEngine, prompt_key
__all__ = ["ServeEngine", "Request", "PrefixCache", "prompt_key"]
