"""Live telemetry plane: metrics registry, causal spans, codelet profiles.

Three small pieces, shared by all three backends (``fix.local()``,
``fix.on(cluster)``, ``fix.remote()``) and the serving engine:

* :class:`MetricsRegistry` — an always-on, low-overhead registry of
  labelled counters / gauges / histograms.  Metrics are pure in-memory
  arithmetic: they never touch a clock, never emit trace events, and
  never block on anything but one uncontended lock — so enabling them
  does not perturb a ``VirtualClock`` schedule (the golden trace stays
  byte-identical with telemetry at defaults).  Histograms use *fixed*
  bucket edges chosen at construction, so two runs of a deterministic
  workload produce byte-identical :meth:`MetricsRegistry.snapshot`
  output.

* :class:`SpanEmitter` — opt-in causal spans layered on the PR-4 trace
  stream.  Every request → job → stage → transfer gets a ``span_begin``
  / ``span_end`` event pair with a parent link and a monotonic *wall*
  timestamp (``wall_ns``) alongside the backend clock's ``t``.  Spans
  are off by default (``Cluster(spans=True)`` turns them on), so the
  default event vocabulary — and the committed golden fixture — is
  untouched.

* :class:`CodeletProfile` — per-codelet wall durations.  The evaluator
  times every APPLICATION body (``Evaluator.codelets``); real
  ``fix.remote()`` workers ship deltas back in their ``ran`` replies as
  integer nanoseconds (the wire codec has no float tag), and
  :meth:`CodeletProfile.calibrate` fits per-codelet mean seconds — the
  constants a ``VirtualClock`` cluster charges via
  ``Cluster(compute_model=...)``.  That is the record → model → replay
  seam of ROADMAP item 3: record wall timings once on real processes,
  then study placement/speculation in simulation with compute no longer
  free.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from typing import Iterable, Optional

__all__ = [
    "DEFAULT_BUCKETS",
    "CodeletProfile",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SpanEmitter",
    "job_wall_durations",
]

#: Fixed histogram bucket edges (seconds): µs-scale codelets up through
#: multi-minute jobs.  Fixed at import time so snapshots never depend on
#: observed data — the determinism requirement.
DEFAULT_BUCKETS = (
    1e-6, 1e-5, 1e-4, 1e-3, 5e-3, 0.025, 0.1, 0.5, 1.0, 5.0, 30.0, 120.0)


def _label_key(name: str, labels: dict) -> str:
    """Render ``name{k=v,...}`` with sorted keys — the snapshot key."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing count (jobs, transfers, bytes)."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """A level that moves both ways (queue depth, backlog bytes)."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0

    def set(self, v) -> None:
        with self._lock:
            self.value = v

    def inc(self, n=1) -> None:
        with self._lock:
            self.value += n

    def dec(self, n=1) -> None:
        with self._lock:
            self.value -= n


class Histogram:
    """Fixed-edge histogram: ``counts[i]`` observations ≤ ``edges[i]``,
    one overflow bucket, plus exact ``sum``/``count``."""

    __slots__ = ("_lock", "edges", "counts", "sum", "count")

    def __init__(self, lock: threading.Lock,
                 edges: tuple = DEFAULT_BUCKETS):
        self._lock = lock
        self.edges = tuple(edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        with self._lock:
            i = 0
            for edge in self.edges:
                if v <= edge:
                    break
                i += 1
            self.counts[i] += 1
            self.sum += v
            self.count += 1


class MetricsRegistry:
    """Named, labelled metrics with a deterministic snapshot.

    Instruments call ``registry.counter("jobs_finished", tenant="t0")``
    on the hot path; instances are cached per (name, labels) so repeat
    lookups are one dict hit.  :meth:`snapshot` renders everything into
    plain sorted dicts — the shape ``Cluster.stats()`` /
    ``RemoteBackend.stats()`` / ``FixServeEngine.stats()`` embed under
    their ``"metrics"`` key, and what ``repro.obs.top`` renders.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        key = _label_key(name, labels)
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter(self._lock))
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = _label_key(name, labels)
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge(self._lock))
        return g

    def histogram(self, name: str, edges: tuple = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        key = _label_key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    key, Histogram(self._lock, edges))
        return h

    def snapshot(self) -> dict:
        """Plain sorted dicts; byte-stable for a deterministic workload."""
        with self._lock:
            return {
                "counters": {k: c.value
                             for k, c in sorted(self._counters.items())},
                "gauges": {k: g.value
                           for k, g in sorted(self._gauges.items())},
                "histograms": {
                    k: {"edges": list(h.edges), "counts": list(h.counts),
                        "sum": h.sum, "count": h.count}
                    for k, h in sorted(self._histograms.items())},
            }


# ------------------------------------------------------------------ spans
class SpanEmitter:
    """Causal spans over a :class:`~repro.runtime.trace.TraceRecorder`.

    ``begin`` allocates a monotonically increasing span id and emits a
    ``span_begin`` event carrying ``span``, ``parent`` (another span id
    or None — a request root), ``name`` (``job`` / ``stage`` / ``run`` /
    ``transfer``) and ``wall_ns``, the *monotonic wall* timestamp that
    gives real runs usable durations even when the backend clock is
    virtual.  ``end`` closes it.  Span events ride the ordinary trace
    stream (same lock, same seq numbers) so they interleave causally
    with the events they annotate; they are **not** fault kinds and do
    not change ``verify_invariants``.
    """

    __slots__ = ("_trace", "_now", "_ids")

    def __init__(self, trace, *, now=time.monotonic):
        self._trace = trace
        self._now = now
        self._ids = itertools.count(1)

    def begin(self, name: str, parent: Optional[int] = None,
              **fields) -> int:
        sid = next(self._ids)
        self._trace.emit("span_begin", span=sid, parent=parent, name=name,
                         wall_ns=int(self._now() * 1e9), **fields)
        return sid

    def end(self, span: Optional[int], **fields) -> None:
        if span is None:
            return
        self._trace.emit("span_end", span=span,
                         wall_ns=int(self._now() * 1e9), **fields)


# -------------------------------------------------------- codelet profiles
class CodeletProfile:
    """Per-codelet wall-time table: name → (count, total integer ns).

    Integer nanoseconds end to end — that is what
    ``time.perf_counter_ns`` yields, what the remote wire codec can
    carry (no float tag), and what merges without rounding drift.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._t: dict[str, list] = {}  # name -> [count, total_ns]

    def __len__(self) -> int:
        return len(self._t)

    def names(self) -> list:
        return sorted(self._t)

    def record(self, name: str, total_ns: int, count: int = 1) -> None:
        with self._lock:
            ent = self._t.get(name)
            if ent is None:
                self._t[name] = [count, total_ns]
            else:
                ent[0] += count
                ent[1] += total_ns

    def update(self, items: Iterable) -> None:
        """Fold ``(name, count, total_ns)`` triples — the shape remote
        ``ran`` replies carry."""
        for name, count, total_ns in items:
            self.record(str(name), int(total_ns), int(count))

    def merge(self, other: "CodeletProfile") -> None:
        with other._lock:
            triples = [(n, e[0], e[1]) for n, e in other._t.items()]
        self.update(triples)

    def to_dict(self) -> dict:
        with self._lock:
            return {n: {"count": e[0], "total_ns": e[1]}
                    for n, e in sorted(self._t.items())}

    @classmethod
    def from_dict(cls, d: dict) -> "CodeletProfile":
        p = cls()
        for name, ent in d.items():
            p.record(name, int(ent["total_ns"]), int(ent["count"]))
        return p

    def calibrate(self) -> dict:
        """Mean seconds per codelet — the constants
        ``Cluster(compute_model=...)`` charges on a ``VirtualClock``."""
        with self._lock:
            return {n: (e[1] / e[0]) * 1e-9
                    for n, e in sorted(self._t.items()) if e[0] > 0}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "CodeletProfile":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def job_wall_durations(events: Iterable[dict]) -> dict:
    """``job_start``/``job_finish`` pairs → job id → run seconds on the
    recording clock.  On a *wall* trace these are real durations — the
    coarse (per-job, not per-codelet) half of the calibration story."""
    started: dict = {}
    out: dict = {}
    for ev in events:
        if ev["kind"] == "job_start":
            started[ev["job"]] = ev["t"]
        elif ev["kind"] == "job_finish" and ev["job"] in started:
            out[ev["job"]] = ev["t"] - started.pop(ev["job"])
    return out
