"""Production mesh definitions (TPU v5e pods).

A function, not a module-level constant: importing this module never
touches jax device state (device count locks on first backend init).
"""
from __future__ import annotations

import jax


def axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto, ...)`` for ``jax.make_mesh``, or ``{}`` on
    jax < 0.5 (no ``jax.sharding.AxisType``; Auto is the default there)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod = 2 pods = 512 chips.

    Axes: "data" carries DP/FSDP, "model" carries TP/EP/sequence-parallel
    KV; "pod" (multi-pod) carries cross-DCN data parallelism.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_type_kwargs(len(axes)))


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (shardings become no-ops)."""
    return jax.make_mesh((1, 1), ("data", "model"), **axis_type_kwargs(2))
