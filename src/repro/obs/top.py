"""``python -m repro.obs.top`` — a top(1)-style view of a running Fix.

Renders the unified ``stats()`` snapshot shape (``backend`` /
``metrics`` / ``codelets`` plus backend-specific sections) that every
backend and the serving engine produce.  Three modes:

* ``--stats PATH`` — render a JSON stats snapshot from a file (the
  shape ``json.dump(backend.stats())`` writes), repeatedly unless
  ``--once``;
* default (no ``--stats``) — run a small self-contained demo workload
  on a ``VirtualClock`` cluster and render its stats, so
  ``python -m repro.obs.top --once`` works anywhere the package
  imports (the CI smoke);
* ``--interval S`` — refresh cadence for live mode.

:func:`render_snapshot` is pure (dict in, string out) and is what the
tests pin.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _fmt_bytes(n) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return (f"{int(n)}{unit}" if unit == "B"
                    else f"{n:.1f}{unit}")
        n /= 1024
    return f"{n:.1f}GiB"  # pragma: no cover - unreachable


def _counter_total(metrics: dict, name: str) -> int:
    """Sum a counter across label sets (``name`` and ``name{...}``)."""
    total = 0
    for key, val in metrics.get("counters", {}).items():
        if key == name or key.startswith(name + "{"):
            total += val
    return total


def _hist_quantile(hist: dict, q: float) -> float:
    """Upper-edge quantile estimate from fixed-bucket counts."""
    count = hist.get("count", 0)
    if count <= 0:
        return 0.0
    target = q * count
    seen = 0
    edges, counts = hist["edges"], hist["counts"]
    for i, c in enumerate(counts):
        seen += c
        if seen >= target:
            return edges[i] if i < len(edges) else float("inf")
    return edges[-1] if edges else 0.0


def _job_hist(metrics: dict) -> dict:
    """Merge ``job_latency_s`` histograms across tenant labels."""
    merged = None
    for key, h in metrics.get("histograms", {}).items():
        if key != "job_latency_s" and not key.startswith("job_latency_s{"):
            continue
        if merged is None:
            merged = {"edges": list(h["edges"]),
                      "counts": list(h["counts"]),
                      "sum": h["sum"], "count": h["count"]}
        else:
            merged["counts"] = [a + b for a, b in
                                zip(merged["counts"], h["counts"])]
            merged["sum"] += h["sum"]
            merged["count"] += h["count"]
    return merged or {"edges": [], "counts": [], "sum": 0.0, "count": 0}


def render_snapshot(stats: dict) -> str:
    """Render one unified stats snapshot as fixed-width text."""
    lines = []
    be = stats.get("backend", "?")
    if isinstance(be, dict):  # FixServeEngine.stats() nests the backend
        serving = stats.get("serving", {})
        tenants = stats.get("tenants", {})
        body = render_snapshot(be)
        lines.append("== serving ==")
        lines.append(
            "  steps={steps} decode={decode_steps} "
            "pending={pending} active={active} finished={finished}".format(
                **{k: serving.get(k, 0) for k in
                   ("steps", "decode_steps", "pending", "active",
                    "finished")}))
        bt, bh = serving.get("blocks_total", 0), serving.get("blocks_hit", 0)
        lines.append(f"  prefix blocks: {bh}/{bt} hit "
                     f"({(bh / bt if bt else 0.0):.0%})")
        if tenants:
            lines.append("  tenant      queued  inflight  admitted")
            for t, d in sorted(tenants.items()):
                lines.append(f"  {t:<10}  {d['queued']:>6}  "
                             f"{d['inflight']:>8}  {d['admitted']:>8}")
        return body + "\n" + "\n".join(lines) + "\n"

    metrics = stats.get("metrics", {}) or {}
    lines.append(f"fix obs  backend={be}")
    jobs = {o: _counter_total(metrics, "jobs_" + o)
            for o in ("submitted", "finished", "failed", "cancelled",
                      "memo_hit")}
    lines.append("jobs: " + " ".join(f"{k}={v}" for k, v in jobs.items()))
    xfers = _counter_total(metrics, "transfers_total")
    moved = _counter_total(metrics, "bytes_moved_total")
    if not xfers:  # metrics off: fall back to the legacy counters
        xfers = stats.get("transfers", 0)
        moved = stats.get("bytes_moved", 0)
    lines.append(f"transfers: total={xfers} bytes={_fmt_bytes(moved)}")
    hist = _job_hist(metrics)
    if hist["count"]:
        lines.append(
            f"job latency: n={hist['count']} "
            f"mean={hist['sum'] / hist['count']:.4f}s "
            f"p50<={_hist_quantile(hist, 0.50):g}s "
            f"p99<={_hist_quantile(hist, 0.99):g}s")
    codelets = stats.get("codelets", {}) or {}
    if codelets:
        lines.append("codelet            count   mean_ms")
        for name, ent in sorted(codelets.items()):
            cnt = ent["count"]
            mean_ms = (ent["total_ns"] / cnt / 1e6) if cnt else 0.0
            lines.append(f"{name:<18} {cnt:>6}  {mean_ms:>8.3f}")
    nodes = stats.get("nodes")
    if nodes:
        lines.append("node   busy_s    jobs")
        for name, acct in sorted(nodes.items()):
            busy = acct.get("busy_s", 0.0)
            njobs = acct.get("jobs", acct.get("items", 0))
            lines.append(f"{name:<5} {busy:>8.4f} {njobs:>6}")
    workers = stats.get("workers")
    if workers:
        lines.append("worker  alive  gen  jobs")
        for wid, w in sorted(workers.items()):
            lines.append(f"{wid:<6}  {str(w.get('alive', '?')):<5}  "
                         f"{w.get('gen', 0):>3}  {w.get('jobs', 0):>4}")
    rec = stats.get("recovery")
    if rec:
        lines.append("recovery: " + " ".join(
            f"{k}={v}" for k, v in sorted(rec.items())))
    return "\n".join(lines) + "\n"


def _demo_stats() -> dict:
    """A tiny deterministic VirtualClock workload; returns its stats."""
    from .. import fix
    from ..core.stdlib import add, fib, inc_chain
    from ..runtime import Cluster, VirtualClock

    clk = VirtualClock()
    cluster = Cluster(n_nodes=2, workers_per_node=1, clock=clk)
    try:
        be = fix.on(cluster)
        futs = [be.submit(fib(6)), be.submit(inc_chain(0, 4)),
                be.submit(add(20, 22))]
        for f in futs:
            f.result(timeout=60)
        return cluster.stats()
    finally:
        cluster.shutdown()
        clk.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.top",
        description="top-style view over a Fix stats snapshot")
    ap.add_argument("--stats", metavar="PATH",
                    help="JSON stats snapshot to render (default: run a "
                         "small demo workload)")
    ap.add_argument("--once", action="store_true",
                    help="render a single frame and exit")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="refresh interval in seconds (live mode)")
    args = ap.parse_args(argv)

    while True:
        if args.stats:
            with open(args.stats) as f:
                stats = json.load(f)
        else:
            stats = _demo_stats()
        frame = render_snapshot(stats)
        if args.once:
            sys.stdout.write(frame)
            return 0
        sys.stdout.write("\x1b[2J\x1b[H" + frame)
        sys.stdout.flush()
        time.sleep(args.interval)


if __name__ == "__main__":
    raise SystemExit(main())
