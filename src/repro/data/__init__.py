from .pipeline import TokenPipeline, corpus_handle, synth_corpus
__all__ = ["TokenPipeline", "corpus_handle", "synth_corpus"]
