"""Flash attention as a Pallas TPU kernel (online softmax over KV blocks).

TPU adaptation notes (vs the CUDA original): blocks are sized for VMEM and
the 128x128 MXU — (block_q x head_dim) and (block_k x head_dim) tiles with
head_dim padded to a lane multiple; running max/sum live in VREGs via SMEM-
free carries re-read from the output ref between grid steps (the standard
Pallas TPU pattern: the KV-block loop is the innermost grid dimension, so
carries persist in VMEM scratch across that dimension).

Grid: (batch*heads, q_blocks, kv_blocks); kv is the minormost (sequential)
axis, so m/l/acc scratch carries across kv steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                      # [block_q, hd]
    k = k_ref[0]                      # [block_k, hd]
    v = v_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]               # [block_q, 1]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)            # [block_q, block_k]
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False):
    """q: [B,S,H,hd]  k,v: [B,T,H,hd] -> [B,S,H,hd].

    The kernel runs per (batch*head); q/k/v are transposed to
    [B*H, seq, hd] so each grid cell streams KV blocks through VMEM.
    """
    B, S, H, hd = q.shape
    hd_v = v.shape[-1]                 # MLA: v head dim may differ from q/k
    T = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    kv_blocks = T // block_k

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd_v)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_blocks=kv_blocks)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // block_q, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd_v), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd_v), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd_v), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, S, hd_v).transpose(0, 2, 1, 3)
