"""Hypothesis property tests on the system's invariants."""
import struct

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import Evaluator, Handle, Repository  # noqa: E402
from repro.core.stdlib import combination  # noqa: E402

FAST = settings(max_examples=50, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------- handles
@given(st.binary(max_size=200))
@FAST
def test_content_addressing_deterministic(payload):
    assert Handle.blob(payload) == Handle.blob(payload)


@given(st.binary(max_size=200), st.binary(max_size=200))
@FAST
def test_distinct_content_distinct_handle(a, b):
    if a != b:
        assert Handle.blob(a) != Handle.blob(b)


@given(st.binary(max_size=30))
@FAST
def test_literal_payload_roundtrip(payload):
    h = Handle.blob(payload)
    assert h.is_literal and h.literal_payload() == payload


@given(st.binary(min_size=31, max_size=300))
@FAST
def test_size_metadata(payload):
    assert Handle.blob(payload).size == len(payload)


@given(st.lists(st.binary(max_size=64), max_size=8))
@FAST
def test_tree_roundtrip(payloads):
    repo = Repository()
    kids = [repo.put_blob(p) for p in payloads]
    t = repo.put_tree(kids)
    assert list(repo.get_tree(t)) == kids
    assert t.size == len(kids)


@given(st.binary(min_size=31, max_size=100))
@FAST
def test_interpretation_bitflips_are_involutive(payload):
    repo = Repository()
    t = repo.put_tree([repo.put_blob(payload)])
    app = t.application()
    assert app.unwrap_thunk() == t
    assert app.strict().unwrap_encode() == app
    assert app.shallow().unwrap_encode() == app
    assert t.as_ref().as_object() == t


# -------------------------------------------------------------- evaluator
@given(st.integers(-2**31, 2**31), st.integers(-2**31, 2**31))
@FAST
def test_add_correct_and_memoized(a, b):
    repo = Repository()
    ev = Evaluator(repo)
    th = combination(repo, "add",
                     Handle.blob(a.to_bytes(8, "little", signed=True)),
                     Handle.blob(b.to_bytes(8, "little", signed=True)))
    r1 = ev.evaluate(th.strict())
    n = ev.applications
    r2 = ev.evaluate(th.strict())
    assert r1 == r2 and ev.applications == n
    assert int.from_bytes(repo.get_blob(r1), "little", signed=True) == a + b


@given(st.lists(st.binary(min_size=1, max_size=80), min_size=1, max_size=10),
       st.integers(0, 9))
@FAST
def test_selection_returns_exact_child(payloads, idx)  :
    idx = idx % len(payloads)
    repo = Repository()
    ev = Evaluator(repo)
    tree = repo.put_tree([repo.put_blob(p) for p in payloads])
    pair = repo.put_tree([tree, repo.put_blob(struct.pack("<q", idx))])
    out = ev.evaluate(pair.selection_of().strict())
    assert repo.get_blob(out) == payloads[idx]


@given(st.integers(0, 18))
@FAST
def test_fib_matches_reference(n):
    def fib(k):
        a, b = 0, 1
        for _ in range(k):
            a, b = b, a + b
        return a

    repo = Repository()
    ev = Evaluator(repo)
    th = combination(repo, "fib", Handle.blob(n.to_bytes(8, "little", signed=True)))
    out = ev.evaluate(th.strict())
    assert int.from_bytes(repo.get_blob(out), "little", signed=True) == fib(n)


@given(st.binary(min_size=40, max_size=400), st.integers(0, 100),
       st.integers(1, 50))
@FAST
def test_slice_blob_lineage_determinism(corpus, start, ln):
    """Recompute-from-recipe must be byte-identical — the property that
    makes the runtime's recompute-over-transfer safe."""
    repo = Repository()
    ev = Evaluator(repo)
    c = repo.put_blob(corpus)
    th = combination(repo, "slice_blob", c,
                     Handle.blob(start.to_bytes(8, "little", signed=True)),
                     Handle.blob(ln.to_bytes(8, "little", signed=True)))
    out1 = ev.evaluate(th.strict())
    # second, independent evaluator over a fresh repo: same handle
    repo2 = Repository()
    ev2 = Evaluator(repo2)
    c2 = repo2.put_blob(corpus)
    th2 = combination(repo2, "slice_blob", c2,
                      Handle.blob(start.to_bytes(8, "little", signed=True)),
                      Handle.blob(ln.to_bytes(8, "little", signed=True)))
    out2 = ev2.evaluate(th2.strict())
    assert out1.content_key() == out2.content_key()


# ------------------------------------------------------------- checkpoint
@given(st.dictionaries(st.sampled_from(["a", "b", "c", "w1", "w2"]),
                       st.lists(st.floats(-1e3, 1e3, allow_nan=False,
                                          width=32), min_size=1, max_size=8),
                       min_size=1, max_size=5))
@FAST
def test_checkpoint_roundtrip(tree):
    import numpy as np

    from repro.checkpoint import load_tree, save_tree

    pytree = {k: np.asarray(v, np.float32) for k, v in tree.items()}
    repo = Repository()
    h = save_tree(repo, pytree)
    back = load_tree(repo, h)
    assert set(back) == set(pytree)
    for k in pytree:
        np.testing.assert_array_equal(back[k], pytree[k])
    # same content => same root handle (dedup property)
    assert save_tree(repo, pytree) == h


# --------------------------------------------------------------- sharding
@given(st.sampled_from([(16, 16), (2, 16, 16)]),
       st.sampled_from([(8, 128), (32, 64), (7, 13), (256, 4096), (1, 1)]))
@FAST
def test_sharder_specs_always_valid(mesh_shape, dim):
    """Resolved PartitionSpecs never violate divisibility (degrade instead)."""
    import numpy as np

    from repro.parallel.sharding import Sharder

    class FakeMesh:
        axis_names = ("pod", "data", "model")[-len(mesh_shape):]
        shape = dict(zip(axis_names, mesh_shape))

    sh = Sharder.__new__(Sharder)
    sh.mesh = FakeMesh()
    sh.rules = __import__("repro.parallel.sharding", fromlist=["x"]).BASE_RULES
    sh.degradations = []
    spec = sh.spec(("heads", "mlp"), dim)
    sizes = dict(zip(FakeMesh.axis_names, mesh_shape))
    for i, part in enumerate(spec):
        if part is None:
            continue
        names = (part,) if isinstance(part, str) else part
        extent = int(np.prod([sizes[n] for n in names]))
        assert dim[i] % extent == 0
