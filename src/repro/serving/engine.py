"""Serving engine: batched decode with a content-addressed prefix cache.

The Fix view of a KV cache: a prompt's KV state is a *deterministic product
of (weights-handle, prompt-handle)* — so prefill results are memoizable and
shareable across requests exactly like any other Encode.  The engine keys
prefill work by the prompt's content hash (per-block, so common prefixes
dedup block-wise — the B+-tree trick applied to token streams) and performs
all "I/O" (prefill compute, cache fetch) before binding a decode slot: late
binding again, at the request level.

This module is the *host-level* engine: callables in, callables out, no Fix
runtime required (``launch/serve.py`` drives it over jitted model steps).
:mod:`repro.serving.fixserve` is the same engine shape with every prefill
block and decode step running as a Fix codelet through a
:class:`~repro.fix.backend.Backend` — there the prefix cache holds content
handles instead of host states and a hit is a *placement* decision.

The batching discipline is continuous: finished rows are refilled from the
queue each step without stopping the batch.  The decode contract is the
batched one from ``parallel.steps``::

    decode_fn(states, tokens[B, 1]) -> (logits[B, 1, V], states)

where ``states`` is a list of per-row opaque states (the engine owns greedy
argmax), and prefill is *resumable* so a cached prefix is actually reused::

    prefill_fn(tokens[S'], state) -> state      # state=None starts fresh

Cache correctness contract (the seed engine violated both halves):

* ``PrefixCache`` stores the state *at each block boundary* — a lookup that
  matches ``n`` blocks returns a state covering exactly those ``n`` blocks,
  never tokens beyond them;
* eviction drops whole chains: if a boundary's entry goes, every cached
  descendant boundary (whose chain runs through it) goes too, so a lookup
  can never land on a dangling interior block.
"""
from __future__ import annotations

import hashlib
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


class RequestError(ValueError):
    """A request rejected at ``submit()`` — typed, never a mid-batch crash."""


class EmptyPromptError(RequestError):
    """Prompt is empty or not a 1-D integer token array."""


class BudgetError(RequestError):
    """``max_new`` is not a non-negative integer."""


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # int32 [prompt_len]
    max_new: int
    tenant: str = "default"
    out_tokens: list = field(default_factory=list)
    done: bool = False
    # ---- filled by the engine (host-clock seconds; None until reached)
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def queue_wait_s(self) -> float:
        """Admission-queue time — the per-request starvation metric."""
        if self.t_submit is None or self.t_admit is None:
            return 0.0
        return self.t_admit - self.t_submit

    @property
    def latency_s(self) -> float:
        if self.t_submit is None or self.t_done is None:
            return 0.0
        return self.t_done - self.t_submit


def prompt_key(tokens: np.ndarray, block: int = 16) -> list:
    """Content-addressed prefix-block keys (block-wise prefix identity).

    ``keys[j]`` names the token prefix ``tokens[: min((j+1)*block, len)]``
    — a chained hash, so two prompts share ``keys[j]`` iff they agree on
    every token through that boundary (a trailing partial block gets its
    own boundary and can only match exactly).
    """
    keys = []
    h = hashlib.blake2b(digest_size=16)
    for i in range(0, len(tokens), block):
        h.update(np.ascontiguousarray(tokens[i: i + block],
                                      np.int32).tobytes())
        keys.append(h.copy().digest())
    return keys


def validate_request(req: "Request") -> None:
    """Shared ``submit()``-time validation (host and Fix engines): typed
    errors for malformed requests, prompt normalized to contiguous int32."""
    prompt = np.asarray(req.prompt)
    if prompt.ndim != 1 or prompt.size == 0:
        raise EmptyPromptError(
            f"request {req.rid}: prompt must be a non-empty 1-D token "
            f"array (got shape {prompt.shape})")
    if not np.issubdtype(prompt.dtype, np.integer):
        raise EmptyPromptError(
            f"request {req.rid}: prompt dtype {prompt.dtype} is not an "
            f"integer token type")
    if isinstance(req.max_new, bool) or not isinstance(req.max_new, int):
        raise BudgetError(
            f"request {req.rid}: max_new must be an int, got "
            f"{type(req.max_new).__name__}")
    if req.max_new < 0:
        raise BudgetError(
            f"request {req.rid}: max_new must be >= 0, got {req.max_new}")
    req.prompt = np.ascontiguousarray(prompt, np.int32)


class _Entry:
    __slots__ = ("state", "chain")

    def __init__(self, state, chain: tuple):
        self.state = state
        self.chain = chain  # the full key chain through this boundary


class PrefixCache:
    """LRU of per-*boundary* states keyed by prefix-block hash chains.

    Each entry holds the state covering exactly its chain of blocks, so a
    lookup can never return tokens beyond the matched prefix.  Hits and
    misses are counted **per block**: a prompt of 5 blocks matching 3 is
    3 hits + 2 misses, not one of either.

    Invariant (checked by tests): for every cached boundary, every
    ancestor boundary on its chain is also cached — inserts that would
    dangle are refused and eviction cascades to descendants.
    """

    def __init__(self, capacity: int = 16):
        self.capacity = capacity
        self._lru: "OrderedDict[bytes, _Entry]" = OrderedDict()
        self.hits = 0          # blocks served from cache
        self.misses = 0        # blocks that had to be prefilled
        self.evictions = 0     # entries dropped (including cascades)

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, key: bytes) -> bool:
        return key in self._lru

    def chain_of(self, key: bytes) -> Optional[tuple]:
        ent = self._lru.get(key)
        return None if ent is None else ent.chain

    def lookup(self, keys: list):
        """Longest cached prefix: returns ``(n_blocks_covered, state)``.

        The whole matched chain is refreshed to MRU (not just the matched
        boundary) so eviction can't orphan the ancestors of a hot entry.
        """
        for n in range(len(keys), 0, -1):
            ent = self._lru.get(keys[n - 1])
            if ent is not None:
                for k in ent.chain:
                    if k in self._lru:
                        self._lru.move_to_end(k)
                self.hits += n
                self.misses += len(keys) - n
                return n, ent.state
        self.misses += len(keys)
        return 0, None

    def insert(self, chain: list, state) -> bool:
        """Cache ``state`` for the boundary named by ``chain[-1]``.

        ``chain`` is the *full* key chain ``prompt_key(...)[: j + 1]`` and
        ``state`` covers exactly those blocks.  Refused (returns False)
        when an ancestor is missing — a dangling insert would break the
        chain invariant that eviction relies on.
        """
        if not chain:
            return False
        key = chain[-1]
        for k in chain[:-1]:
            if k not in self._lru:
                return False
        ent = self._lru.get(key)
        if ent is None:
            self._lru[key] = _Entry(state, tuple(chain))
        else:
            ent.state = state
        self._lru.move_to_end(key)
        while len(self._lru) > self.capacity:
            victim, _ = self._lru.popitem(last=False)
            self.evictions += 1
            self._evict_descendants(victim)
        return True

    def _evict_descendants(self, victim: bytes) -> None:
        """Chains evict whole: drop every entry whose chain runs through
        ``victim`` so no lookup can land beyond a missing ancestor."""
        dangling = [k for k, e in self._lru.items() if victim in e.chain]
        for k in dangling:
            del self._lru[k]
            self.evictions += 1


class ServeEngine:
    """Continuous batching over a fixed-width batched decode step.

    ``prefill_fn(tokens, state) -> state`` (resumable, ``state=None``
    starts fresh) and ``decode_fn(states, tokens[B,1]) ->
    (logits[B,1,V], states)`` come from ``parallel.steps`` /
    ``launch.serve``; in tests they are small deterministic callables
    (:func:`repro.serving.model.toy_fns`).

    ``admission`` is an optional :class:`repro.serving.admission.TenantQueue`
    — without one, admission is FIFO and tenant-blind.
    """

    def __init__(self, prefill_fn: Callable, decode_fn: Callable,
                 batch: int, eos: int = 0,
                 prefix_cache: Optional[PrefixCache] = None,
                 block: int = 16, admission=None,
                 now: Callable[[], float] = time.monotonic):
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.batch = batch
        self.eos = eos
        self.block = block
        # `is None`, not `or`: an empty PrefixCache is falsy (len 0), and a
        # caller-supplied capacity-0 cache is the cache-disabled ablation
        self.cache = PrefixCache() if prefix_cache is None else prefix_cache
        self.admission = admission
        self.queue: list[Request] = []        # FIFO path (admission=None)
        self.active: list[Optional[Request]] = [None] * batch
        self.finished: list[Request] = []
        self.steps = 0
        self._now = now

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        """Validate and enqueue; raises :class:`RequestError` subtypes."""
        validate_request(req)
        req.t_submit = self._now()
        if req.max_new == 0:
            # zero-budget: a valid request that asks for nothing — complete
            # immediately, never occupy a slot, never emit a token
            req.t_admit = req.t_done = req.t_submit
            req.done = True
            self.finished.append(req)
            return
        if self.admission is not None:
            self.admission.push(req)
        else:
            self.queue.append(req)

    def pending(self) -> int:
        return (len(self.admission) if self.admission is not None
                else len(self.queue))

    def _next_request(self) -> Optional[Request]:
        if self.admission is not None:
            return self.admission.pop()
        return self.queue.pop(0) if self.queue else None

    # ----------------------------------------------------------- prefill
    def _admit(self) -> None:
        for slot in range(self.batch):
            if self.active[slot] is not None:
                continue
            req = self._next_request()
            if req is None:
                break
            keys = prompt_key(req.prompt, self.block)
            n, state = self.cache.lookup(keys)
            # resume from the longest cached boundary; prefill only the
            # uncovered tail, caching every new boundary on the way
            for j in range(n, len(keys)):
                seg = req.prompt[j * self.block: (j + 1) * self.block]
                state = self.prefill_fn(seg, state)
                self.cache.insert(keys[: j + 1], state)
            req._state = state  # type: ignore[attr-defined]
            req._last = int(req.prompt[-1])  # type: ignore[attr-defined]
            req.t_admit = self._now()
            self.active[slot] = req

    # ------------------------------------------------------------ decode
    def step(self) -> int:
        """One batched decode step; returns the number of finished rows."""
        self._admit()
        live = [(i, r) for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        states = [r._state for _, r in live]
        tokens = np.asarray([[r._last] for _, r in live], np.int32)
        logits, states = self.decode_fn(states, tokens)
        finished = 0
        now = self._now()
        for row, (i, req) in enumerate(live):
            req._state = states[row]
            tok = int(np.argmax(logits[row, -1]))
            req._last = tok
            req.out_tokens.append(tok)
            if req.t_first is None:
                req.t_first = now
            if tok == self.eos or len(req.out_tokens) >= req.max_new:
                req.done = True
                req.t_done = now
                self.active[i] = None
                self.finished.append(req)
                if self.admission is not None:
                    self.admission.release(req.tenant)
                finished += 1
        self.steps += 1
        return finished

    def run(self, max_steps: int = 10_000) -> None:
        while (self.pending() or any(r is not None for r in self.active)) \
                and self.steps < max_steps:
            self.step()
