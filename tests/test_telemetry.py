"""The PR-10 telemetry plane: registry determinism, unified stats across
all three backends, causal spans, codelet profiles and the
record → calibrate → replay seam, and the metric/trace lockstep
invariant under seeded chaos.

Two load-bearing contracts pinned here:

* telemetry at defaults (metrics on, spans off) does not perturb a
  ``VirtualClock`` schedule — the golden quickstart trace replays
  byte-identically (the metrics plane never touches a clock);
* every counter is incremented exactly where its trace event is
  emitted, so under fault schedules full of retries and resubmits the
  registry never double-counts: ``jobs_*`` metrics equal trace-derived
  event counts and ``*_total`` transfer metrics equal the legacy
  accounting fields.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))

import repro.fix as fix  # noqa: E402
from repro.core.stdlib import add, fib, inc_chain  # noqa: E402
from repro.runtime import (  # noqa: E402
    Cluster,
    CodeletProfile,
    MetricsRegistry,
    SpanEmitter,
    TraceRecorder,
    VirtualClock,
)
from repro.runtime.trace import percentile, replay_check, tenant_report  # noqa: E402
from workloads import FIXTURE, run_chaos_case, run_quickstart  # noqa: E402

pytestmark = pytest.mark.usefixtures("no_thread_leaks")


# ------------------------------------------------------------- registry
class TestRegistry:
    def test_counter_gauge_histogram_roundtrip(self):
        m = MetricsRegistry()
        m.counter("jobs_finished").inc()
        m.counter("jobs_finished", tenant="t0").inc(3)
        m.gauge("queue_depth", link="n0->n1").set(7)
        m.histogram("job_latency_s").observe(0.0004)
        m.histogram("job_latency_s").observe(999.0)  # overflow bucket
        snap = m.snapshot()
        assert snap["counters"] == {"jobs_finished": 1,
                                    "jobs_finished{tenant=t0}": 3}
        assert snap["gauges"] == {"queue_depth{link=n0->n1}": 7}
        h = snap["histograms"]["job_latency_s"]
        assert h["count"] == 2
        assert h["counts"][-1] == 1        # > last edge lands in overflow
        assert sum(h["counts"]) == 2

    def test_label_keys_sorted_and_cached(self):
        m = MetricsRegistry()
        a = m.counter("c", b="2", a="1")
        b = m.counter("c", a="1", b="2")
        assert a is b  # same instrument regardless of kwarg order
        assert list(m.snapshot()["counters"]) == ["c{a=1,b=2}"]

    def test_snapshot_byte_stable(self):
        def build():
            m = MetricsRegistry()
            for t in ("b", "a"):
                m.counter("jobs_submitted", tenant=t).inc(2)
            m.histogram("job_latency_s").observe(0.01)
            return json.dumps(m.snapshot(), sort_keys=True)
        assert build() == build()


# ------------------------------------------------------ percentile edges
class TestPercentileEdges:
    def test_empty_population(self):
        assert percentile([], 50) == 0.0
        assert percentile([], 0) == 0.0
        assert percentile([], 100) == 0.0

    def test_singleton(self):
        for p in (0, 1, 50, 99, 100):
            assert percentile([4.2], p) == 4.2

    def test_extremes_clamp(self):
        vals = [5.0, 1.0, 3.0]
        assert percentile(vals, -10) == 1.0
        assert percentile(vals, 0) == 1.0
        assert percentile(vals, 100) == 5.0
        assert percentile(vals, 250) == 5.0

    def test_float_rank_no_bump(self):
        # 0.55 * 20 == 11.000000000000002: must stay rank 11, not 12
        vals = list(range(1, 21))
        assert percentile(vals, 55) == 11

    def test_tenant_report_empty_and_tagged(self):
        assert tenant_report([]) == {}
        evs = [{"seq": 0, "t": 0.0, "kind": "job_submit", "job": 1,
                "tenant": "t0"},
               {"seq": 1, "t": 0.5, "kind": "job_finish", "job": 1}]
        rep = tenant_report(evs)
        assert rep["t0"]["jobs"] == 1
        assert rep["t0"]["finished"] == 1
        # single-sample percentiles: the sample itself, p50 == p99
        assert rep["t0"]["p50_latency_s"] == rep["t0"]["p99_latency_s"] == 0.5


# ----------------------------------------------------- golden invariance
class TestGoldenInvariance:
    def test_quickstart_replay_identical_with_metrics_on(self):
        # metrics default ON — this replay passing IS the zero-perturbation
        # claim for the telemetry plane
        diff = replay_check(lambda rec: run_quickstart(trace=rec), FIXTURE)
        assert diff.identical, diff.explain()

    def test_spans_are_pure_annotation(self):
        """spans=True adds span_begin/span_end events but changes nothing
        else: stripping them (and seq numbers) recovers the spans-off
        stream exactly."""
        def run(spans):
            tr = TraceRecorder()
            clk = VirtualClock()
            c = Cluster(n_nodes=2, workers_per_node=1, clock=clk,
                        trace=tr, spans=spans)
            try:
                be = fix.on(c)
                futs = [be.submit(fib(6)), be.submit(add(20, 22))]
                for f in futs:
                    f.result(timeout=60)
            finally:
                c.shutdown()
                clk.close()
            return [e.to_dict() for e in tr.events]

        plain, spanned = run(False), run(True)
        assert not any(e["kind"].startswith("span_") for e in plain)
        assert any(e["kind"] == "span_begin" for e in spanned)
        assert any(e["kind"] == "span_end" for e in spanned)

        def strip(evs):
            return [{k: v for k, v in e.items() if k != "seq"}
                    for e in evs if not e["kind"].startswith("span_")]
        assert strip(spanned) == strip(plain)

    def test_span_parent_links_resolve(self):
        tr = TraceRecorder()
        clk = VirtualClock()
        c = Cluster(n_nodes=2, workers_per_node=1, clock=clk,
                    trace=tr, spans=True)
        try:
            fix.on(c).submit(fib(6)).result(timeout=60)
        finally:
            c.shutdown()
            clk.close()
        begins = {e.fields["span"]: e.fields
                  for e in tr.events if e.kind == "span_begin"}
        ends = [e.fields["span"] for e in tr.events if e.kind == "span_end"]
        assert begins
        for sid, f in begins.items():
            if f["parent"] is not None:
                assert f["parent"] in begins  # every parent is a real span
        assert set(ends) <= set(begins)       # ends close known spans
        # at least one child job hangs off the root (fib recursion)
        assert any(f["parent"] is not None for f in begins.values())


# -------------------------------------------------------- unified stats
class TestUnifiedStats:
    def test_local_backend_stats(self):
        with fix.local() as be:
            assert be.run(add(40, 2))
            st = be.stats()
        assert st["backend"] == "local"
        assert "metrics" in st
        assert st["codelets"]["add"]["count"] >= 1
        assert st["codelets"]["add"]["total_ns"] > 0

    def test_cluster_backend_stats(self):
        clk = VirtualClock()
        c = Cluster(n_nodes=2, workers_per_node=1, clock=clk)
        try:
            be = fix.on(c)
            be.submit(add(1, 2)).result(timeout=60)
            be.submit(add(1, 2), tenant="acme").result(timeout=60)
            st = be.stats()
        finally:
            c.shutdown()
            clk.close()
        assert st["backend"] == "cluster"
        cnt = st["metrics"]["counters"]
        assert cnt["jobs_submitted"] >= 1
        # the second submit is a memo hit billed to the tenant label
        assert cnt.get("jobs_memo_hit{tenant=acme}", 0) == 1
        assert st["codelets"]["add"]["count"] >= 1
        assert set(st["nodes"]) == {"client", "n0", "n1"}

    def test_metrics_off_is_supported(self):
        clk = VirtualClock()
        c = Cluster(n_nodes=2, workers_per_node=1, clock=clk, metrics=False)
        try:
            fix.on(c).submit(add(1, 2)).result(timeout=60)
            st = c.stats()
        finally:
            c.shutdown()
            clk.close()
        assert st["metrics"] == {}
        assert st["transfers"] == 0 or st["transfers"] >= 0  # legacy intact

    def test_remote_backend_stats(self):
        with fix.remote(n_workers=1) as be:
            assert be.run(add(40, 2), timeout=60)
            st = be.stats()
            prof = be.codelet_profile()
        assert st["backend"] == "remote"
        assert st["metrics"]["counters"]["jobs_submitted"] >= 1
        assert st["metrics"]["counters"]["jobs_finished"] >= 1
        # lockstep with the legacy accounting fields
        assert st["metrics"]["counters"]["transfers_total"] == st["transfers"]
        assert (st["metrics"]["counters"]["bytes_moved_total"]
                == st["bytes_moved"])
        # worker wall profile shipped back in the ran reply
        assert st["codelets"]["add"]["count"] >= 1
        assert prof.calibrate()["add"] > 0.0
        assert "recovery" in st and "store" in st  # legacy keys intact

    def test_tenant_labels_agree_with_tenant_report(self):
        tr = TraceRecorder()
        clk = VirtualClock()
        c = Cluster(n_nodes=2, workers_per_node=1, clock=clk, trace=tr)
        try:
            be = fix.on(c)
            be.submit(inc_chain(0, 3), tenant="t0").result(timeout=60)
            be.submit(add(5, 5), tenant="t1").result(timeout=60)
            st = c.stats()
        finally:
            c.shutdown()
            clk.close()
        rep = tenant_report(tr.events)
        cnt = st["metrics"]["counters"]
        for ten in ("t0", "t1"):
            assert cnt[f"jobs_submitted{{tenant={ten}}}"] == rep[ten]["jobs"]
            assert (cnt[f"jobs_finished{{tenant={ten}}}"]
                    == rep[ten]["finished"])


# ------------------------------------------------- calibration (item 3)
class TestCalibration:
    def test_remote_profile_calibrates_virtual_clock(self):
        """The record → model → replay seam: wall timings from a real
        fix.remote() run, folded into a CodeletProfile, change the
        simulated makespan of a compute-heavy workload once installed
        via Cluster(compute_model=...)."""
        with fix.remote(n_workers=1) as be:
            assert be.run(fib(10), timeout=120)
            prof = be.codelet_profile()
        assert len(prof) >= 1
        model = prof.calibrate()
        assert model["fib"] > 0.0

        def makespan(compute_model):
            clk = VirtualClock()
            c = Cluster(n_nodes=2, workers_per_node=1, clock=clk,
                        compute_model=compute_model)
            try:
                fix.on(c).submit(fib(10)).result(timeout=120)
                return clk.now()
            finally:
                c.shutdown()
                clk.close()

        free = makespan(None)
        charged = makespan(prof)  # CodeletProfile accepted directly
        assert charged > free
        # the charge is the modeled per-application cost, deterministically
        assert makespan(prof) == charged

    def test_profile_serialization_roundtrip(self, tmp_path):
        p = CodeletProfile()
        p.record("fib", 3_000_000, count=3)
        p.update([("add", 2, 500_000)])
        path = tmp_path / "prof.json"
        p.save(str(path))
        q = CodeletProfile.load(str(path))
        assert q.to_dict() == p.to_dict()
        assert q.calibrate() == {"add": 500_000 / 2 * 1e-9,
                                 "fib": 3_000_000 / 3 * 1e-9}

    def test_span_emitter_standalone(self):
        tr = TraceRecorder()
        sp = SpanEmitter(tr)
        root = sp.begin("request", rid=1)
        child = sp.begin("job", parent=root, job=7)
        sp.end(child, status="ok")
        sp.end(root)
        sp.end(None)  # no-op by contract
        kinds = [e.kind for e in tr.events]
        assert kinds == ["span_begin", "span_begin", "span_end", "span_end"]
        assert tr.events[1].fields["parent"] == root


# ------------------------------------------------------ chaos lockstep
class TestChaosLockstep:
    @pytest.mark.parametrize("seed", [3, 11])
    def test_no_double_counting_under_faults(self, seed):
        """Metric/trace lockstep under seeded fault schedules: retries,
        resubmits and recomputes must not double-count.  The registry's
        jobs_* counters equal trace-derived event counts, and the
        transfer counters equal the cluster's legacy accounting."""
        tr = TraceRecorder()
        res = run_chaos_case(seed, trace=tr)
        assert res["violations"] == []
        st = res["fault_stats"]
        cnt = st["metrics"]["counters"]

        def total(name):
            return sum(v for k, v in cnt.items()
                       if k == name or k.startswith(name + "{"))

        kinds = {}
        for e in tr.events:
            kinds[e.kind] = kinds.get(e.kind, 0) + 1
        assert total("jobs_submitted") == kinds.get("job_submit", 0)
        assert total("jobs_finished") == kinds.get("job_finish", 0)
        assert total("jobs_failed") == kinds.get("job_fail", 0)
        assert total("jobs_cancelled") == kinds.get("job_cancel", 0)
        assert total("jobs_memo_hit") == kinds.get("job_memo_hit", 0)
        assert total("transfers_total") == st["transfers"]
        assert total("bytes_moved_total") == st["bytes_moved"]
