"""HLO cost rollup: exact loop-aware FLOPs / HBM bytes / collective bytes.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, so any
scanned model (layers, microbatches) is undercounted by the trip count.
This module parses the post-optimization HLO text, builds the computation
call graph, extracts static trip counts from while conditions, and rolls up
per-computation costs weighted by execution multiplicity:

* FLOPs: dots = 2 * prod(result) * K (K = contraction extent from operand
  shapes); elementwise/reduce ~ 1 flop per element.
* HBM bytes: per *top-level* (post-fusion) instruction: operands + result
  (fusion internals are VMEM traffic, skipped) — matching XLA's own
  bytes-accessed convention.
* Collectives: payload bytes by kind, loop-multiplied.

Validated in tests against cost_analysis() on unrolled references.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+) = (.+?) ([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(%[\w.\-]+)\s*\((.*?)\)\s*->", re.M)
_PARAM_RE = re.compile(r"([\w.\-]+): ([\w\[\],]+)")
_OPERAND_RE = re.compile(r"%[\w.\-]+")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "cosine", "sine", "expm1", "log1p", "select", "compare",
    "and", "or", "xor", "not", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "sign", "atan2", "remainder", "clamp",
    "exponential-minus-one", "cbrt", "erf",
}


def _parse_shape(shape_text: str):
    """Total (elements, bytes) across all array shapes in the text."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _shape_dims(shape_text: str):
    """Dims of the FIRST array shape in the text."""
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",") if d]


@dataclass
class Instr:
    name: str
    shape_text: str
    op: str
    rest: str          # everything after the open paren
    operands: list = field(default_factory=list)
    jax_op: str = ""   # op_name metadata (jax source op path)


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    param_shapes: dict = field(default_factory=dict)


class HloModuleCost:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, Computation] = {}
        self.shape_of: dict[str, str] = {}      # %name -> shape text
        self.const_val: dict[str, int] = {}      # s32 constants
        self.entry: str = ""
        self._parse(hlo_text)
        self.mult = self._multipliers()

    # --------------------------------------------------------------- parse
    def _parse(self, text: str) -> None:
        cur: Computation | None = None
        for line in text.splitlines():
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.rstrip().endswith("{"):
                cur = Computation(hdr.group(1))
                self.comps[cur.name] = cur
                if "ENTRY" in line:
                    self.entry = cur.name
                for pname, pshape in _PARAM_RE.findall(hdr.group(2)):
                    cur.param_shapes["%" + pname] = pshape
                    self.shape_of["%" + pname] = pshape
                continue
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY (%[\w.\-]+)", line)
                if m:
                    cur = Computation(m.group(1))
                    self.comps[cur.name] = cur
                    self.entry = cur.name
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            d = _DEF_RE.match(line)
            if not d:
                continue
            name, shape_text, op, rest = d.groups()
            self.shape_of[name] = shape_text
            # operand list: %refs before any ), attribute section
            args = rest.split("), ")[0] if "), " in rest else rest.rstrip(")")
            operands = _OPERAND_RE.findall(args)
            nm = re.search(r'op_name="([^"]+)"', rest)
            cur.instrs.append(Instr(name, shape_text, op, rest, operands,
                                    nm.group(1) if nm else ""))
            if op == "constant":
                m = re.search(r"constant\((-?\d+)\)", line)
                if m:
                    self.const_val[name] = int(m.group(1))

    # -------------------------------------------------------- trip counts
    def _trip_count(self, cond_name: str, while_instr: Instr) -> int:
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        for ins in cond.instrs:
            if ins.op == "compare" and "direction=LT" in ins.rest:
                for opnd in ins.operands:
                    if opnd in self.const_val:
                        return max(self.const_val[opnd], 1)
                # operands are params of a wrapped computation: resolve via
                # the fusion call site inside cond
            if ins.op == "fusion" and "calls=" in ins.rest:
                callee = re.search(r"calls=(%[\w.\-]+)", ins.rest)
                if callee and callee.group(1) in self.comps:
                    inner = self.comps[callee.group(1)]
                    for iin in inner.instrs:
                        if iin.op == "compare" and "direction=LT" in iin.rest:
                            # map param_i -> call-site operand i
                            params = list(inner.param_shapes)
                            for opnd in iin.operands:
                                if opnd in params:
                                    idx = params.index(opnd)
                                    if idx < len(ins.operands):
                                        site = ins.operands[idx]
                                        if site in self.const_val:
                                            return max(self.const_val[site], 1)
        return 1

    # -------------------------------------------------------- multipliers
    def _multipliers(self) -> dict[str, float]:
        mult: dict[str, float] = {c: 0.0 for c in self.comps}
        if self.entry not in self.comps:
            # fall back: first computation
            self.entry = next(iter(self.comps), "")
        if not self.entry:
            return mult
        mult[self.entry] = 1.0
        # propagate in dependency order via repeated passes (call graph is a
        # DAG; few passes suffice)
        for _ in range(len(self.comps)):
            changed = False
            for cname, comp in self.comps.items():
                m = mult.get(cname, 0.0)
                if m == 0.0:
                    continue
                for ins in comp.instrs:
                    callees: list[tuple[str, float]] = []
                    if ins.op == "fusion":
                        c = re.search(r"calls=(%[\w.\-]+)", ins.rest)
                        if c:
                            callees.append((c.group(1), m))
                    elif ins.op == "while":
                        b = re.search(r"body=(%[\w.\-]+)", ins.rest)
                        c = re.search(r"condition=(%[\w.\-]+)", ins.rest)
                        if b and c:
                            trip = self._trip_count(c.group(1), ins)
                            callees.append((b.group(1), m * trip))
                            callees.append((c.group(1), m * (trip + 1)))
                    elif ins.op == "conditional":
                        for c in re.findall(r"%[\w.\-]+",
                                            ins.rest.split("branch_computations=")[-1]
                                            if "branch_computations" in ins.rest else ""):
                            callees.append((c, m))  # upper bound: every branch
                    elif ins.op in ("call", "async-start"):
                        c = re.search(r"to_apply=(%[\w.\-]+)", ins.rest)
                        if c:
                            callees.append((c.group(1), m))
                    for callee, cm in callees:
                        if callee in mult and cm > mult[callee]:
                            mult[callee] = cm
                            changed = True
            if not changed:
                break
        return mult

    # ------------------------------------------------------------- rollup
    def _dot_flops(self, ins: Instr) -> float:
        _, out_dims = _shape_dims(ins.shape_text)
        out_elems = math.prod(out_dims) if out_dims else 0
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
        if m and ins.operands:
            lhs_shape = self.shape_of.get(ins.operands[0], "")
            _, lhs_dims = _shape_dims(lhs_shape)
            for d in m.group(1).split(","):
                if d and int(d) < len(lhs_dims):
                    k *= lhs_dims[int(d)]
        return 2.0 * out_elems * k

    def flops(self) -> float:
        total = 0.0
        for cname, comp in self.comps.items():
            m = self.mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                if ins.op in ("dot", "dot_general") or ins.op == "dot":
                    total += m * self._dot_flops(ins)
                elif ins.op == "convolution":
                    # rare here; approximate as dot on result * window
                    elems, _ = _parse_shape(ins.shape_text)
                    total += m * 2.0 * elems
                elif ins.op in _ELEMENTWISE_FLOP_OPS:
                    elems, _ = _parse_shape(ins.shape_text)
                    total += m * elems
                elif ins.op in ("reduce", "reduce-window"):
                    if ins.operands:
                        elems, _ = _parse_shape(self.shape_of.get(ins.operands[0], ""))
                        total += m * elems
        return total

    def _root_op(self, comp_name: str) -> str:
        comp = self.comps.get(comp_name)
        if comp and comp.instrs:
            return comp.instrs[-1].op
        return ""

    def _fusion_operand_bytes(self, ins: Instr, callee_name: str) -> float:
        """Bytes a fusion actually reads per operand: an operand whose only
        consumers inside the fused computation are dynamic-slice / gather is
        read slice-wise, not in full (the stacked layer buffers of a scanned
        model enter every per-iteration fusion but only one slice is
        touched)."""
        self._build_legalization_maps()
        callee = self.comps.get(callee_name)
        if callee is None:
            return sum(self._operand_bytes(o) for o in ins.operands)
        # param index -> name, and param name -> consuming instrs
        param_names: dict[int, str] = {}
        for cins in callee.instrs:
            if cins.op == "parameter":
                m = re.search(r"parameter\((\d+)", cins.rest)
                if m:
                    param_names[int(m.group(1))] = cins.name
        consumers: dict[str, list] = {}
        for cins in callee.instrs:
            for o in cins.operands:
                consumers.setdefault(o, []).append(cins)
        total = 0.0
        for i, opnd in enumerate(ins.operands):
            full = self._operand_bytes(opnd)
            pname = param_names.get(i)
            cons = consumers.get(pname, []) if pname else []
            if cons and all(c.op in ("dynamic-slice", "gather") for c in cons):
                total += sum(_parse_shape(c.shape_text)[1] for c in cons)
            else:
                total += full
        return total

    def _build_legalization_maps(self) -> None:
        """XLA:CPU has no native bf16: it wraps dots/elementwise in
        f32 converts ('wrapped_convert' fusions whose op_name metadata
        points at the *consumer*, not a user convert_element_type).  On the
        TPU target these buffers don't exist, so traffic accounting
        (a) skips legalization converts, and (b) counts operands defined by
        them at the pre-convert width."""
        if hasattr(self, "_legal_src"):
            return
        self._legal_src: dict[str, str] = {}   # convert result -> true source
        self._def_instr: dict[str, Instr] = {}
        for comp in self.comps.values():
            for ins in comp.instrs:
                self._def_instr[ins.name] = ins
        for comp in self.comps.values():
            for ins in comp.instrs:
                is_conv = ins.op == "convert"
                if ins.op == "fusion":
                    c = re.search(r"calls=(%[\w.\-]+)", ins.rest)
                    is_conv = bool(c) and self._root_op(c.group(1)) == "convert" \
                        and len(ins.operands) == 1
                if is_conv and "convert_element_type" not in ins.jax_op and ins.operands:
                    self._legal_src[ins.name] = ins.operands[0]

    def _operand_bytes(self, name: str) -> float:
        """Bytes of an operand, seen through legalization converts."""
        seen = 0
        while name in self._legal_src and seen < 4:
            name = self._legal_src[name]
            seen += 1
        _, b = _parse_shape(self.shape_of.get(name, ""))
        return b

    def hbm_bytes(self) -> float:
        """Post-fusion instruction traffic in non-fused computations.

        In-place conventions (XLA aliases these; counting full buffers
        would overstate scan-heavy models by ~10x):
        * dynamic-update-slice (bare or as a fusion root): traffic = all
          operands EXCEPT the aliased destination buffer, + one write of
          the update-sized slice.
        * dynamic-slice: read + write of the slice only.
        * CPU bf16->f32 legalization converts are skipped (absent on TPU).
        """
        self._build_legalization_maps()
        fused = set()
        for comp in self.comps.values():
            for ins in comp.instrs:
                if ins.op == "fusion":
                    c = re.search(r"calls=(%[\w.\-]+)", ins.rest)
                    if c:
                        fused.add(c.group(1))
        total = 0.0
        skip_ops = {"parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "after-all", "partition-id", "replica-id"}
        for cname, comp in self.comps.items():
            if cname in fused:
                continue  # fusion internals: VMEM traffic
            m = self.mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                if ins.op in skip_ops:
                    continue
                if ins.name in self._legal_src:
                    continue  # CPU legalization convert: no TPU traffic
                _, out_b = _parse_shape(ins.shape_text)
                op = ins.op
                root = ""
                if op == "fusion":
                    c = re.search(r"calls=(%[\w.\-]+)", ins.rest)
                    root = self._root_op(c.group(1)) if c else ""
                if op == "dynamic-update-slice" or root == "dynamic-update-slice":
                    # skip the aliased big destination; count the rest
                    opnd_bytes = [self._operand_bytes(o) for o in ins.operands]
                    if opnd_bytes:
                        dest = max(range(len(opnd_bytes)), key=lambda i: opnd_bytes[i])
                        small = sum(b for i, b in enumerate(opnd_bytes) if i != dest)
                        total += m * 2 * small
                    continue
                if op == "dynamic-slice" or root == "dynamic-slice":
                    total += m * 2 * out_b
                    continue
                if op == "fusion":
                    c = re.search(r"calls=(%[\w.\-]+)", ins.rest)
                    in_b = self._fusion_operand_bytes(ins, c.group(1)) if c else 0
                    total += m * (out_b + in_b)
                    continue
                in_b = sum(self._operand_bytes(o) for o in ins.operands)
                total += m * (out_b + in_b)
        return total

    def collective_bytes(self) -> dict:
        bytes_by: dict[str, float] = {}
        counts: dict[str, float] = {}
        for cname, comp in self.comps.items():
            m = self.mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
                if base in _COLLECTIVES:
                    _, b = _parse_shape(ins.shape_text)
                    bytes_by[base] = bytes_by.get(base, 0.0) + m * b
                    counts[base] = counts.get(base, 0.0) + m
        return {"bytes": bytes_by, "counts": counts}

    def summary(self) -> dict:
        return {"flops": self.flops(), "hbm_bytes": self.hbm_bytes(),
                "collectives": self.collective_bytes()}
