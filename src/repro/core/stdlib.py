"""Codelets used across tests and benchmarks — the paper's running examples.

``add`` (fig 7a's trivial function), ``inc_chain`` (fig 7b's 500-deep chain),
``fix_if`` (Fig 2's lazy conditional), ``fib`` (Fig 3's recursion via Thunks),
``btree_get`` lives in examples/btree_kv.py, ``count_string`` / ``merge_counts``
(fig 8b's map-reduce) live here too since the runtime benchmarks share them.

Combination convention (paper §4.1): ``[limits, procedure, arg...]``.
"""
from __future__ import annotations

import struct

from .api import FixAPI
from .handle import Handle
from .procedures import handle_for, make_limits, register
from .repository import Repository

LIMITS_SMALL = make_limits(ram_bytes=1 << 16)


def combination(repo: Repository, proc_name: str, *args: Handle,
                limits: bytes = LIMITS_SMALL) -> Handle:
    """Build an Application Thunk for ``proc_name(*args)``."""
    tree = repo.put_tree([repo.put_blob(limits), handle_for(repo, proc_name), *args])
    return tree.application()


# --------------------------------------------------------------------- add
@register("add")
def _add(api: FixAPI, comb: Handle) -> Handle:
    _, _, a, b = api.read_tree(comb)
    return api.create_int(api.read_int(a) + api.read_int(b))


# ----------------------------------------------------------------- fig 7b
@register("inc_chain")
def _inc_chain(api: FixAPI, comb: Handle) -> Handle:
    """Increment; if steps remain, tail-call self (one submission, no client
    round-trips — the whole chain is described by the initial thunk)."""
    kids = api.read_tree(comb)
    limits, proc, value, remaining = kids
    v = api.read_int(value)
    r = api.read_int(remaining)
    if r <= 0:
        return api.create_int(v)
    nxt = api.create_tree([limits, proc, api.create_int(v + 1), api.create_int(r - 1)])
    return api.application(nxt)


# ------------------------------------------------------------------ fig 2
@register("fix_if")
def _fix_if(api: FixAPI, comb: Handle) -> Handle:
    """Lazy conditional: the untaken branch's thunk is never evaluated and
    its minimum repository is never fetched."""
    _, _, pred, then_t, else_t = api.read_tree(comb)
    take = api.read_int(pred) != 0
    return then_t if take else else_t


# ------------------------------------------------------------------ fig 3
@register("fib")
def _fib(api: FixAPI, comb: Handle) -> Handle:
    limits, proc, n_h = api.read_tree(comb)
    n = api.read_int(n_h)
    if n < 2:
        return api.create_int(n)
    f1 = api.application(api.create_tree([limits, proc, api.create_int(n - 1)]))
    f2 = api.application(api.create_tree([limits, proc, api.create_int(n - 2)]))
    add_comb = api.create_tree(
        [limits, api.create_blob(b"fix/proc/add"), api.strict(f1), api.strict(f2)]
    )
    return api.application(add_comb)


# ------------------------------------------------------------------ fig 8b
@register("count_string")
def _count_string(api: FixAPI, comb: Handle) -> Handle:
    """Count non-overlapping occurrences of a needle in one corpus shard."""
    _, _, shard, needle = api.read_tree(comb)
    hay = api.read_blob(shard)
    ndl = api.read_blob(needle)
    return api.create_int(hay.count(ndl))


@register("merge_counts")
def _merge_counts(api: FixAPI, comb: Handle) -> Handle:
    _, _, a, b = api.read_tree(comb)
    return api.create_int(api.read_int(a) + api.read_int(b))


# ------------------------------------------------- data-pipeline codelets
@register("slice_blob")
def _slice_blob(api: FixAPI, comb: Handle) -> Handle:
    """Deterministic re-derivation of a shard from (corpus, start, len) —
    the paper's recompute-instead-of-transfer strategy needs shards to be
    products of pure functions."""
    _, _, corpus, start_h, len_h = api.read_tree(comb)
    start, ln = api.read_int(start_h), api.read_int(len_h)
    return api.create_blob(api.read_blob(corpus)[start : start + ln])


@register("identity")
def _identity(api: FixAPI, comb: Handle) -> Handle:
    kids = api.read_tree(comb)
    return kids[2]


@register("checksum_tree")
def _checksum_tree(api: FixAPI, comb: Handle) -> Handle:
    """Fold a Tree of input Blobs into one checksum — a fan-out staging
    workload: every child blob is in the minimum repository, so the
    platform must move all of them before the slot binds (the batched
    transfer scheduler's benchmark case)."""
    _, _, inputs = api.read_tree(comb)
    acc = 0
    for kid in api.read_tree(inputs):
        data = api.read_blob(kid)
        acc = (acc * 31 + len(data) + (data[0] if data else 0)) & 0x7FFFFFFF
    return api.create_int(acc)
