"""``fix.remote(...)`` — the first off-simulation deployment path.

The coordinator runs the same scheduling algorithm as the in-process
:class:`~repro.runtime.cluster.Cluster` (one ``think``/``strictify`` step
per dispatch, children as jobs, memoized encodes folded into the step's
minimum repository), but places steps on **real worker processes** over
local sockets, with every byte of data movement routed through a
content-addressed :class:`~repro.remote.storage.ObjectStore`:

* **invocation plane** — one control socket per worker carrying framed
  ``submit`` / ``ran`` / ``error`` messages (names and memo pairs only,
  never content);
* **liveness plane** — one heartbeat socket per worker, answered by a
  sidecar thread inside the worker, polled by the backend's monitor
  thread: a worker that misses ``heartbeat_miss_budget`` consecutive
  pings is *fenced* (SIGKILL) so the control socket's EOF turns a silent
  hang into an ordinary observable death;
* **storage plane** — one store socket per worker.  The coordinator pushes
  a step's needs client→store before dispatch; the worker pre-stages
  store→worker before computing and pushes everything it creates
  worker→store before replying.  Workers never talk to each other, so all
  inter-worker movement is two observable hops through the platform-owned
  store — the paper's externalized I/O across a real process boundary.

**Failure model.**  Results are re-derivable (deterministic codelets over
content-addressed inputs), so failures cost retries, not answers:

* a dead worker is *replaced* (up to ``max_respawns``) and its in-flight
  steps are resubmitted with capped exponential backoff — safe
  exactly-once-by-content-key, because results land in the store and a
  duplicate ``ran`` is a dup-put no-op;
* a rotten store payload (``verify_reads``) is quarantined and recovered:
  re-put from the client repository, pulled back from a live worker that
  holds it, or recomputed through the recorded lineage encode;
* exhausted budgets surface as *typed* errors — :class:`WorkerCrashed`
  only when respawn+resubmit ran out, :class:`TransferFailed` /
  :class:`~repro.core.repository.CorruptData` /
  :class:`~repro.fix.future.DeadlineExceeded` /
  :class:`~repro.fix.future.CancelledError` otherwise;
* ``close()`` drains recovery in progress before tearing down.

Residency ground truth is the store's put *notifications* plus the
workers' per-reply fetched/created reports — not in-process repository
listeners — feeding the same :class:`~repro.runtime.transfers.LocationIndex`
the simulated cluster uses.  With ``trace=`` the run emits the PR-4 JSONL
schema plus the PR-6 fault vocabulary (``fault``, ``worker_respawn``,
``job_resubmit``, ``corruption_detected``, ``quarantine``,
``transfer_retry``) and passes fault-mode ``verify_invariants`` — the same
seeded-schedule invariant the simulator checks, now on real processes.

Content addressing is what makes this backend small: a handle is its own
checksum, so every hop verifies its delivery, and content keys are
process-independent, so strict-memo and dedup work unchanged across the
boundary.
"""
from __future__ import annotations

import builtins
import itertools
import multiprocessing
import os
import queue
import socket
import tempfile
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Optional

from ..core.handle import (
    APPLICATION,
    BLOB,
    IDENTIFICATION,
    SELECTION,
    STRICT,
    TREE,
    Handle,
)
from ..core.repository import (
    CorruptData,
    MissingData,
    Repository,
    walk_object_closure,
)
from ..fix.backend import Backend
from ..fix.future import CancelledError, DeadlineExceeded, Future
from ..runtime.faults import TransferFailed
from ..runtime.telemetry import CodeletProfile, MetricsRegistry, SpanEmitter
from ..runtime.transfers import LocationIndex
from .protocol import ProtocolError, recv_msg, retriable, send_msg
from .storage import (
    FileStore,
    MemoryStore,
    ObjectStore,
    StoreError,
    StoreServer,
    decode_tree_payload,
    encode_tree_payload,
    payload_nbytes,
)
from .worker import worker_main

RESOLVE, WAIT_CHILDREN, RUNNING, STRICT_WAIT, DONE, RETRY_WAIT = range(6)


class WorkerCrashed(RuntimeError):
    """Worker death exhausted the respawn+resubmit budget (typed, not a
    hang) — every other failure surfaces as a more specific error."""


class RemoteError(RuntimeError):
    """A worker-side failure that has no builtin exception to rebuild."""

    def __init__(self, etype: str, emsg: str):
        super().__init__(f"{etype}: {emsg}")
        self.etype = etype
        self.emsg = emsg


class _MonotonicClock:
    """now() for TraceRecorder.bind: wall-monotonic seconds since start."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0


@dataclass
class _RJob:
    id: int
    encode: Handle
    thunk: Handle
    strict: bool
    tenant: Optional[str] = None   # accounting tag, inherited by children
    phase: int = RESOLVE
    epoch: int = 0
    node: Optional[str] = None
    kind: str = "think"            # op of the in-flight dispatch
    retries: int = 0               # recovery attempts consumed
    dispatched_at: float = 0.0     # monotonic instant of the last dispatch
    futures: list = field(default_factory=list)
    parents: list = field(default_factory=list)
    children: set = field(default_factory=set)
    pending_children: set = field(default_factory=set)
    whnf: Optional[Handle] = None
    result: Optional[Handle] = None
    strict_children: list = field(default_factory=list)
    strict_stage: list = field(default_factory=list)
    span: Optional[int] = None     # causal span id (spans=True only)
    _metric_t0: float = 0.0        # monotonic submit instant


class _Worker:
    __slots__ = ("wid", "proc", "ctl", "hb", "send_lock", "hb_lock", "reader",
                 "alive", "outstanding", "log_path", "gen", "hb_misses",
                 "hb_lost", "jobs_reported")

    def __init__(self, wid: str, proc, ctl, hb, log_path: str, gen: int):
        self.wid = wid
        self.proc = proc
        self.ctl = ctl
        self.hb = hb
        self.send_lock = threading.Lock()
        self.hb_lock = threading.Lock()
        self.reader: Optional[threading.Thread] = None
        self.alive = True
        self.outstanding: set[int] = set()
        self.log_path = log_path
        self.gen = gen            # respawn generation under this wid
        self.hb_misses = 0        # consecutive missed heartbeats
        self.hb_lost = False      # fenced by the monitor (budget exhausted)
        self.jobs_reported = 0    # steps-completed count from the last pong


class RemoteBackend(Backend):
    """Real worker processes + pluggable content-addressed object storage.

    ``store`` is ``"memory"`` (server-backed, default), ``"file"`` (a
    :class:`FileStore` under ``store_dir`` — persistent, so two runs of the
    same program share content), or any :class:`ObjectStore` instance.
    Worker stdout/stderr land in per-worker files under ``log_dir``
    (default: ``$FIX_REMOTE_LOGDIR`` or a fresh temp dir) — these are what
    CI uploads when the smoke job fails.

    Recovery knobs (defaults tuned for tests; production would scale them
    with the deployment):

    * ``heartbeat_s`` / ``heartbeat_miss_budget`` / ``heartbeat_timeout_s``
      — monitor cadence, consecutive-miss budget before a worker is fenced,
      and per-ping wait (defaults to ``heartbeat_s``);
    * ``max_respawns`` — total replacement workers across the backend's
      lifetime (default ``4 * n_workers``); ``0`` restores fail-fast;
    * ``job_retry_limit`` / ``retry_backoff_s`` / ``retry_backoff_cap_s``
      — per-job resubmit budget and capped exponential backoff;
    * ``store_retry_limit`` — attempts per client→store put before a typed
      :class:`TransferFailed`;
    * ``dispatch_timeout_s`` — optional watchdog: a step RUNNING longer
      than this is resubmitted (dup results are dup-put no-ops), turning a
      dropped control frame into a retry instead of a hang;
    * ``drain_timeout_s`` — how long ``close()`` waits for in-flight work
      (including recovery) to finish before failing the remainder;
    * ``chaos`` — a :class:`~repro.remote.chaos.RemoteChaos` schedule; arms
      ``store.verify_reads`` and routes control-plane sends through the
      injection shim.
    """

    def __init__(self, n_workers: int = 2, *, store="memory",
                 store_dir: Optional[str] = None, trace=None,
                 log_dir: Optional[str] = None, chaos=None,
                 heartbeat_s: float = 1.0, heartbeat_miss_budget: int = 5,
                 heartbeat_timeout_s: Optional[float] = None,
                 max_respawns: Optional[int] = None,
                 job_retry_limit: int = 3, retry_backoff_s: float = 0.05,
                 retry_backoff_cap_s: float = 2.0, store_retry_limit: int = 3,
                 dispatch_timeout_s: Optional[float] = None,
                 drain_timeout_s: float = 10.0,
                 recover_wait_s: float = 5.0,
                 metrics: bool = True, spans: bool = False):
        if n_workers < 1:
            raise ValueError("need at least one worker process")
        self._repo = Repository("client")
        self.trace = trace
        if trace is not None:
            trace.bind(_MonotonicClock())
        self.metrics = MetricsRegistry() if metrics else None
        self.spans = (SpanEmitter(trace)
                      if spans and trace is not None else None)
        self.profile = CodeletProfile()  # folded from worker ran replies
        self._locs = LocationIndex()
        self._store_mutex = threading.Lock()
        self.store = self._resolve_store(store, store_dir)
        self.store.add_put_listener(self._on_store_put)
        self._repo.add_put_listener(self._on_client_put)
        self.log_dir = (log_dir or os.environ.get("FIX_REMOTE_LOGDIR")
                        or tempfile.mkdtemp(prefix="fix-remote-logs-"))
        os.makedirs(self.log_dir, exist_ok=True)

        # recovery configuration
        self.heartbeat_s = heartbeat_s
        self.heartbeat_miss_budget = heartbeat_miss_budget
        self.heartbeat_timeout_s = (heartbeat_timeout_s
                                    if heartbeat_timeout_s is not None
                                    else heartbeat_s)
        self.max_respawns = (max_respawns if max_respawns is not None
                             else 4 * n_workers)
        self.job_retry_limit = job_retry_limit
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_cap_s = retry_backoff_cap_s
        self.store_retry_limit = store_retry_limit
        self.dispatch_timeout_s = dispatch_timeout_s
        self.drain_timeout_s = drain_timeout_s
        self.recover_wait_s = recover_wait_s

        # recovery counters (stats() / benchmarks)
        self.respawns = 0
        self.resubmits = 0
        self.quarantines = 0
        self.recomputes = 0
        self.hb_fences = 0

        # scheduler state (coordinator thread only, except _memo reads)
        self._jobs: dict[int, _RJob] = {}
        self._by_encode: dict[bytes, int] = {}
        self._memo: dict[bytes, Handle] = {}
        self._reach: dict[bytes, tuple] = {}
        self._lineage: dict[bytes, bytes] = {}    # content key -> creator encode
        self._quarantined: set[bytes] = set()     # rot detected, not yet re-put
        self._recomputing: set[bytes] = set()     # recovery in flight
        self._quar_lock = threading.Lock()
        self._ids = itertools.count()
        self._nonces = itertools.count()
        self._events: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._timers: set[threading.Timer] = set()
        self._graveyard: list[_Worker] = []
        self._respawns_used = 0
        self.transfers = 0
        self.bytes_moved = 0
        self._closed = False
        self._closing = False

        self._chaos = chaos
        if chaos is not None:
            self.store.verify_reads = True
            chaos.bind(self)

        self._store_server = StoreServer(self.store, mutex=self._store_mutex)
        self._store_server.on_corrupt = (
            lambda h, peer: self._quarantine(h, via="read", dst=peer))
        self._workers: dict[str, _Worker] = {}
        self._ctx = multiprocessing.get_context("fork")
        for i in range(n_workers):
            self._spawn_worker(f"w{i}")
        self._coord = threading.Thread(target=self._loop, daemon=True,
                                       name="fix-remote-coord")
        self._coord.start()
        self._stop_monitor = threading.Event()
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         daemon=True,
                                         name="fix-remote-monitor")
        self._monitor.start()

    # ----------------------------------------------------------- lifecycle
    @staticmethod
    def _resolve_store(store, store_dir: Optional[str]) -> ObjectStore:
        if isinstance(store, ObjectStore):
            return store
        if store == "memory":
            return MemoryStore()
        if store == "file":
            return FileStore(store_dir or tempfile.mkdtemp(prefix="fix-store-"))
        raise ValueError(f"store must be 'memory', 'file' or an ObjectStore, "
                         f"not {store!r}")

    def _spawn_worker(self, wid: str, gen: int = 0) -> None:
        ctl_parent, ctl_child = socket.socketpair()
        store_parent, store_child = socket.socketpair()
        hb_parent, hb_child = socket.socketpair()
        log_path = os.path.join(self.log_dir, f"{wid}.log")
        proc = self._ctx.Process(
            target=worker_main,
            args=(ctl_child, store_child, wid, log_path, hb_child),
            daemon=True, name=f"fix-remote-{wid}-g{gen}")
        proc.start()
        # Close the child ends NOW, before the next worker forks: a later
        # child inheriting these fds would keep this worker's sockets open
        # past its death and break EOF-based crash detection.
        ctl_child.close()
        store_child.close()
        hb_child.close()
        old = self._workers.get(wid)
        if old is not None:
            self._graveyard.append(old)
        w = _Worker(wid, proc, ctl_parent, hb_parent, log_path, gen)
        self._workers[wid] = w
        self._store_server.serve(store_parent, wid)
        w.reader = threading.Thread(target=self._read_loop, args=(w,),
                                    daemon=True,
                                    name=f"fix-remote-rx-{wid}-g{gen}")
        w.reader.start()

    def _read_loop(self, w: _Worker) -> None:
        fatal: Optional[BaseException] = None
        try:
            while True:
                msg = recv_msg(w.ctl)
                if msg is None:
                    break
                if self._chaos is not None:
                    self._chaos.on_ctl_recv(w)
                if msg.get("op") == "pong":
                    continue  # legacy between-steps pong: liveness moved to hb
                self._events.put(("msg", w.wid, msg, w.gen))
        except ProtocolError as e:
            # FrameTruncated is a channel casualty (retriable); BadTag /
            # FrameTooLarge mean a poisoned conversation (fatal for the
            # steps that died with it — resending could only repeat it).
            fatal = None if retriable(e) else e
        except OSError:
            pass
        self._events.put(("worker_died", w.wid, w.gen, fatal))

    def _ctl_send(self, w: _Worker, msg: dict) -> None:
        """Control-plane send, routed through the chaos shim when armed."""
        if self._chaos is not None:
            self._chaos.ctl_send(w, msg)
        else:
            send_msg(w.ctl, msg, lock=w.send_lock)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True                 # no new submissions
        self._drain(self.drain_timeout_s)   # let recovery in progress finish
        self._closing = True
        self._stop_monitor.set()
        self._monitor.join(timeout=5)
        for t in list(self._timers):
            t.cancel()
        # anything still pending after the drain fails typed, not hanging
        self._events.put(("teardown",))
        for w in self._workers.values():
            if w.alive:
                try:
                    send_msg(w.ctl, {"op": "shutdown"}, lock=w.send_lock)
                except OSError:
                    pass
        everyone = list(self._workers.values()) + self._graveyard
        for w in everyone:
            w.proc.join(timeout=5)
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2)
            if w.proc.is_alive():  # pragma: no cover - last resort
                w.proc.kill()
                w.proc.join(timeout=2)
        self._events.put(None)
        self._coord.join(timeout=5)
        for w in everyone:
            for sock in (w.ctl, w.hb):
                try:
                    sock.close()
                except OSError:
                    pass
            if w.reader is not None:
                w.reader.join(timeout=5)
        for t in list(self._timers):
            t.join(timeout=1)
        self._store_server.close()
        self.store.close()
        if self._chaos is not None:
            self._chaos.close()

    def _drain(self, timeout: float) -> None:
        """Wait (bounded) for the event queue and every job to settle —
        recovery that is mid-flight at close() is never truncated."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            busy = (not self._events.empty()
                    or any(j.phase != DONE
                           for j in list(self._jobs.values())))
            if not busy:
                return
            time.sleep(0.02)

    # --------------------------------------------------------------- public
    @property
    def repo(self) -> Repository:
        return self._repo

    def submit(self, program, *, deadline_s: Optional[float] = None,
               tenant: Optional[str] = None) -> Future:
        if self._closed:
            raise RuntimeError("backend is closed")
        encode, out_type = self._compile(program)
        fut = Future()
        fut.out_type = out_type
        fut._canceller = lambda f: self._request_cancel(f, "cancel")
        if deadline_s is not None:
            timer = threading.Timer(
                deadline_s, lambda: self._request_cancel(fut, "deadline"))
            timer.daemon = True
            timer.start()
            fut.add_done_callback(lambda _f: timer.cancel())
        self._events.put(("submit", encode, fut, None, False, tenant))
        return fut

    def _request_cancel(self, fut: Future, reason: str) -> None:
        """Route a cancel/deadline through the coordinator so the job (and
        its orphaned children) are pruned, not just the future failed."""
        if fut.done():
            return
        if self._coord.is_alive() and not self._closing:
            self._events.put(("cancel", fut, reason))
        else:
            fut.set_exception(self._cancel_exc(reason))

    @staticmethod
    def _cancel_exc(reason: str) -> BaseException:
        if reason == "deadline":
            return DeadlineExceeded("job deadline exceeded")
        return CancelledError("future cancelled")

    def ping(self, timeout: float = 5.0) -> dict[str, bool]:
        """Heartbeat every live worker; {worker id: answered in time}.

        Pings travel the dedicated heartbeat socket (answered by a sidecar
        thread in the worker), so a pong bounds process liveness even while
        a codelet runs.  Stale pongs left in the buffer by a timed-out
        earlier ping are drained by nonce, never miscounted."""
        out: dict[str, bool] = {}
        for wid, w in self._workers.items():
            out[wid] = w.alive and self._hb_ping_worker(w, timeout)
        return out

    def _hb_ping_worker(self, w: _Worker, timeout: float) -> bool:
        nonce = next(self._nonces)
        deadline = time.monotonic() + timeout
        try:
            with w.hb_lock:
                send_msg(w.hb, {"op": "heartbeat", "nonce": nonce})
                while True:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                    w.hb.settimeout(remaining)
                    try:
                        msg = recv_msg(w.hb)
                    finally:
                        try:
                            w.hb.settimeout(None)
                        except OSError:
                            return False
                    if msg is None:
                        return False  # EOF: the worker is gone
                    if msg.get("op") != "pong" or msg.get("nonce") != nonce:
                        continue      # stale pong from a timed-out ping
                    if (self._chaos is not None
                            and not self._chaos.take_pong(w.wid)):
                        return False  # injected heartbeat stall
                    w.jobs_reported = msg.get("jobs", w.jobs_reported)
                    return True
        except (OSError, ProtocolError):
            return False

    def _count_job(self, job: _RJob, outcome: str) -> None:
        m = self.metrics
        if m is not None:
            tl = {} if job.tenant is None else {"tenant": job.tenant}
            m.counter("jobs_" + outcome, **tl).inc()

    def codelet_profile(self) -> CodeletProfile:
        return self.profile

    def stats(self) -> dict:
        return {
            "backend": "remote",
            "metrics": (self.metrics.snapshot()
                        if self.metrics is not None else {}),
            "codelets": self.profile.to_dict(),
            "store": self.store.stats(),
            "workers": {wid: {"alive": w.alive, "pid": w.proc.pid,
                              "gen": w.gen, "jobs": w.jobs_reported,
                              "log": w.log_path}
                        for wid, w in self._workers.items()},
            "transfers": self.transfers,
            "bytes_moved": self.bytes_moved,
            "recovery": {"respawns": self.respawns,
                         "resubmits": self.resubmits,
                         "quarantines": self.quarantines,
                         "recomputes": self.recomputes,
                         "hb_fences": self.hb_fences},
        }

    # ------------------------------------------------------ event loop
    def _loop(self) -> None:
        while True:
            ev = self._events.get()
            if ev is None:
                return
            try:
                kind = ev[0]
                if kind == "submit":
                    self._on_submit(*ev[1:])
                elif kind == "msg":
                    self._on_msg(ev[1], ev[2], ev[3])
                elif kind == "worker_died":
                    self._on_worker_died(ev[1], ev[2], ev[3])
                elif kind == "retry_job":
                    self._on_retry(ev[1], ev[2])
                elif kind == "job_timeout":
                    self._on_job_timeout(ev[1], ev[2])
                elif kind == "cancel":
                    self._on_cancel(ev[1], ev[2])
                elif kind == "teardown":
                    self._on_teardown()
            except BaseException:  # pragma: no cover - coordinator must live
                traceback.print_exc()

    # ------------------------------------------------------ monitor thread
    def _monitor_loop(self) -> None:
        """Active failure detection: heartbeat every worker each period;
        a worker over the miss budget is fenced (SIGKILL) so its control
        socket EOFs and the ordinary death path takes over.  Optionally
        also watches for dispatches that outlive ``dispatch_timeout_s``
        (a dropped frame leaves a step RUNNING forever otherwise)."""
        while not self._stop_monitor.wait(self.heartbeat_s):
            if self._closing:
                return
            for w in list(self._workers.values()):
                if not w.alive or self._closing:
                    continue
                if self._hb_ping_worker(w, self.heartbeat_timeout_s):
                    w.hb_misses = 0
                    continue
                w.hb_misses += 1
                if w.hb_misses < self.heartbeat_miss_budget or w.hb_lost:
                    continue
                w.hb_lost = True
                self.hb_fences += 1
                try:
                    w.proc.kill()  # fence: make the silence a real death
                except Exception:  # noqa: BLE001 - already gone
                    pass
            if self.dispatch_timeout_s is None:
                continue
            now = time.monotonic()
            for job in list(self._jobs.values()):
                if (job.phase == RUNNING and job.dispatched_at
                        and now - job.dispatched_at > self.dispatch_timeout_s):
                    self._events.put(("job_timeout", job.id, job.epoch))

    # ------------------------------------------------------------ submit
    def _on_submit(self, encode: Handle, fut: Optional[Future],
                   parent: Optional[int], ignore_memo: bool,
                   tenant: Optional[str] = None) -> None:
        tr = self.trace
        if tenant is None and parent is not None:
            # child work bills to whoever submitted the root program
            pj = self._jobs.get(parent)
            if pj is not None:
                tenant = pj.tenant
        if not ignore_memo:
            memo = self._memo.get(encode.raw)
            if memo is not None:
                # the content universe (client repo ∪ store) never evicts,
                # so a memoized result is always fetchable
                if tr is not None:
                    extra = {} if tenant is None else {"tenant": tenant}
                    tr.emit("job_memo_hit", encode=encode.raw.hex(), **extra)
                if self.metrics is not None:
                    tl = {} if tenant is None else {"tenant": tenant}
                    self.metrics.counter("jobs_memo_hit", **tl).inc()
                if fut is not None:
                    fut.set(memo)
                if parent is not None:
                    self._child_resolved(parent, encode)
                return
            existing = self._by_encode.get(encode.raw)
            if existing is not None and self._jobs[existing].phase != DONE:
                job = self._jobs[existing]
                if fut is not None:
                    fut._jid = existing
                    job.futures.append(fut)
                if parent is not None:
                    job.parents.append(parent)
                    pj = self._jobs.get(parent)
                    if pj is not None:
                        pj.children.add(existing)
                return
        jid = next(self._ids)
        job = _RJob(jid, encode, encode.unwrap_encode(),
                    encode.interp == STRICT, tenant=tenant)
        if fut is not None:
            fut._jid = jid
            job.futures.append(fut)
        if parent is not None:
            job.parents.append(parent)
            pj = self._jobs.get(parent)
            if pj is not None:
                pj.children.add(jid)
        self._jobs[jid] = job
        if not ignore_memo:
            self._by_encode[encode.raw] = jid
        if tr is not None:
            # tenant only when tagged: untagged runs keep byte-identical
            # traces (the golden-fixture replay diff)
            extra = {} if tenant is None else {"tenant": tenant}
            tr.emit("job_submit", job=jid, encode=encode.raw.hex(),
                    strict=job.strict, parent=parent, recompute=ignore_memo,
                    **extra)
        job._metric_t0 = time.monotonic()
        self._count_job(job, "submitted")
        if self.spans is not None:
            pj = self._jobs.get(parent) if parent is not None else None
            job.span = self.spans.begin(
                f"job:{jid}", parent=(pj.span if pj is not None else None),
                job=jid)
        self._advance_guarded(job)

    def _advance_guarded(self, job: _RJob) -> None:
        try:
            self._advance(job)
        except (MissingData, CorruptData) as e:
            self._handle_content_loss(job, e)
        except BaseException as e:  # noqa: BLE001 — failures stay job-scoped
            self._fail_job(job, e)

    def _strictify_guarded(self, job: _RJob) -> None:
        try:
            self._begin_strictify(job)
        except (MissingData, CorruptData) as e:
            self._handle_content_loss(job, e)
        except BaseException as e:  # noqa: BLE001
            self._fail_job(job, e)

    # ------------------------------------------------------------- advance
    def _advance(self, job: _RJob) -> None:
        thunk = job.thunk
        if thunk.is_data():  # encode over an already-data handle
            job.whnf = thunk
            if job.strict:
                self._begin_strictify(job)
            else:
                self._finalize(job, thunk.as_ref())
            return
        needs, children, memo_pairs = self._step_needs(thunk)
        unresolved = [c for c in children if self._memo.get(c.raw) is None]
        if unresolved:
            job.phase = WAIT_CHILDREN
            job.pending_children = {c.raw for c in unresolved}
            for c in unresolved:
                self._events.put(("submit", c, None, job.id, False, None))
            return
        for enc in children:
            res = self._memo[enc.raw]
            memo_pairs.append((enc, res))
            needs.extend(self._deep_object_handles(res))
        self._dispatch(job, "think", job.thunk, needs, memo_pairs)

    def _child_resolved(self, parent_id: int, child_encode: Handle) -> None:
        job = self._jobs.get(parent_id)
        if job is None or job.phase == DONE:
            return
        job.pending_children.discard(child_encode.raw)
        if job.pending_children or job.phase not in (WAIT_CHILDREN,
                                                     STRICT_WAIT):
            return
        if job.phase == WAIT_CHILDREN:
            job.phase = RESOLVE
            self._advance_guarded(job)
        else:  # children of the WHNF walk resolved: re-walk, now memoized
            self._strictify_guarded(job)

    # --------------------------------------------------------- strictify
    def _begin_strictify(self, job: _RJob) -> None:
        """Deep-evaluate the WHNF result (mirror of the cluster's walk):
        nested thunks/encodes become child jobs, Ref'd data is staged."""
        whnf = job.whnf
        children: list[Handle] = []
        stage: list[Handle] = []
        stack = [whnf]
        seen: set[bytes] = set()
        while stack:
            h = stack.pop()
            if h.raw in seen or h.is_literal:
                continue
            seen.add(h.raw)
            if h.is_encode():
                res = self._memo.get(h.raw)
                if res is None:
                    children.append(h)
                else:
                    stack.append(res)
                continue
            if h.is_thunk():
                children.append(h.strict())
                continue
            stage.append(h)
            if h.content_type == TREE:
                kids = self._tree_children(h)
                if kids is not None:
                    stack.extend(kids)
        job.strict_stage = stage
        job.strict_children = children
        unresolved = [c for c in children if self._memo.get(c.raw) is None]
        if unresolved:
            job.phase = STRICT_WAIT
            job.pending_children = {c.raw for c in unresolved}
            for c in unresolved:
                self._events.put(("submit", c, None, job.id, False, None))
            return
        self._advance_strict(job)

    def _advance_strict(self, job: _RJob) -> None:
        if job.whnf.content_type == BLOB and job.whnf.is_data():
            # a blob is its own strict form: no worker round-trip
            self._finalize(job, job.whnf.as_object())
            return
        needs = list(job.strict_stage)
        memo_pairs: list[tuple] = []
        for c in job.strict_children:
            res = self._memo[c.raw]
            memo_pairs.append((c, res))
            needs.extend(self._deep_object_handles(res))
        self._dispatch(job, "strictify", job.whnf, needs, memo_pairs)

    # ---------------------------------------------------------- stepneeds
    def _step_needs(self, thunk: Handle):
        """(stage handles, child encodes, memo pairs) for one reduction —
        the cluster's algorithm verbatim, over client repo ∪ store."""
        interp = thunk.interp
        if interp == IDENTIFICATION:
            return [], [], []
        if interp == SELECTION:
            pair_h = thunk.unwrap_thunk()
            needs = [pair_h]
            pair = self._tree_children(pair_h)
            if pair is None:
                raise MissingData(pair_h)
            target, idx = pair
            if not idx.is_literal:
                needs.append(idx)
            children: list[Handle] = []
            memo_pairs: list[tuple] = []
            if target.is_encode():
                res = self._memo.get(target.raw)
                if res is None:
                    return needs, [target], []
                memo_pairs.append((target, res))
                target = res
            if target.is_thunk():
                res = self._memo.get(target.shallow().raw)
                if res is None:
                    return needs, [target.shallow()], []
                memo_pairs.append((target.shallow(), res))
                target = res
            if not target.is_literal:
                needs.append(target)  # the node itself; children stay put
            return needs, children, memo_pairs
        if interp == APPLICATION:
            defn = thunk.unwrap_thunk()
            needs, children, memo_pairs = [], [], []
            stack = [defn]
            seen: set[bytes] = set()
            while stack:
                h = stack.pop()
                if h.raw in seen or h.is_literal:
                    continue
                seen.add(h.raw)
                if h.is_encode():
                    res = self._memo.get(h.raw)
                    if res is None:
                        children.append(h)
                    else:
                        memo_pairs.append((h, res))
                        stack.append(res)
                    continue
                if h.is_thunk() or h.is_ref():
                    continue  # lazy / metadata-only
                needs.append(h)
                if h.content_type == TREE:
                    kids = self._tree_children(h)
                    if kids is None:
                        raise MissingData(h)
                    stack.extend(kids)
            return needs, children, memo_pairs
        raise ValueError(f"not a thunk: {thunk!r}")

    def _tree_children(self, h: Handle) -> Optional[tuple]:
        try:
            return self._repo.get_tree(h)
        except MissingData:
            payload = self._store_read(h, dst="client")
            if payload is None:
                return None
            return decode_tree_payload(payload)

    def _deep_object_handles(self, handle: Handle) -> list[Handle]:
        return list(walk_object_closure(
            handle, lambda h: self._memo.get(h.raw),
            self._tree_children, self._reach))

    # ----------------------------------------------------------- dispatch
    def _dispatch(self, job: _RJob, kind: str, target: Handle,
                  needs: list, memo_pairs: list) -> None:
        uniq: list[Handle] = []
        seen: set[bytes] = set()
        for h in needs:
            if h.is_literal or h.raw in seen:
                continue
            seen.add(h.raw)
            uniq.append(h)
        wid = self._pick_worker(uniq)
        if wid is None:
            self._fail_job(job, WorkerCrashed("no live worker processes"))
            return
        # Storage plane first: every need must be servable from the store
        # before the step is dispatched (client→store is an accounted,
        # traced transfer like any other).  The mutex makes the residency
        # check and the trace choreography atomic against worker pushes.
        with self._store_mutex:
            for h in uniq:
                self._ensure_in_store_locked(job.id, h)
        missing = [h for h in uniq
                   if wid not in self._locs.nodes_for(h.content_key())]
        tr = self.trace
        job.node = wid
        job.kind = kind
        if tr is not None:
            tr.emit("job_place", job=job.id, node=wid, epoch=job.epoch,
                    n_missing=len(missing),
                    missing_nbytes=sum(payload_nbytes(h) for h in missing))
        job.phase = RUNNING
        job.dispatched_at = time.monotonic()
        if tr is not None:
            tr.emit("job_start", job=job.id, node=wid, epoch=job.epoch,
                    op="run" if kind == "think" else "strictify", internal=0)
        w = self._workers[wid]
        w.outstanding.add(job.id)
        try:
            self._ctl_send(w, {
                "op": "submit", "job": job.id, "epoch": job.epoch,
                "kind": kind, "target": target.raw,
                "memos": [[e.raw, r.raw] for e, r in memo_pairs],
                "needs": [h.raw for h in uniq],
            })
        except OSError:
            # the reader's worker_died event will resubmit the job; doing
            # it here too would race the reader thread
            pass

    def _pick_worker(self, uniq: list) -> Optional[str]:
        """Place where the fewest bytes of the step's needs are missing
        (the location index knows worker residency), breaking ties toward
        the shorter outstanding queue, then by worker order."""
        live = [w for w in self._workers.values() if w.alive]
        if not live:
            return None
        best, best_cost = None, None
        for w in live:
            missing = sum(payload_nbytes(h) for h in uniq
                          if w.wid not in self._locs.nodes_for(h.content_key()))
            cost = (missing, len(w.outstanding))
            if best_cost is None or cost < best_cost:
                best, best_cost = w, cost
        return best.wid

    def _ensure_in_store_locked(self, jid: Optional[int], h: Handle) -> None:
        """Client→store movement for one handle (store mutex held), with
        capped-backoff retry and a typed :class:`TransferFailed` give-up."""
        if self.store.contains(h):
            return
        if h.content_type == BLOB:
            payload = self._repo.get_blob(h)
        else:
            payload = encode_tree_payload(self._repo.get_tree(h))
        nbytes = payload_nbytes(h)
        tr = self.trace
        key_hex = h.content_key().hex()
        if tr is not None:
            tr.emit("stage_request", job=jid, dst="store", key=key_hex,
                    nbytes=nbytes, action="enqueue", src="client")
        attempts = 0
        while True:
            attempts += 1
            try:
                self.store.put(h, payload, src="client")  # put(node="store")
                break
            except (OSError, StoreError) as e:
                if attempts >= self.store_retry_limit:
                    if tr is not None:
                        tr.emit("transfer_gaveup", dst="store", key=key_hex,
                                jobs=[], attempts=attempts)
                    raise TransferFailed(key_hex, "store", attempts,
                                         str(e)) from e
                if tr is not None:
                    tr.emit("transfer_retry", dst="store", key=key_hex,
                            attempt=attempts, reason=str(e))
                time.sleep(min(self.retry_backoff_s * 2 ** (attempts - 1),
                               self.retry_backoff_cap_s))
        if tr is not None:
            tr.emit("transfer_deliver", src="client", dst="store", n=1,
                    nbytes=nbytes, keys=[key_hex], ok=True, via="store")
        self.transfers += 1
        self.bytes_moved += nbytes
        if self.metrics is not None:
            self.metrics.counter("transfers_total").inc()
            self.metrics.counter("bytes_moved_total").inc(nbytes)

    # ------------------------------------------------------------- replies
    def _on_msg(self, wid: str, msg: dict, gen: int) -> None:
        w = self._workers.get(wid)
        if w is None or w.gen != gen:
            return  # a message from a replaced generation: nothing current
        jid = msg.get("job")
        w.outstanding.discard(jid)
        # Residency/trace accounting first — the movement happened whether
        # or not the job is still current; same for codelet wall time
        # (the profile deltas are high-water-marked worker-side, so folding
        # a stale reply cannot double-count).
        self._record_movement(wid, msg, jid)
        prof = msg.get("profile")
        if prof:
            self.profile.update(prof)
        job = self._jobs.get(jid)
        if job is None or job.phase != RUNNING or msg.get("epoch") != job.epoch:
            return  # stale reply (job failed over or already finished)
        if msg["op"] == "error":
            exc = self._rebuild_exc(msg)
            if msg.get("etype") == "MissingData":
                # the store lost (or quarantined) content between staging
                # and the worker's fetch: recovery may repopulate it, so
                # this is a retry, not a verdict
                self._retry_or_fail(job, "content missing at worker", exc)
            else:
                self._fail_job(job, exc)
            return
        result = Handle(bytes(msg["result"]))
        if job.kind == "strictify":
            self._finalize(job, result)
            return
        if result.is_thunk():  # tail call: fresh placement (paper §4.2.2)
            job.thunk = result
            job.epoch += 1
            job.phase = RESOLVE
            self._advance_guarded(job)
            return
        job.whnf = result
        job.epoch += 1
        if not job.strict:
            self._finalize(job, result.as_ref() if result.is_data() else result)
            return
        self._strictify_guarded(job)

    def _record_movement(self, wid: str, msg: dict, jid) -> None:
        """Fold a reply's fetched/created reports into the trace and the
        location index — the worker's ground truth of what actually moved
        store→worker and what fresh content it produced.  Created entries
        also record lineage (content key → creator encode) so quarantined
        content can be recomputed through the memo machinery."""
        tr = self.trace
        resident = self._locs
        job = self._jobs.get(jid)
        enc_raw = job.encode.raw if job is not None else None
        for raw, nbytes in msg.get("fetched", ()):
            h = Handle(bytes(raw))
            key = h.content_key()
            if tr is not None:
                key_hex = key.hex()
                tr.emit("stage_request", job=jid, dst=wid, key=key_hex,
                        nbytes=nbytes, action="enqueue", src="store")
                tr.emit("transfer_deliver", src="store", dst=wid, n=1,
                        nbytes=nbytes, keys=[key_hex], ok=True, via="store")
                tr.emit("put", node=wid, key=key_hex, nbytes=nbytes)
            resident.add(key, wid)
            self.transfers += 1
            self.bytes_moved += nbytes
            if self.metrics is not None:
                self.metrics.counter("transfers_total").inc()
                self.metrics.counter("bytes_moved_total").inc(nbytes)
        for raw, nbytes in msg.get("created", ()):
            h = Handle(bytes(raw))
            key = h.content_key()
            if enc_raw is not None:
                self._lineage.setdefault(key, enc_raw)
            if wid in resident.nodes_for(key):
                continue  # already accounted (identical content re-derived)
            if tr is not None:
                tr.emit("put", node=wid, key=key.hex(), nbytes=nbytes)
            resident.add(key, wid)

    @staticmethod
    def _rebuild_exc(msg: dict) -> BaseException:
        etype, emsg = msg.get("etype", "Exception"), msg.get("emsg", "")
        cls = getattr(builtins, etype, None)
        if not (isinstance(cls, type) and issubclass(cls, BaseException)):
            # the repro exception types a shim can raise — rebuilding them
            # keeps error behavior identical to fix.local()
            from ..core.evaluator import FixError
            from ..fix.marshal import MarshalError
            cls = {"FixError": FixError,
                   "MarshalError": MarshalError}.get(etype)
        if cls is not None:
            try:
                return cls(emsg)
            except Exception:  # noqa: BLE001 - exotic signature
                pass
        if etype == "MissingData":
            return RemoteError(etype, emsg or "content unavailable at worker")
        return RemoteError(etype, emsg)

    # ------------------------------------------------------------ recovery
    def _on_worker_died(self, wid: str, gen: int, fatal) -> None:
        w = self._workers.get(wid)
        if w is None or w.gen != gen or not w.alive:
            return
        w.alive = False
        self._locs.drop_node(wid)
        victims = sorted(w.outstanding)
        w.outstanding.clear()
        if self._closing:
            return
        reason = ("heartbeat_lost" if w.hb_lost
                  else type(fatal).__name__ if fatal is not None else "crash")
        tr = self.trace
        if tr is not None:
            tr.emit("fault", fault="crash", node=wid, applied=True,
                    reason=reason)
        respawned = False
        if self._respawns_used < self.max_respawns:
            self._respawns_used += 1
            self.respawns += 1
            try:
                self._spawn_worker(wid, gen=gen + 1)
                respawned = True
                nw = self._workers[wid]
                if tr is not None:
                    tr.emit("worker_respawn", node=wid, pid=nw.proc.pid,
                            gen=nw.gen, reason=reason)
                    tr.emit("node_join", node=wid, fresh=False)
            except BaseException:  # pragma: no cover - fork failure
                traceback.print_exc()
        crashed = WorkerCrashed(
            f"worker {wid} (pid {w.proc.pid}) died ({reason}); "
            f"log: {w.log_path}")
        have_live = respawned or any(x.alive for x in self._workers.values())
        for jid in victims:
            job = self._jobs.get(jid)
            if job is None or job.phase != RUNNING or job.node != wid:
                continue
            if fatal is not None and not retriable(fatal):
                self._fail_job(job, fatal)       # poisoned conversation
            elif not have_live:
                self._fail_job(job, crashed)     # nowhere left to retry
            else:
                self._retry_or_fail(job, f"worker {wid} died ({reason})",
                                    crashed)

    def _handle_content_loss(self, job: _RJob, exc: BaseException) -> None:
        """A step's needs hit missing/quarantined store content.  The read
        that detected it already kicked off recovery (re-put, worker push
        or lineage recompute); back off and retry the step, giving up with
        the typed loss itself."""
        self._retry_or_fail(job, f"content loss ({type(exc).__name__})", exc)

    def _retry_or_fail(self, job: _RJob, reason: str,
                       give_up: BaseException) -> None:
        if job.phase in (DONE, RETRY_WAIT):
            return
        job.retries += 1
        if job.retries > self.job_retry_limit:
            self._fail_job(job, give_up)
            return
        delay = min(self.retry_backoff_s * 2 ** (job.retries - 1),
                    self.retry_backoff_cap_s)
        if self.trace is not None:
            self.trace.emit("job_resubmit", job=job.id, epoch=job.epoch,
                            attempt=job.retries, delay_s=delay, reason=reason)
        job.phase = RETRY_WAIT
        jid, epoch = job.id, job.epoch
        box: dict = {}

        def fire() -> None:
            self._timers.discard(box["t"])
            self._events.put(("retry_job", jid, epoch))

        timer = box["t"] = threading.Timer(delay, fire)
        timer.daemon = True
        self._timers.add(timer)
        timer.start()

    def _on_retry(self, jid: int, epoch: int) -> None:
        job = self._jobs.get(jid)
        if job is None or job.phase != RETRY_WAIT or job.epoch != epoch:
            return
        self._redispatch(job)

    def _on_job_timeout(self, jid: int, epoch: int) -> None:
        job = self._jobs.get(jid)
        if job is None or job.phase != RUNNING or job.epoch != epoch:
            return
        w = self._workers.get(job.node) if job.node else None
        if w is not None:
            w.outstanding.discard(jid)
        self._retry_or_fail(
            job, "dispatch timed out",
            TransferFailed("control", job.node or "?", job.retries + 1,
                           "dispatch timed out"))

    def _redispatch(self, job: _RJob) -> None:
        """Resubmit from the job's current step.  The epoch bump makes any
        late reply from the previous dispatch stale; duplicate results are
        harmless anyway (dup-put no-ops in the content-addressed store)."""
        self.resubmits += 1
        job.epoch += 1
        job.node = None
        job.phase = RESOLVE
        if job.whnf is not None and job.strict:
            self._strictify_guarded(job)
        else:
            self._advance_guarded(job)

    # ---------------------------------------------------------- quarantine
    def _store_read(self, h: Handle, dst: str) -> Optional[bytes]:
        """Store read with rot handling: CorruptData quarantines the entry
        and starts recovery; the caller sees 'absent', never the rot."""
        try:
            return self.store.get(h)
        except CorruptData:
            self._quarantine(h, via="read", dst=dst)
            try:
                # the client-repo re-put branch of recovery is synchronous:
                # the content may already be back, verified
                return self.store.get(h)
            except CorruptData:  # pragma: no cover - re-rotted immediately
                return None

    def _quarantine(self, h: Handle, via: str, dst: str) -> None:
        """Evict a rotten store entry and start recovery: re-put from the
        client repo, ask a live worker that holds the content to push it
        back, or recompute it through the recorded lineage encode."""
        key = h.content_key()
        with self._quar_lock:
            if key in self._quarantined:
                return  # already quarantined; recovery underway
            self._quarantined.add(key)
        with self._store_mutex:
            self.store.delete(h)
        self.quarantines += 1
        key_hex = key.hex()
        tr = self.trace
        if tr is not None:
            tr.emit("corruption_detected", dst="store", key=key_hex, via=via,
                    reader=dst)
            tr.emit("quarantine", node="store", key=key_hex)
        self._locs.discard(key, "store")
        if self._repo.contains(h):
            with self._store_mutex:
                self._ensure_in_store_locked(None, h)
            return
        holders = [n for n in self._locs.nodes_for(key)
                   if n in self._workers and self._workers[n].alive]
        if holders:
            w = self._workers[holders[0]]
            self._recomputing.add(key)
            if tr is not None:
                tr.emit("stage_request", job=None, dst="store", key=key_hex,
                        nbytes=payload_nbytes(h), action="push",
                        src=holders[0])
            try:
                self._ctl_send(w, {"op": "push", "raws": [h.raw]})
                return
            except OSError:
                pass  # the holder died under us: fall through to recompute
        enc_raw = self._lineage.get(key)
        if enc_raw is not None:
            self._recomputing.add(key)
            self.recomputes += 1
            if tr is not None:
                tr.emit("stage_request", job=None, dst="store", key=key_hex,
                        nbytes=payload_nbytes(h), action="recompute",
                        src=None)
            self._events.put(("submit", Handle(enc_raw), None, None, True,
                              None))

    # ------------------------------------------------------------ terminal
    def _finalize(self, job: _RJob, result: Handle) -> None:
        job.result = result
        job.phase = DONE
        if self.trace is not None:
            self.trace.emit("job_finish", job=job.id, node=job.node,
                            result=result.raw.hex())
        self._count_job(job, "finished")
        if self.metrics is not None:
            tl = {} if job.tenant is None else {"tenant": job.tenant}
            self.metrics.histogram("job_latency_s", **tl).observe(
                time.monotonic() - job._metric_t0)
        if self.spans is not None and job.span is not None:
            self.spans.end(job.span, status="ok")
            job.span = None
        self._memo.setdefault(job.encode.raw, result)
        for f in job.futures:
            f.set(result)
        for pid in job.parents:
            self._child_resolved(pid, job.encode)

    def _fail_job(self, job: _RJob, exc: BaseException) -> None:
        if job.phase == DONE:
            return
        job.phase = DONE
        if self.trace is not None:
            self.trace.emit("job_fail", job=job.id, error=type(exc).__name__)
        self._count_job(job, "failed")
        if self.spans is not None and job.span is not None:
            self.spans.end(job.span, status="fail")
            job.span = None
        for f in job.futures:
            f.set_exception(exc)
        self._notify_parents_exc(job, exc)

    def _notify_parents_exc(self, job: _RJob, exc: BaseException) -> None:
        for pid in job.parents:
            parent = self._jobs.get(pid)
            if parent is not None and parent.phase != DONE:
                self._fail_job(parent, exc)

    # -------------------------------------------------------------- cancel
    def _on_cancel(self, fut: Future, reason: str) -> None:
        exc = self._cancel_exc(reason)
        jid = getattr(fut, "_jid", None)
        job = self._jobs.get(jid) if jid is not None else None
        if job is None or job.phase == DONE:
            fut.set_exception(exc)  # no-op if it already completed
            return
        others = [f for f in job.futures if f is not fut]
        if others or job.parents:
            # the job is shared (dedup or a parent's child): cancel only
            # this waiter, the computation itself is still wanted
            fut.set_exception(exc)
            job.futures = others
            return
        self._cancel_job(job, reason)

    def _cancel_job(self, job: _RJob, reason: str) -> None:
        if job.phase == DONE:
            return
        job.phase = DONE
        if self.trace is not None:
            self.trace.emit("job_cancel", job=job.id, reason=reason)
        self._count_job(job, "cancelled")
        if self.spans is not None and job.span is not None:
            self.spans.end(job.span, status="cancel")
            job.span = None
        exc = self._cancel_exc(reason)
        for f in job.futures:
            f.set_exception(exc)
        job.futures = []
        if job.node is not None:
            w = self._workers.get(job.node)
            if w is not None:
                w.outstanding.discard(job.id)
        # prune orphaned children: a child submitted only on behalf of
        # this job (no other parent, no direct waiter) is cancelled too
        for cid in sorted(job.children):
            child = self._jobs.get(cid)
            if child is None or child.phase == DONE:
                continue
            if job.id in child.parents:
                child.parents.remove(job.id)
            if not child.parents and not child.futures:
                self._cancel_job(child, reason)

    def _on_teardown(self) -> None:
        exc = WorkerCrashed("backend closed with work outstanding")
        for job in list(self._jobs.values()):
            if job.phase != DONE:
                self._fail_job(job, exc)

    # ------------------------------------------------------------ localize
    def _localize(self, handle: Handle) -> None:
        """Pull a result's object closure store→client (the accounted,
        traced fetch hop — the remote analogue of the cluster's
        ``fetch_result`` link charges)."""
        if handle.is_ref():
            handle = handle.as_object()
        closure = walk_object_closure(
            handle, lambda h: self._memo.get(h.raw),
            self._tree_children, {})
        for h in closure:
            self._pull_to_client(h)

    def _localize_shallow(self, handle: Handle) -> None:
        """Pull only this handle's own content (a tree node, not its
        children) — the streaming-fetch hop."""
        if handle.is_ref():
            handle = handle.as_object()
        self._pull_to_client(handle)

    def _pull_to_client(self, h: Handle) -> None:
        if h.is_literal or self._repo.contains(h):
            return
        key = h.content_key()
        payload = self._store_read(h, dst="client")
        if payload is None and key in self._recomputing:
            # quarantine recovery is in flight: wait (bounded) for the
            # re-put/recompute to land rather than failing a good answer
            deadline = time.monotonic() + self.recover_wait_s
            while payload is None and time.monotonic() < deadline:
                if key not in self._recomputing:
                    payload = self._store_read(h, dst="client")
                    break
                time.sleep(0.02)
                payload = self._store_read(h, dst="client")
        if payload is None:
            if key in self._quarantined:
                raise CorruptData(h)
            raise MissingData(h)
        nbytes = payload_nbytes(h)
        data = (payload if h.content_type == BLOB
                else decode_tree_payload(payload))
        tr = self.trace
        key_hex = key.hex()
        with self._store_mutex:
            if self._repo.contains(h):
                return
            if tr is not None:
                tr.emit("stage_request", job=None, dst="client", key=key_hex,
                        nbytes=nbytes, action="enqueue", src="store")
            self._repo.put_handle_data(h, data)  # fires put(node="client")
            if tr is not None:
                tr.emit("transfer_deliver", src="store", dst="client", n=1,
                        nbytes=nbytes, keys=[key_hex], ok=True, via="store")
        self.transfers += 1
        self.bytes_moved += nbytes
        if self.metrics is not None:
            self.metrics.counter("transfers_total").inc()
            self.metrics.counter("bytes_moved_total").inc(nbytes)

    # ----------------------------------------------------------- listeners
    def _on_store_put(self, handle: Handle, nbytes: int, src: str) -> None:
        key = handle.content_key()
        self._locs.add(key, "store")
        self._quarantined.discard(key)   # verified content re-installed
        self._recomputing.discard(key)   # recovery (if any) has landed
        if self.trace is not None:
            self.trace.emit("put", node="store", key=key.hex(), nbytes=nbytes)

    def _on_client_put(self, handle: Handle) -> None:
        self._locs.add(handle.content_key(), "client")
        if self.trace is not None:
            self.trace.emit("put", node="client",
                            key=handle.content_key().hex(),
                            nbytes=payload_nbytes(handle))


def remote(n_workers: int = 2, **kwargs) -> RemoteBackend:
    """Spawn a multi-process backend: ``fix.remote(n_workers=4)``."""
    return RemoteBackend(n_workers, **kwargs)
