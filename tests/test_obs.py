"""Observability views: the Perfetto exporter round-trip and the
``repro.obs.top`` renderer.

The exporter contract pinned here: exporting the committed golden
quickstart trace yields schema-valid Chrome ``trace_event`` JSON that is
byte-stable across runs and covers every job and transfer event in the
source trace (intervals for placed/run jobs and link serialization,
instants for everything else)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))

from repro.obs import export_json, render_snapshot, to_trace_events  # noqa: E402
from repro.obs.perfetto import export_file  # noqa: E402
from repro.obs.top import main as top_main  # noqa: E402
from repro.runtime import TraceRecorder, VirtualClock, Cluster  # noqa: E402
from repro.runtime.trace import load_trace  # noqa: E402
from workloads import FIXTURE  # noqa: E402

import repro.fix as fix  # noqa: E402
from repro.core.stdlib import add, fib  # noqa: E402

pytestmark = pytest.mark.usefixtures("no_thread_leaks")


class TestPerfettoExport:
    def test_fixture_roundtrip_valid_and_stable(self, tmp_path):
        events = load_trace(FIXTURE)
        out1 = export_json(events)
        out2 = export_json(load_trace(FIXTURE))
        assert out1 == out2  # byte-stable across runs
        doc = json.loads(out1)
        assert set(doc) == {"displayTimeUnit", "traceEvents"}
        for ev in doc["traceEvents"]:
            assert ev["ph"] in ("X", "M", "i")
            assert ev["pid"] == 1
            assert isinstance(ev["tid"], int)
            if ev["ph"] == "X":
                assert ev["dur"] >= 1
                assert isinstance(ev["ts"], int)

    def test_fixture_covers_every_job_and_transfer(self):
        events = load_trace(FIXTURE)
        doc = json.loads(export_json(events))
        tevs = doc["traceEvents"]
        # every submitted job appears (as an instant or an interval slice)
        jobs_out = {e["args"]["job"] for e in tevs
                    if e["ph"] != "M" and "job" in e.get("args", {})}
        jobs_in = {e["job"] for e in events if e["kind"] == "job_submit"}
        assert jobs_in <= jobs_out
        # every link serialization window becomes an xfer slice
        n_links = sum(1 for e in events if e["kind"] == "link_acquire")
        n_xfer = sum(1 for e in tevs if e.get("cat") == "xfer")
        assert n_xfer == n_links
        # every transfer delivery/stage request becomes an instant
        for kind in ("transfer_deliver", "stage_request"):
            n_in = sum(1 for e in events if e["kind"] == kind)
            n_out = sum(1 for e in tevs if e.get("cat") == kind)
            assert n_out == n_in
        # lane metadata names every tid exactly once
        tids = {e["tid"] for e in tevs if e["ph"] != "M"}
        named = {e["tid"] for e in tevs if e["ph"] == "M"}
        assert tids == named

    def test_spans_exported_with_parents(self):
        tr = TraceRecorder()
        clk = VirtualClock()
        c = Cluster(n_nodes=2, workers_per_node=1, clock=clk,
                    trace=tr, spans=True)
        try:
            fix.on(c).submit(fib(6)).result(timeout=60)
        finally:
            c.shutdown()
            clk.close()
        doc = json.loads(export_json(tr.events))
        spans = [e for e in doc["traceEvents"] if e.get("cat") == "span"]
        assert spans
        sids = {e["args"]["span"] for e in spans}
        parents = {e["args"]["parent"] for e in spans
                   if "parent" in e["args"]}
        assert parents and parents <= sids

    def test_export_file_and_cli(self, tmp_path):
        out = tmp_path / "trace.json"
        n = export_file(FIXTURE, str(out))
        assert n == len(json.loads(out.read_text())["traceEvents"])
        res = subprocess.run(
            [sys.executable, "-m", "repro.obs.perfetto", FIXTURE,
             str(tmp_path / "cli.json")],
            capture_output=True, text=True,
            env={"PYTHONPATH": str(Path(__file__).resolve().parent.parent
                                   / "src"), "PATH": "/usr/bin:/bin"})
        assert res.returncode == 0, res.stderr
        assert (tmp_path / "cli.json").read_text() == out.read_text()


class TestTopRenderer:
    def _cluster_stats(self):
        clk = VirtualClock()
        c = Cluster(n_nodes=2, workers_per_node=1, clock=clk)
        try:
            be = fix.on(c)
            be.submit(add(20, 22), tenant="acme").result(timeout=60)
            return c.stats()
        finally:
            c.shutdown()
            clk.close()

    def test_render_cluster_snapshot(self):
        text = render_snapshot(self._cluster_stats())
        assert "backend=cluster" in text
        assert "jobs:" in text and "submitted=" in text
        assert "add" in text  # codelet table
        assert "n0" in text and "n1" in text

    def test_render_is_pure(self):
        st = self._cluster_stats()
        assert render_snapshot(st) == render_snapshot(st)

    def test_render_tolerates_minimal_stats(self):
        # the Backend.stats() default shape must render, not crash
        text = render_snapshot({"backend": "none", "metrics": {},
                                "codelets": {}})
        assert "backend=none" in text

    def test_render_serving_shape(self):
        st = {"backend": {"backend": "local", "metrics": {}, "codelets": {}},
              "serving": {"steps": 3, "decode_steps": 5, "blocks_total": 4,
                          "blocks_hit": 2, "pending": 0, "active": 1,
                          "finished": 2},
              "tenants": {"a": {"queued": 0, "inflight": 1, "admitted": 2}}}
        text = render_snapshot(st)
        assert "== serving ==" in text
        assert "prefix blocks: 2/4 hit (50%)" in text
        assert "a" in text and "admitted" in text

    def test_top_once_stats_file(self, tmp_path, capsys):
        path = tmp_path / "stats.json"
        path.write_text(json.dumps(self._cluster_stats()))
        assert top_main(["--once", "--stats", str(path)]) == 0
        out = capsys.readouterr().out
        assert "backend=cluster" in out

    def test_top_once_demo(self, capsys):
        assert top_main(["--once"]) == 0
        assert "backend=cluster" in capsys.readouterr().out


class TestServingStats:
    def test_fixserve_stats_shape(self):
        from repro.serving.admission import TenantQueue
        from repro.serving.engine import Request
        from repro.serving.fixserve import FixServeEngine
        from repro.serving.model import make_weights
        import numpy as np

        weights = make_weights(seed=7, vocab=64, eos=0)
        with fix.local() as be:
            eng = FixServeEngine(be, weights, batch=2, block=8,
                                 admission=TenantQueue())
            reqs = [Request(rid=i,
                            prompt=np.asarray(range(1, 17), np.int32),
                            max_new=3, tenant=t)
                    for i, t in enumerate(("a", "b"))]
            eng.serve(reqs)
            st = eng.stats()
        assert st["backend"]["backend"] == "local"
        assert st["serving"]["finished"] == 2
        assert st["serving"]["decode_steps"] >= 1
        assert set(st["tenants"]) == {"a", "b"}
        for d in st["tenants"].values():
            assert d["inflight"] == 0      # all released
            assert d["admitted"] >= 1
        # the nested shape renders through obs.top
        assert "== serving ==" in render_snapshot(st)
