"""Deterministic fault injection for the real multi-process backend.

The simulated cluster's :mod:`repro.runtime.faults` pins faults to
virtual-clock instants; real processes have no virtual clock, so this shim
pins them to **deterministic event counts** instead — the Nth control-plane
send to a worker, the Nth store put, the Nth heartbeat — and acts through
*real* mechanisms:

==========================  ===============================================
injection                   mechanism
==========================  ===============================================
``kill_worker``             SIGKILL the worker process at the Nth
                            control-plane send (after the frame leaves) or
                            the Nth reply received from it
``truncate_frame``          write a partial frame then ``shutdown(WR)`` the
                            control socket: the worker sees a mid-frame EOF
                            (:class:`~repro.remote.protocol.FrameTruncated`)
                            and dies; the backend sees the EOF and recovers
``drop_frame``              swallow the Nth control-plane send entirely
                            (pair with ``dispatch_timeout_s`` so the
                            watchdog resubmits the stranded step)
``delay_frame``             sleep before the Nth control-plane send
``stall_heartbeats``        swallow the next N pongs from a worker so the
                            monitor counts misses and (past the budget)
                            fences the process
``rot_store``               flip a byte of the Nth freshly-put store object
                            *at rest* (the backend's ``verify_reads``
                            catches it on the next read → quarantine +
                            recovery)
==========================  ===============================================

Every applied injection is recorded in :attr:`log` and emitted as a PR-6
typed ``fault`` trace event, so fault-mode ``verify_invariants`` checks a
chaotic real run exactly like a chaotic simulated one: the backend's own
recovery events (``fault fault=crash``, ``worker_respawn``, ``node_join``,
``corruption_detected``, ``quarantine``, ``job_resubmit``) answer every
injected loss.

Determinism caveat, stated honestly: the *schedule* is deterministic (same
seed → same injection points, counted per worker), but real thread/process
interleaving varies between runs, so which logical step a given send index
carries can vary.  The chaos invariant the tests assert is therefore
schedule-shaped, not replay-shaped: every run either completes with
byte-identical results or fails with an attributed typed error — never
hangs, never silently corrupts.

Usage::

    chaos = (RemoteChaos(seed=7)
             .kill_worker("w0", after_send=1)
             .rot_store(at_put=3))
    with fix.remote(n_workers=2, chaos=chaos, trace=tr) as be:
        ...

or seeded, mirroring the simulator's schedule-from-seed idiom::

    chaos = seeded_chaos(seed, wids=["w0", "w1"])
"""
from __future__ import annotations

import random
import socket
import struct
import threading
import time
from typing import Optional

from .protocol import pack, send_msg

__all__ = ["RemoteChaos", "seeded_chaos"]


class RemoteChaos:
    """A declarative, count-indexed fault schedule for ``fix.remote()``.

    Build with the chainable ``kill_worker`` / ``truncate_frame`` /
    ``drop_frame`` / ``delay_frame`` / ``stall_heartbeats`` / ``rot_store``
    methods, then pass as ``fix.remote(chaos=...)`` — the backend binds the
    shim (arming ``store.verify_reads``) and routes control-plane sends,
    reply receipts, heartbeat pongs and store puts through it.  All indices
    are 0-based per-worker (or per-store) event counts.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._lock = threading.Lock()
        self._backend = None
        # event counters
        self._sends: dict[str, int] = {}
        self._recvs: dict[str, int] = {}
        self._puts = 0
        # armed injections
        self._kills: set[tuple] = set()          # (wid, plane, idx)
        self._truncs: set[tuple] = set()         # (wid, idx)
        self._drops: set[tuple] = set()          # (wid, idx)
        self._delays: dict[tuple, float] = {}    # (wid, idx) -> seconds
        self._stalls: dict[str, int] = {}        # wid -> pongs to swallow
        self._rots: set[int] = set()             # put indices
        self.log: list[tuple] = []               # applied injections

    # ------------------------------------------------------------ builders
    def kill_worker(self, wid: str, *, after_send: Optional[int] = None,
                    after_recv: Optional[int] = None) -> "RemoteChaos":
        """SIGKILL ``wid`` right after its Nth control-plane send (the
        frame still arrives — mid-job death) or Nth received reply."""
        if after_send is None and after_recv is None:
            raise ValueError("need after_send or after_recv")
        if after_send is not None:
            self._kills.add((wid, "send", after_send))
        if after_recv is not None:
            self._kills.add((wid, "recv", after_recv))
        return self

    def truncate_frame(self, wid: str, *, at_send: int) -> "RemoteChaos":
        """Cut the Nth control frame to ``wid`` in half and close the write
        side — a mid-frame EOF on a real socket."""
        self._truncs.add((wid, at_send))
        return self

    def drop_frame(self, wid: str, *, at_send: int) -> "RemoteChaos":
        """Swallow the Nth control frame to ``wid`` (silent loss)."""
        self._drops.add((wid, at_send))
        return self

    def delay_frame(self, wid: str, *, at_send: int,
                    delay_s: float = 0.2) -> "RemoteChaos":
        """Stall the Nth control frame to ``wid`` for ``delay_s``."""
        self._delays[(wid, at_send)] = delay_s
        return self

    def stall_heartbeats(self, wid: str, *, count: int) -> "RemoteChaos":
        """Swallow the next ``count`` pongs from ``wid`` — past the miss
        budget the monitor fences (SIGKILLs) the worker."""
        self._stalls[wid] = self._stalls.get(wid, 0) + count
        return self

    def rot_store(self, *, at_put: int) -> "RemoteChaos":
        """Flip a byte of the Nth freshly-installed store object at rest."""
        self._rots.add(at_put)
        return self

    # ------------------------------------------------------------- binding
    def bind(self, backend) -> None:
        """Called by the backend constructor: subscribe to store puts (for
        at-rest rot) and remember where to emit trace events."""
        self._backend = backend
        backend.store.add_put_listener(self._on_store_put)

    def close(self) -> None:
        self._backend = None

    # ------------------------------------------------------------ hooks
    def ctl_send(self, w, msg: dict) -> None:
        """The backend's control-plane send, with injections applied."""
        wid = w.wid
        with self._lock:
            idx = self._sends.get(wid, 0)
            self._sends[wid] = idx + 1
            delay = self._delays.get((wid, idx))
            drop = (wid, idx) in self._drops
            trunc = (wid, idx) in self._truncs
            kill = (wid, "send", idx) in self._kills
        if delay:
            self._emit("delay_frame", node=wid, at=idx, delay_s=delay)
            time.sleep(delay)
        if drop:
            self._emit("drop_frame", node=wid, at=idx)
            return
        if trunc:
            self._emit("truncate_frame", node=wid, at=idx)
            body = pack(msg)
            frame = struct.pack(">I", len(body)) + body
            with w.send_lock:
                try:
                    w.ctl.sendall(frame[:max(5, len(frame) // 2)])
                    w.ctl.shutdown(socket.SHUT_WR)
                except OSError:
                    pass
            return
        send_msg(w.ctl, msg, lock=w.send_lock)
        if kill:
            self._emit("kill_worker", node=wid, at=idx, plane="send")
            self._kill(w)

    def on_ctl_recv(self, w) -> None:
        """Called by the backend's reader for every worker reply."""
        with self._lock:
            idx = self._recvs.get(w.wid, 0)
            self._recvs[w.wid] = idx + 1
            kill = (w.wid, "recv", idx) in self._kills
        if kill:
            self._emit("kill_worker", node=w.wid, at=idx, plane="recv")
            self._kill(w)

    def take_pong(self, wid: str) -> bool:
        """Consulted per received pong; False = swallow it (stall)."""
        with self._lock:
            n = self._stalls.get(wid, 0)
            if n <= 0:
                return True
            self._stalls[wid] = n - 1
        self._emit("stall_heartbeat", node=wid)
        return False

    # ------------------------------------------------------------ internal
    def _on_store_put(self, handle, nbytes: int, src: str) -> None:
        be = self._backend
        with self._lock:
            idx = self._puts
            self._puts += 1
            rot = idx in self._rots
        if rot and be is not None:
            if be.store._corrupt(handle.content_key()):
                self._emit("rot_store", node="store", at=idx,
                           key=handle.content_key().hex())

    @staticmethod
    def _kill(w) -> None:
        try:
            w.proc.kill()
        except Exception:  # noqa: BLE001 - already dead is fine
            pass

    def _emit(self, fault: str, **fields) -> None:
        self.log.append((fault, fields))
        be = self._backend
        tr = be.trace if be is not None else None
        if tr is not None:
            tr.emit("fault", fault=fault, applied=True, **fields)


def seeded_chaos(seed: int, wids, *, n_faults: int = 2,
                 kinds=("kill", "truncate", "rot", "stall")) -> RemoteChaos:
    """Build a :class:`RemoteChaos` schedule from a seed — the remote
    analogue of the simulator's schedule-from-seed idiom.  The same seed
    always arms the same injections at the same event counts."""
    rng = random.Random(seed)
    chaos = RemoteChaos(seed=seed)
    wids = list(wids)
    for _ in range(n_faults):
        kind = rng.choice(list(kinds))
        wid = rng.choice(wids)
        if kind == "kill":
            plane = rng.choice(["send", "recv"])
            chaos.kill_worker(wid, **{f"after_{plane}": rng.randrange(0, 6)})
        elif kind == "truncate":
            chaos.truncate_frame(wid, at_send=rng.randrange(0, 6))
        elif kind == "drop":
            chaos.drop_frame(wid, at_send=rng.randrange(0, 6))
        elif kind == "delay":
            chaos.delay_frame(wid, at_send=rng.randrange(0, 6),
                              delay_s=rng.uniform(0.02, 0.2))
        elif kind == "rot":
            chaos.rot_store(at_put=rng.randrange(0, 10))
        elif kind == "stall":
            chaos.stall_heartbeats(wid, count=rng.randrange(2, 8))
        else:
            raise ValueError(f"unknown chaos kind {kind!r}")
    return chaos
