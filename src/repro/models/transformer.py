"""Dense decoder-only transformer (GQA, RoPE, optional qk-norm, SwiGLU).

Covers qwen3-8b / qwen3-4b (qk_norm), deepseek-67b, internlm2-20b, and is
the text backbone for internvl2-26b.  Layers are stacked [L, ...] and run
under ``jax.lax.scan`` so the HLO (and compile time) is O(1) in depth.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .base import (
    apply_remat,
    scan_layers,
    ModelConfig,
    ParamSpec,
    attend,
    causal_mask,
    embed_tokens,
    ps,
    repeat_kv,
    rmsnorm,
    rope,
    swiglu,
    unembed,
)

# ------------------------------------------------------------------- specs
def dense_layer_specs(cfg: ModelConfig, n_layers: Optional[int] = None) -> dict:
    L = n_layers if n_layers is not None else cfg.n_layers
    D, H, Kv, hd, F = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_eff, cfg.d_ff
    specs = {
        "attn_norm": ps((L, D), ("p_layers", "p_none"), init="ones"),
        "wq": ps((L, D, H, hd), ("p_layers", "p_embed", "p_heads", "p_none")),
        "wk": ps((L, D, Kv, hd), ("p_layers", "p_embed", "p_kv_heads", "p_none")),
        "wv": ps((L, D, Kv, hd), ("p_layers", "p_embed", "p_kv_heads", "p_none")),
        "wo": ps((L, H, hd, D), ("p_layers", "p_heads", "p_none", "p_embed")),
        "mlp_norm": ps((L, D), ("p_layers", "p_none"), init="ones"),
        "w_gate": ps((L, D, F), ("p_layers", "p_embed", "p_mlp")),
        "w_up": ps((L, D, F), ("p_layers", "p_embed", "p_mlp")),
        "w_down": ps((L, F, D), ("p_layers", "p_mlp", "p_embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ps((L, hd), ("p_layers", "p_none"), init="ones")
        specs["k_norm"] = ps((L, hd), ("p_layers", "p_none"), init="ones")
    return specs


def dense_specs(cfg: ModelConfig) -> dict:
    Vp, D = cfg.vocab_padded, cfg.d_model
    specs = {
        "embed": ps((Vp, D), ("p_vocab", "p_embed"), init="embed", scale=0.02),
        "layers": dense_layer_specs(cfg),
        "final_norm": ps((D,), ("p_none",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ps((D, Vp), ("p_embed", "p_vocab"))
    if cfg.n_patches:  # VLM backbone: ViT-embedding projection (frontend stub)
        specs["patch_proj"] = ps((3200, D), ("p_none", "p_embed"))
    return specs


# ----------------------------------------------------------------- blocks
def attn_block(x, lp, cfg: ModelConfig, sh, positions, kv_cache=None):
    """Pre-norm GQA attention.  Returns (residual output, (k, v)).

    Train/prefill: kv_cache None, full causal over x itself.
    Decode: kv_cache = (k_all [B,T,Kv,hd], v_all, write_pos scalar); x is the
    single new token's hidden state.
    """
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(h.dtype))
    if cfg.qk_norm:
        q = rmsnorm(q, lp["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, lp["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = sh(q, "batch", "seq", "heads", None)

    if kv_cache is None:
        k_full, v_full = k, v
        mask = None
        pattern = "causal"
        k_sh, v_sh = ("batch", "seq", "kv_heads", None), ("batch", "seq", "kv_heads", None)
    else:
        k_all, v_all, pos = kv_cache
        k_full = jax.lax.dynamic_update_slice(k_all, k.astype(k_all.dtype), (0, pos, 0, 0))
        v_full = jax.lax.dynamic_update_slice(v_all, v.astype(v_all.dtype), (0, pos, 0, 0))
        mask = (jnp.arange(k_full.shape[1]) <= pos)[None, None, None, :]
        pattern = None
        k_sh, v_sh = ("batch", "kv_seq", "kv_heads", None), ("batch", "kv_seq", "kv_heads", None)
    k_full = sh(k_full, *k_sh)
    v_full = sh(v_full, *v_sh)

    kr = repeat_kv(k_full.astype(q.dtype), cfg.n_heads)
    vr = repeat_kv(v_full.astype(q.dtype), cfg.n_heads)
    o = attend(q, kr, vr, mask, sh, pattern=pattern)
    o = sh(o, "batch", "seq", "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(o.dtype))
    return x + sh(out, "batch", "res_seq", "embed"), (k_full, v_full)


def mlp_block(x, lp, cfg: ModelConfig, sh):
    h = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    out = swiglu(h, lp["w_gate"].astype(h.dtype), lp["w_up"].astype(h.dtype),
                 lp["w_down"].astype(h.dtype), sh)
    return x + sh(out, "batch", "res_seq", "embed")


def dense_layer(x, lp, cfg: ModelConfig, sh, positions, kv_cache=None):
    x, kv = attn_block(x, lp, cfg, sh, positions, kv_cache)
    x = mlp_block(x, lp, cfg, sh)
    return x, kv


# ---------------------------------------------------------------- forward
def _embed_input(params, batch, cfg: ModelConfig, sh):
    """Tokens -> embeddings; VLM prepends projected patch embeddings."""
    emb = params["embed"].astype(cfg.compute_dtype)
    x = embed_tokens(emb, batch["tokens"], sh)
    x = sh(x, "batch", "res_seq", "embed")
    if cfg.n_patches and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cfg.compute_dtype)
        pe = jnp.einsum("bpe,ed->bpd", pe, params["patch_proj"].astype(pe.dtype))
        x = jnp.concatenate([pe, x], axis=1)
        x = sh(x, "batch", "seq", "embed")
    return x


def dense_forward(params, batch, cfg: ModelConfig, sh, remat_policy=None,
                  remat_group: int = 1):
    """Full-sequence causal forward -> logits [B, S, Vp]."""
    x = _embed_input(params, batch, cfg, sh)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        x, _ = dense_layer(x, lp, cfg, sh, positions)
        return x, None

    x, _ = scan_layers(body, x, params["layers"], remat_policy, remat_group)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w_un = params.get("unembed", params["embed"].T if cfg.tie_embeddings else None)
    return unembed(x, w_un.astype(x.dtype), sh)


# ------------------------------------------------------------------ cache
def dense_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    L, Kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_eff
    kv = ps((L, batch, max_seq, Kv, hd),
            ("p_layers", "batch", "kv_seq", "kv_heads", "p_none"), init="zeros",
            dtype=cfg.compute_dtype)
    return {"k": kv, "v": kv,
            "pos": ps((), (), init="zeros", dtype=jnp.int32)}


def dense_decode_step(params, cache, tokens, cfg: ModelConfig, sh):
    """One new token against a KV cache of length cache['k'].shape[2]."""
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), tokens, sh)
    pos = cache["pos"]
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)

    def body(x, layer):
        lp, k_all, v_all = layer
        x, (k_new, v_new) = dense_layer(x, lp, cfg, sh, positions,
                                        kv_cache=(k_all, v_all, pos))
        return x, (k_new, v_new)

    x, (k_stack, v_stack) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w_un = params.get("unembed", params["embed"].T if cfg.tie_embeddings else None)
    logits = unembed(x, w_un.astype(x.dtype), sh)
    new_cache = {"k": k_stack, "v": v_stack, "pos": pos + 1}
    return logits, new_cache


def dense_prefill(params, batch, cfg: ModelConfig, sh):
    """Prefill: forward + emit the KV cache (length = prompt length)."""
    x = _embed_input(params, batch, cfg, sh)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        x, kv = dense_layer(x, lp, cfg, sh, positions)
        return x, kv

    x, (k_stack, v_stack) = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w_un = params.get("unembed", params["embed"].T if cfg.tie_embeddings else None)
    logits = unembed(x[:, -1:], w_un.astype(x.dtype), sh)
    # hand the cache off in decode layout (context-parallel over kv_seq)
    k_stack = sh(k_stack, None, "batch", "kv_seq", "kv_heads", None)
    v_stack = sh(v_stack, None, "batch", "kv_seq", "kv_heads", None)
    cache = {"k": k_stack, "v": v_stack, "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache
