"""Lazy expression graphs: client-side construction of whole thunk DAGs.

Calling a typed codelet does not run anything — it returns a :class:`Lazy`
node.  Nesting calls, ``.strict()`` / ``.shallow()``, and ``expr[i]``
selection sugar grow the graph; :meth:`Lazy.compile` lowers it to Table-1
handles, so an arbitrarily deep program is still **one** submission that
describes its precise data needs.

The lowering is the paper's shared-representation guarantee made testable:
for every construct there is exactly one Table-1 spelling, chosen to match
what hand-written code in this repo already does —

* a call lowers to ``put_tree([limits, procedure, arg...]).application()``;
* a nested call in a *value* position (``int``/``bytes``/... parameter)
  lowers to the child thunk wrapped ``.strict()`` (the callee needs the
  value), while a nested call in a ``Handle`` position stays a bare thunk
  (laziness survives: fig 2's untaken branch never evaluates);
* ``expr[i]`` lowers to the ``[target, index]`` pair-tree Selection Thunk;
* compiled handles are therefore byte-identical to the equivalent
  hand-built ``combination`` tree (asserted in tests/test_fix_frontend.py).

Compilation needs only ``put_blob``/``put_tree`` — a client Repository or,
inside a codelet returning a tail-call expression, the sealed FixAPI via
:class:`~repro.fix.marshal.ApiEmitter`.  Content addressing makes the
result independent of *which* emitter lowered it.
"""
from __future__ import annotations

import struct
from typing import Any, Optional

from ..core.handle import Handle, SHALLOW, STRICT
from .marshal import MarshalError, element_type, marshal

_CALL, _CONST, _ENCODE, _SELECT = range(4)


class Lazy:
    """A node of a client-side Fix expression graph."""

    __slots__ = ("_kind", "_codelet", "_args", "_kwargs", "_value", "_target",
                 "_mode", "_index", "out_type")

    def __init__(self, kind: int, *, codelet=None, args=None, kwargs=None,
                 value=None, target=None, mode=None, index=None,
                 out_type=None):
        self._kind = kind
        self._codelet = codelet
        self._args = args
        self._kwargs = kwargs
        self._value = value
        self._target = target
        self._mode = mode
        self._index = index
        self.out_type = out_type

    # ------------------------------------------------------------- sugar
    def strict(self) -> "Lazy":
        """Demand the fully-evaluated value (Encode: maximum work)."""
        if self._kind == _ENCODE and self._mode == STRICT:
            return self
        return Lazy(_ENCODE, target=self, mode=STRICT, out_type=self.out_type)

    def shallow(self) -> "Lazy":
        """Demand WHNF only; data comes back as a Ref (minimum work)."""
        if self._kind == _ENCODE and self._mode == SHALLOW:
            return self
        return Lazy(_ENCODE, target=self, mode=SHALLOW, out_type=self.out_type)

    def __getitem__(self, index) -> "Lazy":
        """Selection Thunk sugar: ``expr[i]`` / ``expr[a:b]`` touch one child
        (or a subrange) without materializing the rest of the target."""
        if isinstance(index, slice):
            if index.step not in (None, 1):
                raise MarshalError("selection slices must be contiguous (step 1)")
            if (index.start or 0) < 0 or (index.stop is not None and index.stop < 0):
                raise MarshalError("selection slices take non-negative bounds "
                                   "(the target's length is not known client-side)")
        elif not isinstance(index, int):
            raise MarshalError(f"selection index must be int or slice, not "
                               f"{type(index).__name__}")
        elif index < 0:
            raise MarshalError("selection indices are non-negative "
                               "(the target's length is not known client-side)")
        return Lazy(_SELECT, target=self, index=index,
                    out_type=element_type(self.out_type, index))

    def __bool__(self):
        raise MarshalError(
            "a Lazy expression has no truth value yet — submit it to a "
            "backend (fix.local() / fix.on(cluster)) to evaluate it")

    def __repr__(self) -> str:
        if self._kind == _CALL:
            return f"<lazy call {self._codelet.name}/{len(self._args)}>"
        if self._kind == _CONST:
            return f"<lazy const {self._value!r}>"
        if self._kind == _ENCODE:
            kind = "strict" if self._mode == STRICT else "shallow"
            return f"<lazy {kind} {self._target!r}>"
        return f"<lazy select [{self._index!r}] of {self._target!r}>"

    # ----------------------------------------------------------- compile
    def compile(self, emitter, _memo: Optional[dict] = None) -> Handle:
        """Lower the graph to a Handle via ``emitter`` (put_blob/put_tree).

        Shared sub-expressions compile once per call (the graph is a DAG);
        content addressing makes the output emitter-independent.
        """
        memo = _memo if _memo is not None else {}
        cached = memo.get(id(self))
        if cached is not None:
            return cached
        h = self._compile(emitter, memo)
        memo[id(self)] = h
        return h

    def _compile(self, emitter, memo: dict) -> Handle:
        if self._kind == _CONST:
            return marshal(emitter, self._value)
        if self._kind == _CALL:
            cd = self._codelet
            kids = [emitter.put_blob(cd.limits), emitter.put_blob(cd.proc_payload)]
            for value, (_pname, hint) in zip(self._args, cd.required):
                kids.append(_lower_arg(emitter, value, hint, memo))
            if self._kwargs:
                # Overridden defaults ride as a trailing Tree of
                # [name-blob, value] pairs (signature order); all-default
                # calls omit it entirely, keeping pre-defaults content keys.
                pairs = []
                for pname, value in self._kwargs:
                    name_h = emitter.put_blob(pname.encode("utf-8"))
                    val_h = _lower_arg(emitter, value, cd._opt_hints[pname],
                                       memo)
                    pairs.append(emitter.put_tree([name_h, val_h]))
                kids.append(emitter.put_tree(pairs))
            return emitter.put_tree(kids).application()
        if self._kind == _ENCODE:
            t = self._target.compile(emitter, memo)
            return _encode(t, self._mode)
        # _SELECT: [target, index] pair-tree reinterpreted as a Selection
        t = self._target.compile(emitter, memo)
        if isinstance(self._index, slice):
            start, stop = self._index.start or 0, self._index.stop
            if stop is None:
                raise MarshalError("selection slices need an explicit stop")
            idx = emitter.put_blob(struct.pack("<qq", start, stop - start))
        else:
            idx = emitter.put_blob(struct.pack("<q", self._index))
        return emitter.put_tree([t, idx]).selection_of()


def _lower_arg(emitter, value: Any, hint: Any, memo: dict) -> Handle:
    """One argument position of a combination tree."""
    if isinstance(value, Lazy):
        ch = value.compile(emitter, memo)
        if hint is Handle or hint is Lazy:
            return ch  # callee wants the name, not the value: stays lazy
        if ch.is_thunk():
            return ch.strict()  # callee reads the value: demand it
        return ch  # already an encode / already data
    return marshal(emitter, value, hint)


def _encode(handle: Handle, mode: int) -> Handle:
    """Wrap a compiled handle in a strict/shallow Encode."""
    if handle.is_encode():
        inner = handle.unwrap_encode()
    elif handle.is_thunk():
        inner = handle
    elif handle.is_data():
        inner = handle.identification()  # evaluate-a-value: identity thunk
    else:
        raise MarshalError(f"cannot encode {handle!r}")
    return inner.strict() if mode == STRICT else inner.shallow()


def lit(value: Any, out_type: Any = None) -> Lazy:
    """Wrap a plain value or Handle as a Lazy leaf, unlocking the sugar:
    ``lit(tree_handle)[3]``, ``lit((1, 2, 3)).strict()``, ..."""
    if isinstance(value, Lazy):
        return value
    if out_type is None and isinstance(value, (int, bytes, str)) \
            and not isinstance(value, bool):
        out_type = type(value)
    elif out_type is None and isinstance(value, bool):
        out_type = bool
    return Lazy(_CONST, value=value, out_type=out_type)
