"""Fixpoint runtime: multi-node execution engine for Fix programs."""
from .clock import Clock, Timer, VirtualClock, WallClock
from .cluster import Cluster, Future, Link, Network
from .faults import (
    DataUnrecoverable,
    Fault,
    FaultError,
    FaultSchedule,
    TransferFailed,
)
from .node import Node, WorkItem
from .telemetry import (
    CodeletProfile,
    MetricsRegistry,
    SpanEmitter,
    job_wall_durations,
)
from .trace import (
    TraceDiff,
    TraceEvent,
    TraceRecorder,
    diff_traces,
    link_utilization,
    load_trace,
    replay_check,
    starvation_intervals,
    verify_invariants,
    waterfall,
)
from .transfers import LocationIndex, TransferManager, TransferPlan

__all__ = ["Clock", "Cluster", "Future", "Link", "Network", "Node",
           "Timer", "VirtualClock", "WallClock", "WorkItem",
           "LocationIndex", "TransferManager", "TransferPlan",
           "Fault", "FaultSchedule", "FaultError", "TransferFailed",
           "DataUnrecoverable",
           "CodeletProfile", "MetricsRegistry", "SpanEmitter",
           "job_wall_durations",
           "TraceDiff", "TraceEvent", "TraceRecorder", "diff_traces",
           "link_utilization", "load_trace", "replay_check",
           "starvation_intervals", "verify_invariants", "waterfall"]
