"""Procedure (codelet) registry.

In the paper, procedures are machine codelets: Wasm modules AOT-compiled by a
trusted toolchain into sandboxed x86-64 ELF objects, invoked through
``_fix_apply``.  Our codelets are deterministic Python callables (usually
wrapping ``jax.jit``-compiled XLA programs — *our* trusted toolchain).  A
procedure is named by a content-addressed Blob; the registry maps that blob's
content to the callable, mirroring Fixpoint's in-memory ELF linker: resolving
a procedure handle to an entrypoint is a dict lookup, off the critical path.

Codelets receive ``(api, combination)`` where ``api`` is a sealed
:class:`~repro.core.api.FixAPI` capability and ``combination`` is the Handle
of the Thunk's definition Tree ``[resource_limits, procedure, arg...]``.
They return a Handle — data, or another Thunk (tail call).
"""
from __future__ import annotations

from typing import Callable, Optional

from .handle import Handle

# content_key of the procedure blob -> callable(api, tree_handle) -> Handle
_REGISTRY: dict[bytes, Callable] = {}
_NAMES: dict[bytes, str] = {}


def procedure_blob(name: str) -> bytes:
    """Canonical bytes identifying a registered procedure."""
    return b"fix/proc/" + name.encode()


def register(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register ``fn`` as the codelet for procedure ``name``."""

    def deco(fn: Callable) -> Callable:
        payload = procedure_blob(name)
        key = Handle.blob(payload).content_key()
        if key in _REGISTRY and _REGISTRY[key] is not fn:
            raise ValueError(f"procedure {name!r} already registered")
        _REGISTRY[key] = fn
        _NAMES[key] = name
        fn.fix_procedure_name = name
        return fn

    return deco


def handle_for(repo, name: str) -> Handle:
    """Store the procedure blob in ``repo`` and return its Handle."""
    return repo.put_blob(procedure_blob(name))


def resolve(handle: Handle) -> Optional[Callable]:
    return _REGISTRY.get(handle.content_key())


def name_of(handle: Handle) -> Optional[str]:
    return _NAMES.get(handle.content_key())


def registered_names() -> list[str]:
    return sorted(_NAMES.values())


# --------------------------------------------------------------------------
# Resource limits: the first element of every Application combination.
# A 16-byte blob: uint64 RAM bytes, uint32 cpu slots, uint32 flags.
# The runtime uses this for late binding — a worker slot plus this much
# memory is claimed only once the minimum repository is resident.
# --------------------------------------------------------------------------

def make_limits(ram_bytes: int = 1 << 20, cpu_slots: int = 1, flags: int = 0) -> bytes:
    return ram_bytes.to_bytes(8, "little") + cpu_slots.to_bytes(4, "little") + flags.to_bytes(4, "little")


def parse_limits(payload: bytes) -> dict:
    if len(payload) != 16:
        raise ValueError("resource-limit blobs are 16 bytes")
    return {
        "ram_bytes": int.from_bytes(payload[0:8], "little"),
        "cpu_slots": int.from_bytes(payload[8:12], "little"),
        "flags": int.from_bytes(payload[12:16], "little"),
    }
