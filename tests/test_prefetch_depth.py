"""Prefetch depth >1: staging follows child thunks one level ahead.

``Cluster(prefetch_depth=d)`` with d>1 walks unresolved child Encodes'
definitions ``d-1`` levels down while a parent waits, staging the blobs
those children will need before the children even start.  Depth 1 is the
pre-knob behavior — asserted byte-identical against the committed golden
trace by test_trace.py; here we pin that depth>1 (a) produces identical
results, (b) emits a schedule that still passes every trace invariant,
and (c) actually stages deeper inputs earlier.
"""
import pytest

import repro.fix as fix
from repro.core.stdlib import checksum_tree, merge_counts
from repro.runtime import (
    Cluster,
    Link,
    Network,
    TraceRecorder,
    VirtualClock,
    verify_invariants,
)

pytestmark = pytest.mark.usefixtures("no_thread_leaks")


def _run(depth: int):
    """A fan-in over two storage-resident trees: the merge's children are
    checksum calls whose blob inputs are exactly what depth-2 prefetch
    can see one level down."""
    tr = TraceRecorder()
    net = Network(Link(latency_s=0.002, gbps=0.5))
    clk = VirtualClock()
    c = Cluster(n_nodes=3, workers_per_node=1, storage_nodes=("s0",),
                network=net, clock=clk, seed=0, trace=tr,
                prefetch_depth=depth)
    try:
        be = fix.on(c)
        store = c.nodes["s0"].repo
        t1 = store.put_tree([store.put_blob(bytes([i]) * 16384)
                             for i in range(3)])
        t2 = store.put_tree([store.put_blob(bytes([9 + i]) * 16384)
                             for i in range(3)])
        prog = merge_counts(checksum_tree(t1), checksum_tree(t2))
        result = be.submit(prog).result(timeout=300)
        return result.raw, tr, clk.now()
    finally:
        c.shutdown()
        clk.close()


def test_depth_validation():
    clk = VirtualClock()
    with pytest.raises(ValueError):
        Cluster(n_nodes=2, clock=clk, prefetch_depth=0)
    clk.close()


def test_depth2_identical_results_and_clean_invariants():
    raw1, tr1, _ = _run(depth=1)
    raw2, tr2, _ = _run(depth=2)
    assert raw1 == raw2
    assert verify_invariants(tr1.events) == []
    assert verify_invariants(tr2.events) == []


def test_depth2_stages_deeper_inputs_ahead():
    _, tr1, _ = _run(depth=1)
    _, tr2, _ = _run(depth=2)

    def stage_count(tr):
        return sum(1 for e in tr.events if e.kind == "stage_request")

    # depth 2 follows the children's definitions one level down while the
    # merge parent waits, so it issues staging for the grandchild blob
    # inputs that depth 1 only discovers when each child is placed
    assert stage_count(tr2) > stage_count(tr1)

    def earliest_stage_for_deep_blobs(tr):
        # the first staging decision for any s0-resident input
        ts = [e.t for e in tr.events
              if e.kind == "stage_request" and e.fields.get("src") == "s0"]
        starts = [e.t for e in tr.events if e.kind == "job_start"
                  and e.fields.get("op") == "run"]
        return min(ts), min(starts)

    stage2, start2 = earliest_stage_for_deep_blobs(tr2)
    assert stage2 <= start2  # staged before (or as) the first run starts


def test_depth3_still_correct():
    raw1, _, _ = _run(depth=1)
    raw3, tr3, _ = _run(depth=3)
    assert raw1 == raw3
    assert verify_invariants(tr3.events) == []
