"""Fixpoint runtime: multi-node execution engine for Fix programs."""
from .cluster import Cluster, Future, Link, Network
from .node import Node, WorkItem
from .transfers import LocationIndex, TransferManager, TransferPlan

__all__ = ["Cluster", "Future", "Link", "Network", "Node", "WorkItem",
           "LocationIndex", "TransferManager", "TransferPlan"]
