"""The real multi-process backend: protocol, object stores, fix.remote().

Everything the simulated cluster asserts semantically, asserted again
across an actual process boundary: byte-identical content keys, storage-
routed data movement, PR-4-schema traces that pass the invariant checker,
and typed errors (never hangs) when a worker process dies.
"""
import os
import socket
import time

import pytest

import repro.fix as fix
from repro.core import Repository
from repro.core.handle import TREE
from repro.core.stdlib import add, checksum_tree, fib, identity, inc_chain
from repro.fix.future import DeadlineExceeded
from repro.remote import (
    FileStore,
    MemoryStore,
    RemoteBackend,
    StoreError,
    WorkerCrashed,
)
from repro.remote.protocol import (
    ProtocolError,
    pack,
    recv_msg,
    send_msg,
    unpack,
)
from repro.remote.storage import encode_tree_payload, payload_nbytes
from repro.runtime import TraceRecorder, verify_invariants

pytestmark = pytest.mark.usefixtures("no_thread_leaks")


# A codelet that blocks long enough to kill its worker mid-flight.  Defined
# at module import so it is registered before fix.remote() forks workers.
@fix.codelet
def stall(ms: int) -> int:
    time.sleep(ms / 1000.0)
    return ms


@fix.codelet
def crash_div(a: int, b: int) -> int:
    return a // b


# ---------------------------------------------------------------- protocol
class TestProtocol:
    def test_roundtrip_values(self):
        samples = [
            None, True, False, 0, -1, 2**40, b"", b"\x00\xffpayload",
            "unicode ☃", [1, [2, b"x"], "y"],
            {"op": "submit", "needs": [b"a", b"b"], "n": 3},
        ]
        for v in samples:
            assert unpack(pack(v)) == v

    def test_unpack_rejects_trailing_garbage(self):
        with pytest.raises(ProtocolError):
            unpack(pack(1) + b"x")

    def test_unpack_rejects_bad_tag(self):
        with pytest.raises(ProtocolError):
            unpack(b"Z")

    def test_socket_framing(self):
        a, b = socket.socketpair()
        try:
            msg = {"op": "fetch", "key": b"k" * 24, "deep": [1, 2, 3]}
            send_msg(a, msg)
            assert recv_msg(b) == msg
            a.close()
            assert recv_msg(b) is None  # clean EOF at a frame boundary
        finally:
            for s in (a, b):
                try:
                    s.close()
                except OSError:
                    pass

    def test_midframe_eof_is_an_error(self):
        a, b = socket.socketpair()
        try:
            a.sendall((1 << 20).to_bytes(4, "big") + b"partial")
            a.close()
            with pytest.raises(ProtocolError):
                recv_msg(b)
        finally:
            b.close()


# ------------------------------------------------------------------ stores
class TestStores:
    @staticmethod
    def _canonical(repo, h):
        """Canonical store payload: blob bytes, or a tree's concatenated
        child raws (what the backend itself ships over the wire)."""
        if h.content_type == TREE:
            return encode_tree_payload(repo.get_tree(h))
        return repo.get_blob(h)

    def _exercise(self, store):
        repo = Repository("t")
        blob = repo.put_blob(b"remote-store-payload" * 100)
        tree = repo.put_tree([blob, repo.put_blob(b"x" * 64)])
        for h in (blob, tree):
            payload = self._canonical(repo, h)
            assert store.put(h, payload, src="client")      # fresh
            assert not store.put(h, payload, src="client")  # dup
            assert store.contains(h)
            assert store.get(h) == payload
        missing = repo.put_blob(b"n" * 77)  # resident in repo, not in store
        assert not store.contains(missing)
        assert store.get(missing) is None
        st = store.stats()
        assert st["objects"] == 2 and st["bytes"] == (
            payload_nbytes(blob) + payload_nbytes(tree))

    def test_memory_store(self):
        self._exercise(MemoryStore())

    def test_file_store(self, tmp_path):
        self._exercise(FileStore(tmp_path))

    def test_file_store_persistence(self, tmp_path):
        repo = Repository("t")
        h = repo.put_blob(b"durable" * 50)
        FileStore(tmp_path).put(h, repo.raw_payload(h))
        reopened = FileStore(tmp_path)  # a new instance over the same root
        assert reopened.contains(h)
        assert reopened.get(h) == repo.raw_payload(h)

    def test_put_verifies_payload(self):
        repo = Repository("t")
        h = repo.put_blob(b"honest bytes" * 10)
        with pytest.raises(StoreError):
            MemoryStore().put(h, b"forged bytes!" * 10)

    def test_literals_never_stored(self):
        h = Repository("t").put_blob(b"tiny")
        store = MemoryStore()
        assert not store.put(h, b"tiny")
        assert store.stats()["objects"] == 0

    def test_put_listener_fires_on_fresh_only(self):
        repo = Repository("t")
        h = repo.put_blob(b"listened" * 20)
        store = MemoryStore()
        seen = []
        store.add_put_listener(lambda hh, n, src: seen.append((hh.raw, n, src)))
        store.put(h, repo.raw_payload(h), src="w0")
        store.put(h, repo.raw_payload(h), src="w1")
        assert seen == [(h.raw, payload_nbytes(h), "w0")]


# ------------------------------------------------------------- the backend
class TestRemoteBackend:
    def test_quick_results(self):
        with fix.remote(n_workers=2) as be:
            assert be.run(add(40, 2)) == 42
            assert be.run(fib(10)) == 55
            assert be.run(inc_chain(0, 7)) == 7  # tail-call chain

    def test_matches_local_content_keys(self):
        progs = [add(40, 2), fib(9), inc_chain(3, 4)]
        with fix.local() as lb:
            want = [lb.evaluate(p).raw for p in progs]
        with fix.remote(n_workers=2) as be:
            got = [be.evaluate(p).raw for p in progs]
        assert got == want

    def test_memo_hit_no_second_run(self):
        with fix.remote(n_workers=2) as be:
            h1 = be.evaluate(fib(8))
            h2 = be.evaluate(fib(8))
            assert h1.raw == h2.raw

    def test_selection_and_handle_passthrough(self):
        with fix.remote(n_workers=2) as be:
            tree = be.repo.put_tree(
                [be.repo.put_blob(bytes([i]) * 40) for i in range(4)])
            assert be.run(fix.lit(identity(tree))[2],
                          timeout=60) == bytes([2]) * 40

    def test_error_propagates_typed(self):
        # the evaluator wraps codelet exceptions in FixError on every
        # backend; remote must rebuild the same type, not hang or bury it
        from repro.core import FixError
        with fix.local() as lb:
            with pytest.raises(FixError):
                lb.run(crash_div(1, 0), timeout=60)
        with fix.remote(n_workers=2) as be:
            with pytest.raises(FixError, match="ZeroDivision"):
                be.run(crash_div(1, 0), timeout=60)
            assert be.run(crash_div(6, 3), timeout=60) == 2  # backend survives

    def test_ping(self):
        with fix.remote(n_workers=2) as be:
            assert be.ping() == {"w0": True, "w1": True}

    def test_deadline(self):
        with fix.remote(n_workers=1) as be:
            with pytest.raises(DeadlineExceeded):
                be.submit(stall(5000), deadline_s=0.2).result(timeout=30)

    def test_file_store_backend(self, tmp_path):
        with fix.remote(n_workers=2, store="file", store_dir=tmp_path) as be:
            assert be.run(fib(9)) == 34
            assert be.stats()["store"]["objects"] > 0
        # the store outlives the backend: a fresh run reuses nothing but
        # proves the on-disk objects still verify
        fs = FileStore(tmp_path)
        assert fs.stats()["objects"] > 0

    def test_all_movement_is_store_routed_and_trace_verifies(self, tmp_path):
        path = tmp_path / "remote_trace.jsonl"
        tr = TraceRecorder()
        with fix.local() as lb:
            ltree = lb.repo.put_tree(
                [lb.repo.put_blob(bytes([i]) * 4096) for i in range(5)])
            want = lb.run(checksum_tree(ltree))
        with RemoteBackend(n_workers=2, trace=tr) as be:
            tree = be.repo.put_tree(
                [be.repo.put_blob(bytes([i]) * 4096) for i in range(5)])
            assert be.run(checksum_tree(tree), timeout=120) == want
            assert be.run(fib(9)) == 34
        tr.save(path)
        assert verify_invariants(tr.events) == []
        moves = [e for e in tr.events if e.kind == "transfer_deliver"]
        assert moves, "expected store-routed transfers"
        # the store is always one endpoint: never worker-to-worker ad hoc
        for e in moves:
            assert "store" in (e.fields["src"], e.fields["dst"])
        assert any(e.kind == "job_finish" for e in tr.events)
        # the saved JSONL round-trips through the PR-4 loader/checker
        from repro.runtime.trace import load_trace
        assert verify_invariants(load_trace(path)) == []

    def test_worker_crash_is_typed_not_a_hang(self):
        # max_respawns=0 restores fail-fast: with recovery on (the
        # default) a killed worker is replaced and the job resubmitted —
        # that path is pinned in tests/test_remote_chaos.py
        with fix.remote(n_workers=2, max_respawns=0) as be:
            fut = be.submit(stall(60000))
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                if any(w.outstanding for w in be._workers.values()):
                    break
                time.sleep(0.01)
            for w in be._workers.values():
                w.proc.kill()
            with pytest.raises(WorkerCrashed):
                fut.result(timeout=30)
            # with every worker dead, new submissions fail fast too
            with pytest.raises(WorkerCrashed):
                be.submit(add(1, 2)).result(timeout=30)

    def test_worker_logs_exist(self):
        with fix.remote(n_workers=2) as be:
            be.run(add(1, 2))
            logs = [w.log_path for w in be._workers.values()]
        assert all(os.path.exists(p) for p in logs)


# ------------------------------------------------------ streaming the tree
def test_remote_fetch_stream_children_arrive_incrementally():
    with fix.remote(n_workers=2) as be:
        tree = be.repo.put_tree(
            [be.repo.put_blob(bytes([i]) * 512) for i in range(4)])
        out = list(be.fetch_stream(fix.lit(identity(tree)), timeout=60))
        assert out == [bytes([i]) * 512 for i in range(4)]
