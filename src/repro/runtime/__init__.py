"""Fixpoint runtime: multi-node execution engine for Fix programs."""
from .cluster import Cluster, Future, Link, Network
from .node import Node, WorkItem

__all__ = ["Cluster", "Future", "Link", "Network", "Node", "WorkItem"]
