"""DeepSeek-67B [arXiv:2401.02954]: llama-arch 95L d8192 64H GQA(kv=8)
ff22016 v102400."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense", n_layers=95, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=22016, vocab=102400, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke", family="dense", n_layers=3, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=320, vocab=512,
)

# dry-run step configuration for the full-scale cells
DRYRUN = dict(microbatches=8, remat="dots")
