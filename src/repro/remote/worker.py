"""The remote worker process: one evaluator behind two sockets.

A worker is the paper's compute container made literal: a separate OS
process holding a private :class:`~repro.core.repository.Repository` and
:class:`~repro.core.evaluator.Evaluator`, connected to the platform by

* a **control socket** — the coordinator dispatches ``submit`` steps
  (one ``think`` reduction or one ``strictify``) with the memo pairs and
  the pre-computed list of content the step needs; the worker answers
  ``ran`` / ``error``.
* a **heartbeat socket** — ``heartbeat`` → ``pong``, answered by a
  dedicated responder thread so liveness is observable *while a codelet
  runs*: the coordinator's monitor can tell "busy" (pongs flow, reply
  pending) from "gone" (pongs stop) without interrupting compute.  The
  control loop still answers heartbeats between steps for compatibility.
* a **store socket** — the *only* data path.  Before running, the worker
  pre-stages every needed handle from the object store (externalized I/O:
  all movement happens before compute starts); after running, it pushes
  every byte it created back to the store before replying, so the
  coordinator never learns a result whose content isn't platform-owned.
  There is no worker→worker channel at all.

The repository is additionally wired with a *backing-store* fallback
(:meth:`Repository.set_backing`): if a run touches content the need
analysis missed, the read faults through to the store instead of dying —
recorded in the reply's ``fetched`` list like the pre-staged content, so
the coordinator's residency/trace accounting stays exact.

Workers are forked from the backend process, so in-process codelet
registrations (tests register codelets at import time) are inherited —
matching how a real deployment ships the codelet bundle to containers.
The worker is single-threaded by design: one slot per process, parallelism
comes from the number of processes.
"""
from __future__ import annotations

import os
import sys
import threading
import traceback

from ..core.evaluator import Evaluator
from ..core.handle import BLOB, Handle
from ..core.repository import MissingData, Repository
from .protocol import ProtocolError, recv_msg, send_msg
from .storage import (
    StoreClient,
    decode_tree_payload,
    encode_tree_payload,
    payload_nbytes,
)


class _WorkerState:
    """Capture bookkeeping for one dispatch: which content was fetched from
    the store and which was freshly created by the run."""

    def __init__(self, repo: Repository, store: StoreClient):
        self.repo = repo
        self.store = store
        self.loading = False          # True while installing store fetches
        self.fetched: list[Handle] = []
        self.created: list[Handle] = []
        repo.add_put_listener(self._on_put)
        repo.set_backing(self._backing_fetch)

    def _on_put(self, handle: Handle) -> None:
        if not self.loading:
            self.created.append(handle)

    def _backing_fetch(self, handle: Handle):
        """Repository read fault → store fetch (the safety net).

        The backing contract: install the content (so later reads hit) and
        return the data, or None when the store doesn't have it either.
        """
        payload = self.store.fetch(handle)
        if payload is None:
            return None
        data = (payload if handle.content_type == BLOB
                else decode_tree_payload(payload))
        self.loading = True
        try:
            if not self.repo.put_handle_data(handle, data):
                return None  # corrupt delivery: treat as missing
        finally:
            self.loading = False
        self.fetched.append(handle)
        return data

    def reset(self) -> None:
        self.fetched = []
        self.created = []

    def ensure(self, handle: Handle) -> None:
        """Pre-stage one handle's own content from the store."""
        if handle.is_literal or self.repo.contains(handle):
            return
        payload = self.store.fetch(handle)
        if payload is None:
            raise MissingData(handle)
        data = (payload if handle.content_type == BLOB
                else decode_tree_payload(payload))
        self.loading = True
        try:
            if not self.repo.put_handle_data(handle, data):
                raise MissingData(handle)  # corrupt delivery: rejected
        finally:
            self.loading = False
        self.fetched.append(handle)

    def push_created(self) -> None:
        """Everything the run created goes to the store before we reply."""
        for h in self.created:
            if h.is_literal:
                continue
            if h.content_type == BLOB:
                payload = self.repo.get_blob(h)
            else:
                payload = encode_tree_payload(self.repo.get_tree(h))
            self.store.put(h, payload)


def _handle_list(handles: list) -> list:
    return [[h.raw, payload_nbytes(h)] for h in handles]


def _profile_delta(evaluator: Evaluator, reported: dict) -> list:
    """Per-codelet wall accounting accrued since the last reply, as
    ``[name, count, total_ns]`` triples — integer nanoseconds because the
    wire codec has no float tag.  ``reported`` is mutated to the new
    high-water marks, so each triple is shipped exactly once and the
    coordinator's fold cannot double-count a codelet across steps."""
    out = []
    for name, ent in evaluator.codelets.items():
        seen = reported.get(name)
        dc = ent[0] - (seen[0] if seen else 0)
        dns = ent[1] - (seen[1] if seen else 0)
        if dc > 0 or dns > 0:
            out.append([name, dc, dns])
        reported[name] = [ent[0], ent[1]]
    return sorted(out)


def _heartbeat_loop(hb_sock, jobs_box: list = None) -> None:
    """Sidecar liveness responder: answer every ping until the channel
    dies.  Runs on its own thread so a long codelet on the main thread
    never makes the process look dead (the GIL still schedules us).
    Pongs carry the steps-completed count (``jobs``) so the monitor gets
    a cheap progress signal with every liveness probe."""
    try:
        while True:
            msg = recv_msg(hb_sock)
            if msg is None:
                return
            if msg.get("op") == "heartbeat":
                send_msg(hb_sock, {"op": "pong", "nonce": msg.get("nonce"),
                                   "jobs": jobs_box[0] if jobs_box else 0})
    except (OSError, ProtocolError):
        return


def worker_main(ctl_sock, store_sock, worker_id: str,
                log_path: str = None, hb_sock=None) -> None:
    """Entry point of the forked worker process.  Never returns normally —
    exits the process via ``os._exit`` so inherited atexit handlers (test
    runners, coverage hooks) don't run twice."""
    code = 0
    try:
        if log_path:
            log_fd = os.open(log_path, os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                             0o644)
            os.dup2(log_fd, 1)
            os.dup2(log_fd, 2)
            os.close(log_fd)
            # rebind the Python-level streams too: the parent may have
            # replaced sys.stdout with an object that doesn't write to
            # fd 1 at all (pytest capture does), and the log must not
            # depend on who forked us
            sys.stdout = open(1, "w", buffering=1, closefd=False)
            sys.stderr = open(2, "w", buffering=1, closefd=False)
        sys.stdin = open(os.devnull)
        print(f"[{worker_id}] up, pid={os.getpid()}", flush=True)
        jobs_box = [0]  # steps completed; shared with the hb responder
        if hb_sock is not None:
            threading.Thread(target=_heartbeat_loop,
                             args=(hb_sock, jobs_box),
                             daemon=True, name="fix-worker-hb").start()
        _serve(ctl_sock, store_sock, worker_id, jobs_box)
        print(f"[{worker_id}] clean shutdown", flush=True)
    except BaseException:
        traceback.print_exc()
        print(f"[{worker_id}] dying", flush=True)
        code = 1
    finally:
        sys.stdout.flush()
        sys.stderr.flush()
        os._exit(code)


def _serve(ctl_sock, store_sock, worker_id: str,
           jobs_box: list = None) -> None:
    repo = Repository(worker_id)
    evaluator = Evaluator(repo)
    state = _WorkerState(repo, StoreClient(store_sock))
    reported: dict = {}  # per-codelet high-water marks already shipped
    jobs_box = jobs_box if jobs_box is not None else [0]
    while True:
        msg = recv_msg(ctl_sock)
        if msg is None:
            return  # coordinator vanished
        op = msg.get("op")
        if op == "shutdown":
            return
        if op == "heartbeat":
            send_msg(ctl_sock, {"op": "pong", "nonce": msg.get("nonce"),
                                "jobs": jobs_box[0]})
            continue
        if op == "submit":
            reply = _run_submit(evaluator, state, msg, worker_id)
            reply["profile"] = _profile_delta(evaluator, reported)
            jobs_box[0] += 1
            send_msg(ctl_sock, reply)
            continue
        if op == "push":
            # quarantine recovery: re-publish content this worker holds
            # (fire-and-forget — a dup put is a no-op, and the coordinator
            # watches the store's put notifications, not a reply)
            for raw in msg.get("raws", ()):
                h = Handle(bytes(raw))
                try:
                    if h.content_type == BLOB:
                        payload = repo.get_blob(h)
                    else:
                        payload = encode_tree_payload(repo.get_tree(h))
                    state.store.put(h, payload)
                    print(f"[{worker_id}] pushed {h!r} back to store",
                          flush=True)
                except MissingData:
                    print(f"[{worker_id}] push miss: {h!r} not held",
                          flush=True)
            continue
        raise ProtocolError(f"unknown op {op!r}")


def _run_submit(evaluator: Evaluator, state: _WorkerState, msg: dict,
                worker_id: str) -> dict:
    """One dispatched step: install memos, pre-stage, run, push, reply."""
    repo = state.repo
    state.reset()
    job, epoch, kind = msg["job"], msg["epoch"], msg["kind"]
    try:
        for enc_raw, res_raw in msg.get("memos", ()):
            enc, res = Handle(enc_raw), Handle(res_raw)
            repo.memo_put(enc, res)
            repo.memo_put(enc.unwrap_encode(), res)
        for raw in msg.get("needs", ()):
            state.ensure(Handle(raw))
        target = Handle(msg["target"])
        print(f"[{worker_id}] job={job} epoch={epoch} {kind} "
              f"{target!r}", flush=True)
        if kind == "think":
            result = evaluator.think(target)
        elif kind == "strictify":
            result = evaluator.strictify(target)
        else:
            raise ProtocolError(f"unknown submit kind {kind!r}")
        state.push_created()
        return {"op": "ran", "job": job, "epoch": epoch, "result": result.raw,
                "fetched": _handle_list(state.fetched),
                "created": _handle_list(state.created)}
    except BaseException as e:  # noqa: BLE001 — every failure becomes a typed reply
        print(f"[{worker_id}] job={job} failed: {type(e).__name__}: {e}",
              flush=True)
        traceback.print_exc()
        try:
            state.push_created()  # partial content is still valid content
        except Exception:  # noqa: BLE001
            pass
        return {"op": "error", "job": job, "epoch": epoch,
                "etype": type(e).__name__, "emsg": str(e),
                "fetched": _handle_list(state.fetched),
                "created": _handle_list(state.created)}
