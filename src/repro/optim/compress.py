"""Error-feedback int8 gradient compression for the cross-pod (DCN) axis.

The inter-pod link is the slowest in the hierarchy; Fix's "describe the
bytes, let the platform move fewer of them" view motivates quantizing the
cross-pod gradient all-reduce to int8 with per-tensor scales and an error-
feedback accumulator (the quantization residual is re-injected next step,
so the method is unbiased in the long run — standard EF-SGD analysis).

Used inside shard_map over the "pod" axis: gradients arrive pod-local,
leave pod-synced, having moved 4x fewer bytes over DCN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_int8_allreduce(g, err, axis_name: str, n_pods: int):
    """Quantize (g + err) to int8, psum over pods, dequantize.

    Returns (synced mean gradient, new error residual).
    """
    g32 = g.astype(jnp.float32) + err.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    # agree on one scale per tensor (scalar pmax — negligible bytes), so the
    # integer sum dequantizes exactly
    amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    # int8 payload over the wire; accumulate in i32 (pods <= 2^23 safe)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    g_sync = q_sum.astype(jnp.float32) * scale / n_pods
    return g_sync.astype(g.dtype), new_err.astype(err.dtype)


def ef_state_specs(param_specs):
    from ..models.base import ParamSpec, ps, tree_map_specs

    return tree_map_specs(
        lambda _p, s: ps(s.shape, s.axes, init="zeros", dtype=jnp.bfloat16),
        param_specs,
    )
