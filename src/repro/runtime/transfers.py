"""Batched, pipelined network transfers + the scheduler's location index.

The seed runtime shipped every missing handle as its own thread-per-handle
transfer: each one paid link latency, took the source NIC lock, slept for
its own (often microscopic) serialization share, and posted its own
scheduler event.  For a job staging K inputs that is K thread spawns,
K latency charges and K events — the per-transfer *fixed* costs dominate
and the scheduler re-walks the object graph to find a source for every
handle.

This module externalizes that work into a proper subsystem (paper §4.2:
the platform owns network I/O, so it can schedule it):

* :class:`TransferPlan` — all handles a job (or prefetch pass) needs moved
  across one (src → dst) link, coalesced into a single wire transfer that
  pays link latency **once** and serializes bandwidth for the summed
  payload.
* :class:`TransferManager` — a small pool of *persistent* per-link worker
  threads executing plans.  Serialization holds the source NIC; propagation
  latency is handed to the clock's timer so consecutive plans on a link
  pipeline (plan N+1 serializes while plan N is in flight).
  ``mode="per_handle"`` reproduces the seed's thread-per-handle behaviour
  for A/B benchmarking (see ``benchmarks --fig staging``).
* **Backlog accounting** — the manager tracks outstanding serialization
  bytes per source NIC and queued plans per link, read (lock-free-ish,
  under a small mutex) by the scheduler's *seconds-to-stage* placement
  model: a far node with an idle fat pipe beats a near congested one.
* :class:`LocationIndex` — content key → node ids, maintained from
  repository put notifications and transfer deliveries, so source lookup
  and locality placement are O(needs) instead of O(nodes × graph walk).

All waiting — link worker queues, NIC locks, serialization sleeps,
delivery timers — goes through the cluster's :class:`~repro.runtime.clock.
Clock`, so the same code runs in real time (``WallClock``) or simulated
time (``VirtualClock``, deterministic and near-instant).

Cross-job dedup (two jobs staging the same blob to the same node share one
wire transfer) lives in the scheduler's in-flight table; this module only
ever sees already-deduplicated batches.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Optional

from ..core import Handle
from .clock import Clock, WallClock
from .faults import corrupt_payload


# ----------------------------------------------------------- location index
class LocationIndex:
    """Which nodes hold which content (content key → node ids).

    Entries are *hints*: data can vanish under us (node failure, explicit
    eviction), so readers must verify residency with the node's repository
    before trusting a hit.  Writers are repository put listeners (worker
    and transfer threads) plus the scheduler, hence the lock.  Node ids are
    kept in insertion order (dict keys, not a set) so iteration — and with
    it source choice and placement — is deterministic across runs.
    """

    def __init__(self):
        self._locs: dict[bytes, dict[str, None]] = {}
        self._lock = threading.Lock()

    def add(self, key: bytes, node_id: str) -> None:
        with self._lock:
            self._locs.setdefault(key, {})[node_id] = None

    def discard(self, key: bytes, node_id: str) -> None:
        """Forget one (key, node) pair — e.g. a replica that failed
        verification and was quarantined."""
        with self._lock:
            nodes = self._locs.get(key)
            if nodes is not None:
                nodes.pop(node_id, None)
                if not nodes:
                    del self._locs[key]

    def drop_node(self, node_id: str) -> None:
        """A node died (fail-stop): forget everything it held."""
        with self._lock:
            empty = []
            for key, nodes in self._locs.items():
                nodes.pop(node_id, None)
                if not nodes:
                    empty.append(key)
            for key in empty:
                del self._locs[key]

    def nodes_for(self, key: bytes) -> tuple[str, ...]:
        with self._lock:
            nodes = self._locs.get(key)
            return tuple(nodes) if nodes else ()

    def __len__(self) -> int:
        with self._lock:
            return len(self._locs)


# ------------------------------------------------------------ transfer plan
@dataclass
class TransferPlan:
    """One coalesced wire transfer: every handle moving src → dst together.

    Payloads are captured eagerly (on the scheduler thread, while the
    source is known to hold them) so a source failing mid-flight cannot
    corrupt the batch — mirroring the seed's eager ``raw_payload`` grab.
    """

    src: str
    dst: str
    items: list = field(default_factory=list)  # (Handle, payload, size)
    span: Optional[int] = None  # open telemetry span id (spans on only)

    @property
    def total_bytes(self) -> int:
        return sum(size for _, _, size in self.items)

    @property
    def raws(self) -> tuple[bytes, ...]:
        return tuple(h.raw for h, _, _ in self.items)


# ----------------------------------------------------- one-handle transfer
def single_transfer(clock: Clock, network, nodes: dict, src_id: str,
                    dst_id: str, h: Handle, payload, size: int,
                    trace=None, via: str = "per_handle",
                    faults=None) -> str:
    """Move ONE handle src → dst, paying link latency then the NIC-locked
    serialization share — the seed's per-handle wire model, shared by the
    cluster's internal-I/O blocking fetch (``via="blocking"``) and the
    ``per_handle`` transfer mode (previously two copies of the same sleep
    choreography).

    Returns a status string: ``"ok"`` (delivered and verified),
    ``"dst_dead"`` (destination died before install — bytes still burned,
    that is the point of the fail-stop model), or under fault injection
    ``"src_crash"`` / ``"link_down"`` / ``"dropped"`` / ``"corrupt"``.
    """
    link = network.link(src_id, dst_id)
    ser_s = link.serialized_s(size)
    if faults is not None:
        ser_s *= faults.bandwidth_factor(src_id, dst_id)
    clock.sleep(link.latency_s)
    src_node = nodes.get(src_id)
    if src_node is not None:
        with src_node.nic_lock:  # serialize on the source NIC
            if trace is not None:
                trace.emit("link_acquire", src=src_id, dst=dst_id,
                           nbytes=size, ser_s=ser_s, via=via)
            clock.sleep(ser_s)
    else:
        if trace is not None:
            trace.emit("link_acquire", src=src_id, dst=dst_id,
                       nbytes=size, ser_s=ser_s, via=via)
        clock.sleep(ser_s)
    dst = nodes.get(dst_id)
    if dst is None or not dst.alive:
        if trace is not None:
            trace.emit("transfer_deliver", src=src_id, dst=dst_id, n=1,
                       nbytes=size, keys=[h.content_key().hex()], ok=False,
                       via=via)
        return "dst_dead"
    status = "ok"
    if faults is not None:
        if src_node is not None and not src_node.alive:
            status = "src_crash"
        elif faults.link_down(src_id, dst_id):
            status = "link_down"
        elif faults.take_drop(src_id, dst_id):
            status = "dropped"
    if status != "ok":
        if trace is not None:
            trace.emit("transfer_drop", src=src_id, dst=dst_id, n=1,
                       nbytes=size, keys=[h.content_key().hex()],
                       reason=status, via=via)
        return status
    if faults is not None and faults.take_corrupt(src_id, dst_id):
        payload = corrupt_payload(h, payload)
    if not dst.repo.put_handle_data(h, payload):
        if trace is not None:
            trace.emit("corruption_detected", src=src_id, dst=dst_id,
                       key=h.content_key().hex(), via=via)
        return "corrupt"
    if trace is not None:
        trace.emit("transfer_deliver", src=src_id, dst=dst_id, n=1,
                   nbytes=size, keys=[h.content_key().hex()], ok=True,
                   via=via)
    return "ok"


# -------------------------------------------------------------- link worker
class _LinkWorker:
    """Persistent worker serializing plans over one (src → dst) link."""

    def __init__(self, manager: "TransferManager", src: str, dst: str):
        self.manager = manager
        self.src = src
        self.dst = dst
        self.q = manager.clock.make_queue()
        self._thread = manager.clock.spawn(self._run,
                                           name=f"fix-xfer-{src}-{dst}")

    def stop(self) -> None:
        self.q.put(None)

    def _run(self) -> None:
        mgr = self.manager
        clock = mgr.clock
        while True:
            plan = self.q.get()
            if plan is None:
                return
            link = mgr.network.link(plan.src, plan.dst)
            src_node = mgr.nodes.get(plan.src)
            nbytes = plan.total_bytes
            ser_s = link.serialized_s(nbytes)
            if mgr.faults is not None:  # degraded link: slower serialization
                ser_s *= mgr.faults.bandwidth_factor(plan.src, plan.dst)
            tr = mgr.trace
            if src_node is not None:
                with src_node.nic_lock:  # the source NIC serializes the
                    if tr is not None:   # summed payload once
                        tr.emit("link_acquire", src=plan.src, dst=plan.dst,
                                nbytes=nbytes, ser_s=ser_s, via="batched")
                    clock.sleep(ser_s)
            else:
                if tr is not None:
                    tr.emit("link_acquire", src=plan.src, dst=plan.dst,
                            nbytes=nbytes, ser_s=ser_s, via="batched")
                clock.sleep(ser_s)
            mgr._serialized(plan.src, nbytes)
            clock.call_at(clock.now() + link.latency_s,
                          lambda p=plan: mgr._deliver(p))


# ---------------------------------------------------------- transfer manager
class TransferManager:
    """Executes :class:`TransferPlan`s with per-link persistent workers.

    ``submit`` is called from the scheduler thread only; completions are
    posted back as ``("transfer_done", dst_id, raws)`` events.  ``account``
    is invoked synchronously on submit with (transfer_count, bytes) so the
    cluster's public counters stay scheduler-thread-owned.
    """

    def __init__(self, network, nodes: dict, post_event: Callable,
                 account: Optional[Callable] = None, mode: str = "batched",
                 clock: Optional[Clock] = None, trace=None, faults=None,
                 metrics=None, spans=None):
        if mode not in ("batched", "per_handle"):
            raise ValueError(f"unknown transfer mode {mode!r}")
        self.network = network
        self.nodes = nodes
        self.mode = mode
        self.clock = clock if clock is not None else WallClock()
        self.trace = trace
        self.faults = faults  # FaultState shared with the scheduler, or None
        self.metrics = metrics  # MetricsRegistry (None = metrics off)
        self.spans = spans      # SpanEmitter (None = spans off)
        # instrument-handle caches (label rendering off the hot path)
        self._g_src: dict = {}
        self._g_link: dict = {}
        self._c_deliver: dict = {}
        self._m_plans = (metrics.counter("transfer_plans_total", mode=mode)
                         if metrics is not None else None)
        self._post = post_event
        self._account = account or (lambda n, b: None)
        self._workers: dict[tuple[str, str], _LinkWorker] = {}
        self._adhoc: list = []  # per_handle threads, joined on stop()
        # Backlog state for the placement cost model (mutated by the
        # scheduler on submit and by link workers / deliveries; read by
        # placement, hence the mutex).
        self._backlog_lock = threading.Lock()
        self._src_pending: dict[str, int] = {}        # bytes awaiting NIC
        self._link_pending: dict[tuple, int] = {}     # plans in flight
        self._adhoc_pending = 0                       # per_handle in flight

    # --------------------------------------------------------------- backlog
    def src_backlog_bytes(self, src_id: str) -> int:
        """Bytes submitted toward ``src_id``'s NIC not yet serialized — the
        queueing delay a new plan from this source would sit behind."""
        with self._backlog_lock:
            return self._src_pending.get(src_id, 0)

    def link_queue_depth(self, src_id: str, dst_id: str) -> int:
        """Plans submitted on (src → dst) not yet delivered."""
        with self._backlog_lock:
            return self._link_pending.get((src_id, dst_id), 0)

    def backlog_snapshot(self) -> tuple[dict, dict]:
        """One consistent read of (src pending bytes, link pending plans)
        for a whole placement pass — one mutex grab instead of one per
        candidate × handle × replica."""
        with self._backlog_lock:
            return dict(self._src_pending), dict(self._link_pending)

    def _src_gauge(self, src_id: str):
        g = self._g_src.get(src_id)
        if g is None:
            g = self._g_src[src_id] = self.metrics.gauge(
                "src_backlog_bytes", src=src_id)
        return g

    def _link_gauge(self, src_id: str, dst_id: str):
        key = (src_id, dst_id)
        g = self._g_link.get(key)
        if g is None:
            g = self._g_link[key] = self.metrics.gauge(
                "link_queue_depth", link=f"{src_id}->{dst_id}")
        return g

    def _serialized(self, src_id: str, nbytes: int) -> None:
        with self._backlog_lock:
            left = self._src_pending.get(src_id, 0) - nbytes
            self._src_pending[src_id] = max(left, 0)
        if self.metrics is not None:
            self._src_gauge(src_id).set(max(left, 0))

    def pending(self) -> int:
        """Transfers submitted but not yet delivered (plans + per-handle
        items) — the scheduler's shutdown drain waits for this to hit 0 so
        every in-flight transfer's completion event gets processed."""
        with self._backlog_lock:
            return sum(self._link_pending.values()) + self._adhoc_pending

    # ---------------------------------------------------------------- submit
    def submit(self, src_id: str, dst_id: str, items: list,
               span_parent: Optional[int] = None) -> None:
        """Move ``items`` = [(handle, payload, size), ...] src → dst.
        ``span_parent`` (spans on only) links the transfer span under the
        requesting job's stage span."""
        if not items:
            return
        plan = TransferPlan(src_id, dst_id, list(items))
        if self.trace is not None:
            self.trace.emit(
                "transfer_enqueue", src=src_id, dst=dst_id,
                n=len(plan.items), nbytes=plan.total_bytes,
                keys=[h.content_key().hex() for h, _, _ in plan.items],
                mode=self.mode)
        m = self.metrics
        if m is not None:
            self._m_plans.inc()
        if self.mode == "per_handle":
            # Seed behaviour: one thread, one latency charge, one NIC grab
            # and one scheduler event *per handle* — kept for A/B runs.
            # (No transfer spans here: the ablation mode predates the plan
            # object the span rides on.)
            self._account(len(plan.items), plan.total_bytes)
            with self._backlog_lock:
                self._adhoc_pending += len(plan.items)
            self._adhoc = [t for t in self._adhoc if t.is_alive()]
            for h, payload, size in plan.items:
                self._adhoc.append(self.clock.spawn(
                    lambda s=plan.src, d=plan.dst, hh=h, p=payload, z=size:
                        self._per_handle_xfer(s, d, hh, p, z),
                    name=f"fix-xfer1-{plan.src}-{plan.dst}"))
            return
        if self.spans is not None:
            plan.span = self.spans.begin(
                "transfer", parent=span_parent, src=src_id, dst=dst_id,
                n=len(plan.items), nbytes=plan.total_bytes)
        self._account(1, plan.total_bytes)
        key = (src_id, dst_id)
        with self._backlog_lock:
            pending = (self._src_pending.get(src_id, 0) + plan.total_bytes)
            self._src_pending[src_id] = pending
            depth = self._link_pending.get(key, 0) + 1
            self._link_pending[key] = depth
        if m is not None:
            self._src_gauge(src_id).set(pending)
            self._link_gauge(src_id, dst_id).set(depth)
        worker = self._workers.get(key)
        if worker is None:
            worker = self._workers[key] = _LinkWorker(self, src_id, dst_id)
        worker.q.put(plan)

    # -------------------------------------------------------------- delivery
    def _deliver(self, plan: TransferPlan) -> None:
        # ALWAYS post (see finally), even toward a dead node or past a
        # failed install: waiting jobs must unblock (an undelivered handle
        # re-misses and fails the job with the real error) and the
        # scheduler's in-flight table must be reaped.  Fault paths replace
        # the blanket completion with typed transfer_failed posts.
        posts: list = [("transfer_done", plan.dst, plan.raws)]
        status = "ok"
        try:
            dst = self.nodes.get(plan.dst)
            if dst is None or not dst.alive:
                status = "dst_dead"
                # Dead destination: the bytes were burned for nothing.  The
                # unconditional transfer_done below reaps the scheduler's
                # in-flight table; waiting jobs re-place via node failure.
                if self.trace is not None:
                    self.trace.emit(
                        "transfer_deliver", src=plan.src, dst=plan.dst,
                        n=len(plan.items), nbytes=plan.total_bytes,
                        keys=[h.content_key().hex() for h, _, _ in plan.items],
                        ok=False, via="batched")
                return
            drop_reason = self._plan_fault(plan)
            if drop_reason is not None:
                # Whole-plan loss (source crashed mid-flight, link down, or
                # an injected drop): nothing installs; the scheduler retries
                # with backoff and possibly another source.
                if self.trace is not None:
                    self.trace.emit(
                        "transfer_drop", src=plan.src, dst=plan.dst,
                        n=len(plan.items), nbytes=plan.total_bytes,
                        keys=[h.content_key().hex() for h, _, _ in plan.items],
                        reason=drop_reason, via="batched")
                posts = [("transfer_failed", plan.dst, plan.raws,
                          drop_reason, plan.src)]
                status = drop_reason
                return
            corrupt_first = (self.faults is not None
                             and self.faults.take_corrupt(plan.src, plan.dst))
            ok_items, bad_raws = [], []
            for h, payload, size in plan.items:
                if corrupt_first:
                    payload = corrupt_payload(h, payload)
                    corrupt_first = False
                if dst.repo.put_handle_data(h, payload):
                    ok_items.append((h, size))
                else:
                    bad_raws.append(h.raw)
                    if self.trace is not None:
                        self.trace.emit("corruption_detected", src=plan.src,
                                        dst=plan.dst,
                                        key=h.content_key().hex(),
                                        via="batched")
            if ok_items and self.trace is not None:
                self.trace.emit(
                    "transfer_deliver", src=plan.src, dst=plan.dst,
                    n=len(ok_items),
                    nbytes=sum(size for _, size in ok_items),
                    keys=[h.content_key().hex() for h, _ in ok_items],
                    ok=True, via="batched")
            if bad_raws:
                posts = [("transfer_failed", plan.dst, tuple(bad_raws),
                          "corrupt", plan.src)]
                status = "corrupt"
                if ok_items:
                    posts.append(("transfer_done", plan.dst,
                                  tuple(h.raw for h, _ in ok_items)))
        finally:
            with self._backlog_lock:
                key = (plan.src, plan.dst)
                left = self._link_pending.get(key, 0) - 1
                if left > 0:
                    self._link_pending[key] = left
                else:
                    self._link_pending.pop(key, None)
            m = self.metrics
            if m is not None:
                self._link_gauge(plan.src, plan.dst).set(max(left, 0))
                c = self._c_deliver.get(status)
                if c is None:
                    c = self._c_deliver[status] = m.counter(
                        "transfer_delivers_total", status=status)
                c.inc()
            if self.spans is not None:
                self.spans.end(plan.span, status=status)
            for p in posts:
                self._post(p)

    def _plan_fault(self, plan: TransferPlan) -> Optional[str]:
        """Reason this plan is lost at delivery time, or None.  Only active
        under fault injection — no-fault runs keep the eager-capture
        semantics (a source dying mid-flight still delivers)."""
        if self.faults is None:
            return None
        src_node = self.nodes.get(plan.src)
        if src_node is not None and not src_node.alive:
            return "src_crash"
        if self.faults.link_down(plan.src, plan.dst):
            return "link_down"
        if self.faults.take_drop(plan.src, plan.dst):
            return "dropped"
        return None

    def _per_handle_xfer(self, src_id: str, dst_id: str, h: Handle,
                         payload, size: int) -> None:
        status = "dst_dead"  # a crash below still unblocks the waiter
        try:
            status = single_transfer(self.clock, self.network, self.nodes,
                                     src_id, dst_id, h, payload, size,
                                     trace=self.trace, via="per_handle",
                                     faults=self.faults)
        finally:
            with self._backlog_lock:  # decrement BEFORE posting: the post
                self._adhoc_pending -= 1  # is what wakes the drain check
            if status in ("ok", "dst_dead"):
                self._post(("transfer_done", dst_id, (h.raw,)))
            else:
                self._post(("transfer_failed", dst_id, (h.raw,),
                            status, src_id))

    # ------------------------------------------------------------- lifecycle
    def stop(self) -> None:
        for w in self._workers.values():
            w.stop()
        threads = [w._thread for w in self._workers.values()] + self._adhoc
        with self.clock.external_wait():  # workers need the clock to drain
            for t in threads:
                t.join(timeout=5)
        self._adhoc = []
