"""B+-tree key-value store on Fix (paper §5.4, fig 9).

The tree is a nest of Fix Trees; a lookup descends node-by-node with
Selection Thunks — spelled ``fix.lit(node)[i]`` in the frontend — so each
step's minimum repository is ONE node (32 bytes per child handle) + ONE key
array — never the siblings' data.  Compare the "blocking" style (fetch
whole subtree data at every level).

Run:  PYTHONPATH=src python examples/btree_kv.py
"""
import bisect
import time

import repro.fix as fix
from repro.core import Handle, Repository


def build_btree(repo: Repository, keys, values, arity: int):
    """Returns (root handle, depth).  Node = Tree [keys_blob, child...]."""
    leaves = []
    for i in range(0, len(keys), arity):
        ks = keys[i : i + arity]
        vs = values[i : i + arity]
        kb = repo.put_blob(b"\x00".join(ks))
        leaves.append((ks[0], repo.put_tree(
            [kb] + [repo.put_blob(v) for v in vs])))
    depth = 1
    level = leaves
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level), arity):
            grp = level[i : i + arity]
            kb = repo.put_blob(b"\x00".join(g[0] for g in grp))
            nxt.append((grp[0][0], repo.put_tree([kb] + [g[1] for g in grp])))
        level = nxt
        depth += 1
    return level[0][1], depth


def fix_lookup(backend: fix.Backend, root: Handle, key: bytes):
    """Descend with Selections: per level, read ONLY the keys blob; the
    child handles travel as a 32-byte-each tree node."""
    node = root
    steps = 0
    while True:
        kids = backend.repo.get_tree(node)
        keys = backend.repo.get_blob(kids[0]).split(b"\x00")
        idx = max(bisect.bisect_right(keys, key) - 1, 0)
        # shallow: minimum work — the child comes back as a Ref (a name),
        # its data untouched until we actually descend into it
        # (timeout=None: the local backend's synchronous fast path)
        child = backend.evaluate(fix.lit(node)[idx + 1].shallow(), timeout=None)
        steps += 1
        if child.content_type == 0:  # blob leaf => value
            return backend.fetch(child, as_type=bytes), steps
        node = child.as_object()


def main() -> None:
    with fix.local() as be:
        n = 50_000
        keys = [f"key{i:08d}".encode() for i in range(n)]
        values = [f"value-{i}".encode() * 3 for i in range(n)]

        for arity in (16, 64, 256):
            root, depth = build_btree(be.repo, keys, values, arity)
            t0 = time.perf_counter()
            hits = 0
            for i in range(0, n, n // 200):  # 200 random-ish lookups
                val, steps = fix_lookup(be, root, keys[i])
                assert val == values[i]
                hits += 1
            dt = (time.perf_counter() - t0) / hits
            print(f"arity {arity:4d}  depth {depth}  {dt*1e6:8.1f} us/lookup "
                  f"({hits} lookups ok)")

    # The same tree, the same selections, on real worker processes: the
    # lookups descend through the object store instead of a shared heap,
    # and content addressing guarantees the identical answers.
    with fix.remote(n_workers=2) as be:
        n = 2_000
        keys = [f"key{i:08d}".encode() for i in range(n)]
        values = [f"value-{i}".encode() * 3 for i in range(n)]
        root, depth = build_btree(be.repo, keys, values, 64)
        for i in range(0, n, n // 20):
            val, _steps = fix_lookup(be, root, keys[i])
            assert val == values[i]
        print(f"remote: depth-{depth} lookups ok on "
              f"{len(be._workers)} worker processes")


if __name__ == "__main__":
    main()
