"""Serving on the Fix core: correctness of the memoized-prefix path.

What these tests pin, in order of importance:

* **bit-identity** — a prefix-cache hit must never change a token stream.
  The seed engine's ``PrefixCache.insert`` cached one state per *prompt*
  (covering all its tokens), so a lookup matching fewer blocks resumed
  from a state that had already consumed tokens beyond the match; these
  tests serve overlapping prompts in cache-friendly order and compare
  against cache-disabled runs, on the host engine and on every backend;
* **chain invariants** — per-boundary entries, ancestors always present,
  eviction cascades to descendants, dangling inserts refused;
* **accounting** — hits/misses counted per block (the benchmark's
  comparison axis), full hits admit with zero prefill submissions;
* **typed intake errors** — empty/malformed prompts and bad budgets fail
  at ``submit()`` with :class:`RequestError` subtypes, and ``max_new=0``
  completes without emitting a token or occupying a slot;
* **fairness** — stride scheduling converges to the weight ratio and an
  overloaded tenant cannot lock a light one out of the batch;
* **portability** — the same traffic produces identical streams on
  ``fix.local()``, the simulated cluster and real worker processes, with
  per-tenant attribution visible in the simulated trace.
"""
import itertools
import sys
from pathlib import Path

import numpy as np
import pytest

import repro.fix as fix
from repro.runtime import Cluster, TraceRecorder, VirtualClock, verify_invariants
from repro.runtime.trace import percentile, tenant_report
from repro.serving import (
    BudgetError,
    EmptyPromptError,
    FixServeEngine,
    PrefixCache,
    Request,
    ServeEngine,
    TenantQueue,
    make_weights,
    prompt_key,
    toy_fns,
)
from repro.serving.model import lm_prefill_block, token_block_bytes

sys.path.insert(0, str(Path(__file__).resolve().parent))
from workloads import make_serving_requests, make_serving_spec, run_serving  # noqa: E402

pytestmark = pytest.mark.usefixtures("no_thread_leaks")

BLOCK = 4  # small blocks so a handful of tokens spans several boundaries
W = make_weights(seed=7, vocab=64, eos=0)


def _req(rid, prompt, max_new=8, tenant="default"):
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   max_new=max_new, tenant=tenant)


def _host_engine(capacity=64, **kw):
    prefill_fn, decode_fn = toy_fns(W)
    return ServeEngine(prefill_fn, decode_fn, batch=kw.pop("batch", 2),
                       eos=0, prefix_cache=PrefixCache(capacity=capacity),
                       block=BLOCK, **kw)


def _serve(engine, reqs):
    for r in reqs:
        engine.submit(r)
    engine.run()
    return {r.rid: list(r.out_tokens) for r in engine.finished}


# ------------------------------------------------------------- prompt_key
def test_prompt_key_is_chained_prefix_identity():
    a = prompt_key(np.arange(1, 13, dtype=np.int32), BLOCK)
    b = prompt_key(np.arange(1, 9, dtype=np.int32), BLOCK)
    assert a[:2] == b and len(a) == 3
    # diverge inside block 0: every downstream key changes (chained hash)
    c = prompt_key(np.asarray([9, 2, 3, 4, 5, 6, 7, 8], np.int32), BLOCK)
    assert all(x != y for x, y in zip(a, c))
    # a trailing partial block gets its own boundary
    d = prompt_key(np.arange(1, 11, dtype=np.int32), BLOCK)
    assert d[:2] == a[:2] and d[2] != a[2]


# ------------------------------------------------------------ PrefixCache
def test_cache_states_cover_exactly_the_matched_blocks():
    """The seed bug: a cached state must cover its boundary's tokens and
    not one token more — a 2-block match returns the 2-block chain state."""
    cache = PrefixCache(capacity=16)
    prompt = np.arange(1, 13, dtype=np.int32)  # 3 blocks of BLOCK
    keys = prompt_key(prompt, BLOCK)
    state = None
    for j in range(3):
        state = lm_prefill_block(
            W, state or b"", token_block_bytes(prompt[j * BLOCK:(j + 1) * BLOCK]))
        assert cache.insert(keys[:j + 1], state)
    # a prompt sharing only 2 blocks must get the 2-block state
    shorter = np.concatenate([prompt[:8], [50, 51, 52, 53]]).astype(np.int32)
    n, got = cache.lookup(prompt_key(shorter, BLOCK))
    want = lm_prefill_block(
        W, lm_prefill_block(W, b"", token_block_bytes(prompt[:4])),
        token_block_bytes(prompt[4:8]))
    assert n == 2 and got == want


def test_cache_refuses_dangling_insert():
    cache = PrefixCache(capacity=16)
    keys = prompt_key(np.arange(1, 9, dtype=np.int32), BLOCK)
    assert not cache.insert(keys, b"s2")       # ancestor keys[0] missing
    assert len(cache) == 0
    assert cache.insert(keys[:1], b"s1")
    assert cache.insert(keys, b"s2")


def test_cache_eviction_cascades_to_descendants():
    cache = PrefixCache(capacity=3)
    a = np.arange(1, 13, dtype=np.int32)
    ka = prompt_key(a, BLOCK)
    for j in range(3):
        cache.insert(ka[:j + 1], f"a{j}".encode())
    kb = prompt_key(np.arange(20, 24, dtype=np.int32), BLOCK)
    cache.insert(kb, b"b0")      # evicts LRU a0 -> cascade drops a1, a2
    assert len(cache) == 1 and kb[0] in cache
    assert cache.evictions == 3
    n, state = cache.lookup(ka)
    assert (n, state) == (0, None)
    # invariant: every surviving entry still has all its ancestors
    for key in list(cache._lru):
        assert all(k in cache for k in cache.chain_of(key))


def test_cache_counts_hits_and_misses_per_block():
    cache = PrefixCache(capacity=16)
    prompt = np.arange(1, 21, dtype=np.int32)  # 5 blocks
    keys = prompt_key(prompt, BLOCK)
    state = b""
    for j in range(3):
        state = lm_prefill_block(
            W, state, token_block_bytes(prompt[j * BLOCK:(j + 1) * BLOCK]))
        cache.insert(keys[:j + 1], state)
    n, _ = cache.lookup(keys)
    assert n == 3
    assert (cache.hits, cache.misses) == (3, 2)  # 3 covered + 2 to prefill


def test_lookup_refreshes_whole_chain_to_mru():
    """Touching a deep boundary must also refresh its ancestors, else an
    eviction of the cold-looking root cascades the hot chain away."""
    cache = PrefixCache(capacity=4)
    a = prompt_key(np.arange(1, 13, dtype=np.int32), BLOCK)
    for j in range(3):
        cache.insert(a[:j + 1], b"x")
    cold = prompt_key(np.arange(20, 24, dtype=np.int32), BLOCK)
    cache.insert(cold, b"cold")
    cache.lookup(a)  # refresh: the whole a-chain outranks `cold` now
    cache.insert(prompt_key(np.arange(30, 34, dtype=np.int32), BLOCK), b"y")
    assert cold[0] not in cache          # the cold entry paid, not the chain
    n, _ = cache.lookup(a)
    assert n == 3
    for key in list(cache._lru):
        assert all(k in cache for k in cache.chain_of(key))


# ------------------------------------------------------- host ServeEngine
def test_cached_streams_bit_identical_to_uncached():
    """Overlapping prompts served in cache-friendly order: the long prompt
    warms the cache, the shorter-prefix prompt hits it — streams must match
    a cache-disabled engine token for token."""
    long_p = list(range(1, 13))
    reqs = [(0, long_p), (1, long_p[:8] + [50, 51]), (2, long_p[:4] + [60]),
            (3, long_p)]
    warm = _serve(_host_engine(capacity=64, batch=1),
                  [_req(r, p, max_new=6) for r, p in reqs])
    cold = _serve(_host_engine(capacity=0, batch=1),
                  [_req(r, p, max_new=6) for r, p in reqs])
    assert warm == cold


def test_admit_prefills_only_the_uncovered_tail():
    prefill_fn, decode_fn = toy_fns(W)
    calls = []

    def counting_prefill(tokens, state=None):
        calls.append(len(tokens))
        return prefill_fn(tokens, state)

    eng = ServeEngine(counting_prefill, decode_fn, batch=1, eos=0,
                      prefix_cache=PrefixCache(capacity=64), block=BLOCK)
    _serve(eng, [_req(0, list(range(1, 13)), max_new=2)])
    assert len(calls) == 3            # 3 blocks prefilled fresh
    calls.clear()
    _serve(eng, [_req(1, list(range(1, 9)) + [50, 51, 52, 53], max_new=2)])
    assert len(calls) == 1            # 2-block hit: only the tail block
    assert eng.cache.hits == 2


def test_intake_errors_are_typed():
    for make in (_host_engine, lambda: _fix_engine(fix.local())[0]):
        eng = make()
        with pytest.raises(EmptyPromptError):
            eng.submit(_req(0, []))
        with pytest.raises(EmptyPromptError):
            eng.submit(Request(rid=1, prompt=np.zeros((2, 2), np.int32),
                               max_new=4))
        with pytest.raises(EmptyPromptError):
            eng.submit(Request(rid=2, prompt=np.asarray([1.5, 2.5]),
                               max_new=4))
        with pytest.raises(BudgetError):
            eng.submit(_req(3, [1, 2], max_new=-1))
        with pytest.raises(BudgetError):
            eng.submit(Request(rid=4, prompt=np.asarray([1], np.int32),
                               max_new=True))
        with pytest.raises(BudgetError):
            eng.submit(Request(rid=5, prompt=np.asarray([1], np.int32),
                               max_new=2.0))
        assert eng.pending() == 0 and not eng.finished
        be = getattr(eng, "be", None)
        if be is not None:
            be.close()


def test_zero_budget_completes_without_a_token():
    eng = _host_engine()
    r = _req(0, [1, 2, 3], max_new=0)
    eng.submit(r)
    assert r.done and r.out_tokens == [] and eng.pending() == 0
    assert eng.finished == [r]
    eng.run()
    assert eng.steps == 0


# ------------------------------------------------------------ TenantQueue
def test_stride_scheduling_converges_to_weight_ratio():
    q = TenantQueue(weights={"a": 3.0, "b": 1.0})
    for i in range(40):
        q.push(_req(i, [1], tenant="a"))
        q.push(_req(100 + i, [1], tenant="b"))
    order = []
    for _ in range(40):
        r = q.pop()
        order.append(r.tenant)
        q.release(r.tenant)
    assert order.count("a") == 30 and order.count("b") == 10
    # no long runs: every window of 4 admissions serves b at least once
    for i in range(0, 40, 4):
        assert "b" in order[i:i + 4]


def test_inflight_cap_and_idle_rejoin():
    q = TenantQueue(max_inflight=1)
    q.push(_req(0, [1], tenant="a"))
    q.push(_req(1, [1], tenant="a"))
    q.push(_req(2, [1], tenant="b"))
    assert q.pop().tenant == "a"
    assert q.pop().tenant == "b"          # a is at its cap
    assert q.pop() is None                # everyone capped, backlog remains
    q.release("a")
    assert q.pop().tenant == "a"
    # idle rejoin: a tenant arriving after a busy stretch starts at the
    # floor, not at vtime 0 (no starving the incumbents)...
    for i in range(10):
        q.push(_req(10 + i, [1], tenant="a"))
    q.release("a"), q.release("a"), q.release("b")
    for _ in range(5):
        q.release(q.pop().tenant)
    q.push(_req(99, [1], tenant="c"))
    # ...and not at a penalty either: c is admitted next round, not after
    # a's whole backlog
    admits = []
    for _ in range(3):
        r = q.pop()
        admits.append(r.tenant)
        q.release(r.tenant)
    assert "c" in admits


def test_overloaded_tenant_cannot_lock_out_a_light_one():
    """20 heavy requests submitted before 2 light ones; fair admission
    must interleave the light tenant near the front, FIFO must not."""
    def traffic():
        reqs = [_req(i, [1, 2, 3, i], max_new=3, tenant="heavy")
                for i in range(20)]
        reqs += [_req(100 + i, [7, 7, i], max_new=3, tenant="light")
                 for i in range(2)]
        return reqs

    def admit_ranks(admission):
        clock = itertools.count()
        eng = _host_engine(batch=2, admission=admission,
                           now=lambda: float(next(clock)))
        _serve(eng, traffic())
        by_admit = sorted(eng.finished, key=lambda r: r.t_admit)
        return [i for i, r in enumerate(by_admit) if r.tenant == "light"]

    fair = admit_ranks(TenantQueue(max_inflight=1))
    fifo = admit_ranks(None)
    assert max(fair) <= 5, f"light tenant starved under fair queue: {fair}"
    assert min(fifo) >= 18, f"FIFO should have admitted light last: {fifo}"


# ---------------------------------------------------------- FixServeEngine
def _fix_engine(be, **kw):
    eng = FixServeEngine(be, W, batch=kw.pop("batch", 2), block=BLOCK, **kw)
    return eng, be


def test_fix_engine_matches_host_engine():
    prompts = [(0, list(range(1, 13))), (1, list(range(1, 9)) + [50, 51]),
               (2, [3, 1, 4, 1, 5, 9, 2, 6])]
    host = _serve(_host_engine(), [_req(r, p, max_new=5) for r, p in prompts])
    with fix.local() as be:
        eng, _ = _fix_engine(be)
        got = _serve(eng, [_req(r, p, max_new=5) for r, p in prompts])
    assert got == host


def test_full_prefix_hit_admits_with_zero_submissions():
    with fix.local() as be:
        eng, _ = _fix_engine(be, batch=1)
        prompt = list(range(1, 13))
        _serve(eng, [_req(0, prompt, max_new=2)])
        submits = []
        orig = be.submit

        def spying_submit(program, **kw):
            submits.append(program)
            return orig(program, **kw)

        be.submit = spying_submit
        _serve(eng, [_req(1, prompt, max_new=2)])
        assert eng.blocks_hit == 3 and eng.blocks_total == 6
        # every submission in round 2 was a decode step — zero prefills
        assert len(submits) == 2
    assert eng.report()["hit_ratio"] == 0.5


def test_strict_memo_survives_chain_cache_eviction():
    """The repo's strict-memo table is the durable index: evicting the
    client-side chain map must not force recomputation."""
    with fix.local() as be:
        eng, _ = _fix_engine(be, prefix_cache=PrefixCache(capacity=2))
        prompt = list(range(1, 17))  # 4 blocks > capacity 2
        _serve(eng, [_req(0, prompt, max_new=2)])
        assert len(eng.chain) <= 2   # chain map evicted most boundaries
        before = eng.blocks_hit
        _serve(eng, [_req(1, prompt, max_new=2)])
        # all 4 boundaries recovered through strict_memo_get
        assert eng.blocks_hit - before == 4


def test_ablation_streams_identical_but_never_hit():
    spec = make_serving_spec(11, n_requests=10)
    with fix.local() as be:
        memo = _serve(_fix_engine(be, batch=spec.batch)[0],
                      make_serving_requests(spec))
    with fix.local() as be:
        eng, _ = _fix_engine(be, batch=spec.batch, prefix_memo=False)
        abl = _serve(eng, make_serving_requests(spec))
    assert memo == abl
    assert eng.blocks_hit == 0 and eng.prefill_bytes_hit == 0


def test_cross_backend_streams_identical():
    spec = make_serving_spec(5, n_requests=12)
    ref = run_serving(spec, backend="local")
    assert ref["errors"] == []
    for kind in ("simulated", "remote"):
        got = run_serving(spec, backend=kind)
        assert got["streams"] == ref["streams"], f"{kind} diverged"
        assert got["errors"] == []


def test_simulated_trace_attributes_tenants():
    spec = make_serving_spec(2, n_requests=16)
    tr = TraceRecorder()
    out = run_serving(spec, backend="simulated", trace=tr)
    assert out["report"]["requests"] == spec.n_requests
    assert verify_invariants(tr.events) == []
    rep = tenant_report(tr.events)
    tenants = {t for t in rep if t.startswith("t")}
    assert len(tenants) == spec.n_tenants
    for t in tenants:
        assert rep[t]["jobs"] > 0
        assert rep[t]["finished"] > 0
        assert rep[t]["p50_latency_s"] >= 0.0


def test_memo_hit_carries_tenant_tag():
    """A resubmission of an already-computed encode is a cluster-level
    memo hit attributed to the *resubmitting* tenant — the serving
    engine's chain cache usually absorbs these client-side, so pin the
    trace plumbing directly."""
    from repro.core.stdlib import add
    clk = VirtualClock()
    tr = TraceRecorder()
    tr.bind(clk)
    c = Cluster(n_nodes=2, workers_per_node=1, clock=clk, seed=0, trace=tr)
    be = fix.on(c)
    try:
        be.submit(add(19, 23), tenant="alpha").result(300)
        be.submit(add(19, 23), tenant="beta").result(300)
    finally:
        be.close()
        clk.close()
    rep = tenant_report(tr.events)
    assert rep["alpha"]["jobs"] >= 1 and rep["alpha"]["memo_hits"] == 0
    assert rep["beta"]["memo_hits"] == 1


def test_percentile_ranks():
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 50) == 5.0
    vals = [float(i) for i in range(1, 101)]
    assert percentile(vals, 50) == 50.0
    assert percentile(vals, 99) == 99.0
    assert percentile(vals, 100) == 100.0
