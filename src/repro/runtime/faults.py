"""Deterministic fault-injection plane for the cluster runtime.

A :class:`FaultSchedule` is a declarative list of faults pinned to
*virtual-clock instants*; ``Cluster(faults=schedule)`` arms one timer per
fault at startup, so the same seed + schedule always injects at the same
simulated nanosecond and the whole run (including every recovery action)
replays bit-identically.  Supported fault kinds:

==================  =====================================================
kind                effect
==================  =====================================================
``crash``           fail-stop a node (store wiped, workers drain)
``join``            (re)join a node — a crashed node revives with an
                    empty store, or a brand-new node id is added
``link_down``       drop every plan on a directed link until ``link_up``
``link_up``         re-enable a downed link
``degrade``         multiply a link's serialization time by ``factor``
``degrade_end``     restore the link's bandwidth
``drop``            drop the next ``count`` plans on a link (transient)
``corrupt_wire``    flip bytes in the next ``count`` deliveries on a link
``corrupt_blob``    flip a byte of a resident blob on a node (at-rest)
==================  =====================================================

Transient link state (down links, degradation factors, pending drop and
corruption budgets) lives in a :class:`FaultState` shared between the
scheduler thread (which applies schedule entries) and the transfer plane's
link workers (which consult it at serialization/delivery time); it is the
only mutable coupling between the two and is guarded by one lock.

The *errors* recovery can surface — :class:`TransferFailed`,
:class:`DataUnrecoverable`, plus :class:`~repro.fix.future.CancelledError`
and :class:`~repro.fix.future.DeadlineExceeded` re-exported from the
frontend — are all typed, so a chaos harness can assert every failed job
died for an attributed reason.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Iterable, Optional

from ..core.handle import TREE, Handle
from ..fix.future import CancelledError, DeadlineExceeded

__all__ = [
    "Fault",
    "FaultSchedule",
    "FaultState",
    "FaultError",
    "TransferFailed",
    "DataUnrecoverable",
    "CancelledError",
    "DeadlineExceeded",
    "corrupt_payload",
]


# ------------------------------------------------------------------ errors
class FaultError(RuntimeError):
    """Base class for attributed failures surfaced by fault recovery."""


class TransferFailed(FaultError):
    """Staging a blob to a node exhausted its retry budget."""

    def __init__(self, key_hex: str, dst: str, attempts: int, reason: str):
        super().__init__(
            f"transfer of {key_hex[:16]} to {dst} failed after "
            f"{attempts} attempt(s): {reason}")
        self.key_hex = key_hex
        self.dst = dst
        self.attempts = attempts
        self.reason = reason


class DataUnrecoverable(FaultError):
    """A needed blob has no surviving replica and no lineage to recompute
    it from (or its recompute failed)."""

    def __init__(self, key_hex: str, reason: str):
        super().__init__(
            f"content {key_hex[:16]} unrecoverable: {reason}")
        self.key_hex = key_hex
        self.reason = reason


# ---------------------------------------------------------------- schedule
@dataclass(frozen=True)
class Fault:
    """One scheduled injection.  ``t`` is seconds after cluster start on
    the cluster's clock; which other fields matter depends on ``kind``."""

    t: float
    kind: str
    node: Optional[str] = None        # crash / join / corrupt_blob
    src: Optional[str] = None         # link faults
    dst: Optional[str] = None
    count: int = 1                    # drop / corrupt_wire budget
    factor: float = 1.0               # degrade multiplier
    workers: int = 0                  # join: worker slots (0 = cluster default)
    index: int = 0                    # corrupt_blob: which resident blob


class FaultSchedule:
    """Chainable builder for a deterministic fault timeline.

    >>> sched = (FaultSchedule()
    ...          .crash(at=0.05, node="n1")
    ...          .join(at=0.20, node="n1")
    ...          .link_down(at=0.02, src="s0", dst="n0", for_s=0.1)
    ...          .drop(at=0.01, src="s0", dst="n2", count=2))

    Durations (``for_s``) expand into paired up/down entries, so
    :meth:`expanded` yields a flat, stably time-sorted list of
    :class:`Fault` records — what ``Cluster`` arms timers from and what
    the trace's ``fault`` events mirror one-to-one.
    """

    def __init__(self, faults: Iterable[Fault] = ()):  # noqa: D401
        self._faults: list[Fault] = list(faults)

    # each builder returns self so schedules read as one chained expression
    def crash(self, at: float, node: str) -> "FaultSchedule":
        self._faults.append(Fault(t=at, kind="crash", node=node))
        return self

    def join(self, at: float, node: str, workers: int = 0) -> "FaultSchedule":
        self._faults.append(Fault(t=at, kind="join", node=node,
                                  workers=workers))
        return self

    def link_down(self, at: float, src: str, dst: str,
                  for_s: Optional[float] = None) -> "FaultSchedule":
        self._faults.append(Fault(t=at, kind="link_down", src=src, dst=dst))
        if for_s is not None:
            self._faults.append(Fault(t=at + for_s, kind="link_up",
                                      src=src, dst=dst))
        return self

    def link_up(self, at: float, src: str, dst: str) -> "FaultSchedule":
        self._faults.append(Fault(t=at, kind="link_up", src=src, dst=dst))
        return self

    def degrade(self, at: float, src: str, dst: str, factor: float,
                for_s: Optional[float] = None) -> "FaultSchedule":
        self._faults.append(Fault(t=at, kind="degrade", src=src, dst=dst,
                                  factor=factor))
        if for_s is not None:
            self._faults.append(Fault(t=at + for_s, kind="degrade_end",
                                      src=src, dst=dst))
        return self

    def drop(self, at: float, src: str, dst: str,
             count: int = 1) -> "FaultSchedule":
        self._faults.append(Fault(t=at, kind="drop", src=src, dst=dst,
                                  count=count))
        return self

    def corrupt_wire(self, at: float, src: str, dst: str,
                     count: int = 1) -> "FaultSchedule":
        self._faults.append(Fault(t=at, kind="corrupt_wire", src=src,
                                  dst=dst, count=count))
        return self

    def corrupt_blob(self, at: float, node: str,
                     index: int = 0) -> "FaultSchedule":
        self._faults.append(Fault(t=at, kind="corrupt_blob", node=node,
                                  index=index))
        return self

    def expanded(self) -> list[Fault]:
        """The flat timeline, stably sorted by injection instant."""
        return sorted(self._faults, key=lambda f: f.t)

    def __len__(self) -> int:
        return len(self._faults)


# ------------------------------------------------------------- live state
@dataclass
class FaultState:
    """Transient link state shared between scheduler and link workers.

    The scheduler mutates it when a schedule entry fires; link workers
    read it at serialization time (bandwidth factor) and delivery time
    (down links, drop/corrupt budgets).  Budgets are consumed atomically
    (``take_*``) so a count-2 drop hits exactly two plans regardless of
    which worker threads race to deliver."""

    _lock: threading.Lock = field(default_factory=threading.Lock)
    _down: set = field(default_factory=set)           # {(src, dst)}
    _factors: dict = field(default_factory=dict)      # (src, dst) -> float
    _drops: dict = field(default_factory=dict)        # (src, dst) -> remaining
    _corrupts: dict = field(default_factory=dict)     # (src, dst) -> remaining

    # scheduler-side setters
    def set_link_down(self, src: str, dst: str, down: bool) -> None:
        with self._lock:
            if down:
                self._down.add((src, dst))
            else:
                self._down.discard((src, dst))

    def set_factor(self, src: str, dst: str, factor: Optional[float]) -> None:
        with self._lock:
            if factor is None or factor == 1.0:
                self._factors.pop((src, dst), None)
            else:
                self._factors[(src, dst)] = factor

    def add_drops(self, src: str, dst: str, count: int) -> None:
        with self._lock:
            self._drops[(src, dst)] = self._drops.get((src, dst), 0) + count

    def add_corrupts(self, src: str, dst: str, count: int) -> None:
        with self._lock:
            self._corrupts[(src, dst)] = (
                self._corrupts.get((src, dst), 0) + count)

    # transfer-plane-side readers/consumers
    def link_down(self, src: str, dst: str) -> bool:
        with self._lock:
            return (src, dst) in self._down

    def bandwidth_factor(self, src: str, dst: str) -> float:
        with self._lock:
            return self._factors.get((src, dst), 1.0)

    def take_drop(self, src: str, dst: str) -> bool:
        with self._lock:
            left = self._drops.get((src, dst), 0)
            if left <= 0:
                return False
            self._drops[(src, dst)] = left - 1
            return True

    def take_corrupt(self, src: str, dst: str) -> bool:
        with self._lock:
            left = self._corrupts.get((src, dst), 0)
            if left <= 0:
                return False
            self._corrupts[(src, dst)] = left - 1
            return True


# ----------------------------------------------------------------- helpers
def corrupt_payload(handle: Handle, payload):
    """Deterministically corrupt one delivery payload (flip the first
    byte), preserving its python shape so the receiving repository's
    verify-on-put — not a type error — is what catches it."""
    if handle.content_type == TREE:
        kids = list(payload)
        if not kids:
            return payload
        first = bytearray(kids[0].raw)
        first[0] ^= 0xFF
        kids[0] = Handle(bytes(first))
        return tuple(kids)
    data = bytearray(payload)
    if not data:
        return payload
    data[0] ^= 0xFF
    return bytes(data)
