"""Quickstart: the Fix computation model in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import struct

from repro.core import Evaluator, Handle, Repository
from repro.core.stdlib import combination
from repro.runtime import Cluster, Link, Network


def main() -> None:
    # --- 1. local evaluation: data + code -> content-addressed results ----
    repo = Repository()
    ev = Evaluator(repo)
    th = combination(repo, "add",
                     Handle.blob((40).to_bytes(8, "little", signed=True)),
                     Handle.blob((2).to_bytes(8, "little", signed=True)))
    out = ev.evaluate(th.strict())
    print("40 + 2 =", int.from_bytes(repo.get_blob(out), "little", signed=True))

    # memoization: the thunk IS the cache key
    before = ev.applications
    ev.evaluate(th.strict())
    print("re-evaluation ran", ev.applications - before, "codelets (memo hit)")

    # --- 2. laziness: the untaken branch never evaluates ------------------
    bomb = combination(repo, "add", Handle.blob(b"not-an-int"), Handle.blob(b"x"))
    good = combination(repo, "add", Handle.blob((1).to_bytes(8, "little", signed=True)),
                       Handle.blob((2).to_bytes(8, "little", signed=True)))
    cond = combination(repo, "fix_if",
                       Handle.blob((1).to_bytes(8, "little", signed=True)), good, bomb)
    out = ev.evaluate(cond.strict())
    print("lazy if ->", int.from_bytes(repo.get_blob(out), "little", signed=True))

    # --- 3. selection: touch one child of a big tree ----------------------
    kids = [repo.put_blob(bytes([i]) * 1000) for i in range(100)]
    tree = repo.put_tree(kids)
    pair = repo.put_tree([tree, repo.put_blob(struct.pack("<q", 42))])
    sel = ev.evaluate(pair.selection_of().strict())
    print("selected child 42, first byte:", repo.get_blob(sel)[0])

    # --- 4. the same program on a 3-node cluster ---------------------------
    cluster = Cluster(n_nodes=3, workers_per_node=2,
                      network=Network(Link(latency_s=0.001, gbps=10)))
    try:
        fib = combination(cluster.client_repo, "fib",
                          Handle.blob((15).to_bytes(8, "little", signed=True)))
        out = cluster.evaluate(fib.strict(), timeout=60)
        got = cluster.fetch_result(out)
        print("fib(15) on the cluster =",
              int.from_bytes(got.get_blob(out), "little", signed=True))
        print("bytes moved:", cluster.bytes_moved, " transfers:", cluster.transfers)
    finally:
        cluster.shutdown()


if __name__ == "__main__":
    main()
