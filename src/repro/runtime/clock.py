"""Pluggable time for the cluster simulation: wall clock or virtual clock.

Every time source in the runtime — link latency and serialization sleeps,
delivery timers, speculation wakeups, job timestamps, worker accounting,
Future deadlines — goes through a :class:`Clock`, so the same scheduler
code runs in two regimes:

* :class:`WallClock` — today's behaviour: ``sleep`` is ``time.sleep``,
  ``now`` is ``time.monotonic``, timers run on one daemon thread.  Zero
  semantic change from the pre-clock runtime.

* :class:`VirtualClock` — simulated time is *free* and runs are
  *bit-identical*.  The clock owns a run token: exactly one participating
  thread executes at a time, and every blocking point in the runtime
  (queue get, NIC lock, event wait, sleep) is a clock primitive that hands
  the token to the next ready thread in deterministic FIFO order.  When no
  thread is runnable — all participants are quiescent, blocked on clock
  primitives — the clock pops the earliest ``(time, seq)`` entry from its
  event heap and advances ``now`` to it.  Multi-second simulated
  topologies therefore execute in milliseconds of wall time, and because
  execution is fully serialized with deterministic handoff order, two runs
  of the same program produce identical schedules, transfer counts and
  makespans.

The cost of determinism is cooperative scheduling: a virtual-clock cluster
must be driven from the thread that created it (``Cluster.__init__``
registers its caller as the driver).  Threads the runtime spawns register
through :meth:`Clock.spawn`; foreign threads that touch a clock primitive
are adopted for the duration of the wait and hand the token back
afterwards — best-effort liveness (their wakeups ride the same event
heap, which advances while the registered set keeps yielding or is idle;
a driver that busy-spins outside clock primitives starves them) and no
determinism guarantees outside the registered set.
"""
from __future__ import annotations

import abc
import heapq
import itertools
import queue
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Callable, Optional


class Timer:
    """Cancellation handle returned by :meth:`Clock.call_at`."""

    __slots__ = ("fn", "cancelled")

    def __init__(self, fn: Callable[[], None]):
        self.fn = fn
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class Clock(abc.ABC):
    """The runtime's one source of time, sleep, timers and blocking."""

    is_virtual = False

    # ------------------------------------------------------------- time
    @abc.abstractmethod
    def now(self) -> float:
        """Monotonic seconds (simulated under a virtual clock)."""

    @abc.abstractmethod
    def ns(self) -> int:
        """Monotonic nanoseconds, for worker busy/starved accounting."""

    @abc.abstractmethod
    def sleep(self, dt: float) -> None:
        """Block the calling thread for ``dt`` clock-seconds."""

    @abc.abstractmethod
    def call_at(self, when: float, fn: Callable[[], None]) -> Timer:
        """Run ``fn`` when the clock reaches ``when`` (absolute)."""

    def call_later(self, dt: float, fn: Callable[[], None]) -> Timer:
        """Run ``fn`` after ``dt`` clock-seconds (relative convenience)."""
        return self.call_at(self.now() + dt, fn)

    # ------------------------------------------------- blocking primitives
    @abc.abstractmethod
    def make_queue(self):
        """A FIFO queue whose blocking ``get`` the clock understands."""

    @abc.abstractmethod
    def make_lock(self):
        """A mutex (context manager) the clock understands — used for NIC
        locks held across :meth:`sleep`."""

    @abc.abstractmethod
    def make_event(self):
        """A one-shot event (``set``/``wait``/``is_set``) the clock
        understands — used for clock-aware Future deadlines."""

    # ------------------------------------------------------------ threads
    @abc.abstractmethod
    def spawn(self, target: Callable[[], None],
              name: Optional[str] = None) -> threading.Thread:
        """Start a daemon thread participating in this clock."""

    def register_current(self) -> None:
        """Make the calling thread a clock participant (the driver)."""

    def unregister_current(self) -> None:
        pass

    @contextmanager
    def external_wait(self):
        """Mark a region where the calling participant blocks on something
        the clock cannot see (e.g. ``Thread.join``), so the rest of the
        runtime keeps running meanwhile."""
        yield

    def close(self) -> None:
        pass


# =========================================================== wall clock
class _WallTimer:
    """Single daemon thread firing callbacks at wall deadlines (moved here
    from ``transfers._DeliveryTimer`` — now it also serves speculation
    wakeups, so wall runs no longer poll-and-oversleep)."""

    def __init__(self):
        self._heap: list = []
        self._cv = threading.Condition()
        self._seq = itertools.count()
        self._stopped = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="fix-clock-timer")
        self._thread.start()

    def schedule(self, when: float, timer: Timer) -> None:
        with self._cv:
            heapq.heappush(self._heap, (when, next(self._seq), timer))
            self._cv.notify()

    def stop(self) -> None:
        with self._cv:
            self._stopped = True
            self._cv.notify()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)

    def _run(self) -> None:
        while True:
            with self._cv:
                if self._stopped:
                    return
                if not self._heap:
                    self._cv.wait()
                    continue
                when, _, timer = self._heap[0]
                now = time.monotonic()
                if when > now:
                    self._cv.wait(when - now)
                    continue
                heapq.heappop(self._heap)
            if timer.cancelled:
                continue
            try:
                timer.fn()
            except Exception:  # noqa: BLE001 — a callback must not kill the clock
                pass


class WallClock(Clock):
    """Real time: the pre-clock runtime's exact behaviour."""

    is_virtual = False

    def __init__(self):
        self._timer: Optional[_WallTimer] = None
        self._timer_lock = threading.Lock()
        self._closed = False

    def now(self) -> float:
        return time.monotonic()

    def ns(self) -> int:
        return time.perf_counter_ns()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)

    def call_at(self, when: float, fn: Callable[[], None]) -> Timer:
        t = Timer(fn)
        with self._timer_lock:
            if self._closed:
                return t  # post-shutdown: pending deliveries are dropped,
                #           exactly like the seed's stopped delivery timer
            if self._timer is None:  # lazy: clusters that never schedule
                self._timer = _WallTimer()  # timers get no extra thread
            self._timer.schedule(when, t)
        return t

    def make_queue(self):
        return queue.Queue()

    def make_lock(self):
        return threading.Lock()

    def make_event(self):
        return threading.Event()

    def spawn(self, target, name=None) -> threading.Thread:
        t = threading.Thread(target=target, daemon=True, name=name)
        t.start()
        return t

    def close(self) -> None:
        with self._timer_lock:
            self._closed = True
            timer, self._timer = self._timer, None
        if timer is not None:
            timer.stop()
            timer.join(timeout=5)  # no thread outlives the clock


# ======================================================== virtual clock
class _TState:
    """Per-participant scheduling state (guarded by the clock's lock)."""

    __slots__ = ("cv", "running", "ready", "adopted", "dead", "name")

    def __init__(self, cv: threading.Condition, adopted: bool, name: str):
        self.cv = cv
        self.running = False   # holds the run token
        self.ready = False     # queued for the token
        self.adopted = adopted  # foreign thread: hand the token back after waits
        self.dead = False      # unregistered; never grant it the token
        self.name = name


class VirtualClock(Clock):
    """Deterministic simulated time over cooperative real threads.

    Invariants (all transitions under ``self._lock``):

    * at most one participant has ``running=True`` — it is the only
      participant executing; everyone else is parked on its own condition
      variable or queued in ``self._ready``;
    * ``self._heap`` holds pending wakeups: ``('sleep', state)`` entries
      re-ready a sleeping participant, ``('timer', Timer)`` entries are
      executed in order on the internal timer participant;
    * time advances **only** in :meth:`_dispatch`, and only when the ready
      queue is empty — i.e. every participant is quiescent, so nothing
      that could still happen "now" is outrun by the clock.  One event is
      popped per advance, which serializes same-timestamp events in
      deterministic ``seq`` order.
    """

    is_virtual = True

    def __init__(self, start: float = 0.0):
        self._lock = threading.Lock()
        self._now = float(start)
        self._seq = itertools.count()
        self._heap: list = []          # (when, seq, kind, payload)
        self._threads: dict[int, _TState] = {}
        self._ready: deque[_TState] = deque()
        self._running: Optional[_TState] = None
        self._closed = False
        self._timer_pending: deque[Timer] = deque()
        self._timer_state: Optional[_TState] = None
        started = threading.Event()
        self._timer_thread = threading.Thread(
            target=self._timer_loop, args=(started,),
            daemon=True, name="fix-vclock-timer")
        self._timer_thread.start()
        started.wait()

    # ------------------------------------------------------------- time
    def now(self) -> float:
        return self._now

    def ns(self) -> int:
        return int(round(self._now * 1e9))

    def sleep(self, dt: float) -> None:
        with self._lock:
            st = self._adopt_locked()
            heapq.heappush(self._heap,
                           (self._now + max(dt, 0.0), next(self._seq),
                            "sleep", st))
            self._block_current(st)
            self._release_if_adopted(st)

    def call_at(self, when: float, fn: Callable[[], None]) -> Timer:
        with self._lock:
            return self._call_at_locked(when, fn)

    def _call_at_locked(self, when: float, fn: Callable[[], None]) -> Timer:
        t = Timer(fn)
        heapq.heappush(self._heap, (when, next(self._seq), "timer", t))
        if self._running is None:
            self._dispatch()  # idle runtime: someone must advance
        return t

    # ------------------------------------------------- blocking primitives
    def make_queue(self):
        return _VQueue(self)

    def make_lock(self):
        return _VLock(self)

    def make_event(self):
        return _VEvent(self)

    # ------------------------------------------------------------ threads
    def spawn(self, target, name=None) -> threading.Thread:
        started = threading.Event()

        def body():
            st = self._register_enqueue(adopted=False, name=name or "spawned")
            started.set()
            self._await_token(st)
            try:
                target()
            finally:
                self.unregister_current()

        t = threading.Thread(target=body, daemon=True, name=name)
        t.start()
        started.wait()  # registration order == spawn order (determinism)
        return t

    def register_current(self) -> None:
        st = self._register_enqueue(adopted=False,
                                    name=threading.current_thread().name)
        self._await_token(st)

    def unregister_current(self) -> None:
        with self._lock:
            st = self._threads.pop(threading.get_ident(), None)
            if st is None:
                return
            st.dead = True
            was_running = st.running
            st.running = False
            if self._running is st:
                self._running = None
                if was_running:
                    self._dispatch()

    @contextmanager
    def external_wait(self):
        st = self._threads.get(threading.get_ident())
        if st is None or not st.running:
            yield
            return
        with self._lock:
            st.running = False
            if self._running is st:
                self._running = None
                self._dispatch()
        try:
            yield
        finally:
            with self._lock:
                self._make_ready(st)
                while not st.running:
                    st.cv.wait()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            if self._timer_state is not None:
                self._make_ready(self._timer_state)
        # Drain the internal timer participant so no thread outlives the
        # clock (the flake guard in tests/conftest.py pins this).  If the
        # caller is the running participant it must hand the token over
        # while it (real-)waits for the timer thread to exit.
        st = self._threads.get(threading.get_ident())
        if st is not None and st.running:
            with self.external_wait():
                self._timer_thread.join(timeout=5)
        else:
            self._timer_thread.join(timeout=5)

    # -------------------------------------------------------- internals
    def _register_enqueue(self, adopted: bool, name: str) -> _TState:
        with self._lock:
            ident = threading.get_ident()
            st = self._threads.get(ident)
            if st is None:
                st = _TState(threading.Condition(self._lock), adopted, name)
                self._threads[ident] = st
            elif not adopted:
                st.adopted = False  # promotion to full participant sticks
            if not st.running and not st.ready:
                if self._running is None and not self._ready:
                    st.running = True
                    self._running = st
                else:
                    st.ready = True
                    self._ready.append(st)
            return st

    def _await_token(self, st: _TState) -> None:
        with self._lock:
            while not st.running:
                st.cv.wait()

    def _adopt_locked(self) -> _TState:
        """State for the calling thread, creating a token-less *adopted*
        entry for foreign threads (lock held)."""
        st = self._threads.get(threading.get_ident())
        if st is None:
            st = _TState(threading.Condition(self._lock), True,
                         threading.current_thread().name)
            self._threads[threading.get_ident()] = st
        return st

    def _release_if_adopted(self, st: _TState) -> None:
        """Adopted threads give the token back after their wait so the
        registered runtime keeps running (lock held)."""
        if st.adopted and st.running:
            st.running = False
            if self._running is st:
                self._running = None
                self._dispatch()

    def _make_ready(self, st: _TState) -> None:
        if st.ready or st.running or st.dead:
            return
        st.ready = True
        self._ready.append(st)
        if self._running is None:
            self._dispatch()

    def _block_current(self, st: _TState) -> None:
        """Give up the token, hand off / advance time, park until granted
        again (lock held)."""
        st.running = False
        if self._running is st:
            self._running = None
            self._dispatch()
        elif self._running is None:
            # Idle runtime and a token-less (adopted) thread just queued a
            # wakeup for itself: dispatch here, *after* running is cleared,
            # so a self-grant is observed by the loop below instead of
            # being overwritten (granting before parking deadlocks).
            self._dispatch()
        while not st.running:
            st.cv.wait()

    def _dispatch(self) -> None:
        """Grant the token to the next ready participant; when nobody is
        ready, advance virtual time one event at a time (lock held)."""
        while self._running is None:
            if self._ready:
                nxt = self._ready.popleft()
                nxt.ready = False
                if nxt.dead:
                    continue
                nxt.running = True
                self._running = nxt
                nxt.cv.notify()
                return
            if self._closed or not self._heap:
                return  # fully idle: an external put/set will re-dispatch
            when, _, kind, payload = heapq.heappop(self._heap)
            if kind == "timer" and payload.cancelled:
                continue
            if when > self._now:
                self._now = when
            if kind == "sleep":
                self._make_ready(payload)
            else:
                self._timer_pending.append(payload)
                if self._timer_state is not None:
                    self._make_ready(self._timer_state)

    def _timer_loop(self, started: threading.Event) -> None:
        st = self._register_enqueue(adopted=False, name="fix-vclock-timer")
        self._timer_state = st
        started.set()
        self._await_token(st)
        while True:
            with self._lock:
                while not self._timer_pending:
                    if self._closed:
                        self._threads.pop(threading.get_ident(), None)
                        st.dead = True  # a late _make_ready must skip us,
                        #                 or the token would park on a corpse
                        st.running = False
                        if self._running is st:
                            self._running = None
                            self._dispatch()
                        return
                    self._block_current(st)
                timer = self._timer_pending.popleft()
            if timer.cancelled:
                continue
            try:
                timer.fn()
            except Exception:  # noqa: BLE001 — a callback must not kill the clock
                pass


class _VQueue:
    """FIFO queue whose blocking ``get`` participates in the clock."""

    def __init__(self, clock: VirtualClock):
        self._c = clock
        self._items: deque = deque()
        self._waiters: deque[_TState] = deque()

    def put(self, item) -> None:
        c = self._c
        with c._lock:
            self._items.append(item)
            if self._waiters:
                c._make_ready(self._waiters.popleft())

    def get(self):
        c = self._c
        with c._lock:
            st = c._adopt_locked()
            while not self._items:
                self._waiters.append(st)
                c._block_current(st)
            item = self._items.popleft()
            c._release_if_adopted(st)
            return item

    def qsize(self) -> int:
        return len(self._items)

    def empty(self) -> bool:
        return not self._items


class _VLock:
    """Mutex safe to hold across ``clock.sleep`` (NIC serialization)."""

    def __init__(self, clock: VirtualClock):
        self._c = clock
        self._held = False
        self._waiters: deque[_TState] = deque()

    def acquire(self) -> None:
        c = self._c
        with c._lock:
            st = c._adopt_locked()
            while self._held:
                self._waiters.append(st)
                c._block_current(st)
            self._held = True

    def release(self) -> None:
        c = self._c
        with c._lock:
            self._held = False
            if self._waiters:
                c._make_ready(self._waiters.popleft())

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class _VEvent:
    """One-shot event; ``wait`` blocks — and times out — in clock time,
    mirroring ``threading.Event.wait`` with simulated seconds."""

    def __init__(self, clock: VirtualClock):
        self._c = clock
        self._flag = False
        self._waiters: deque[_TState] = deque()

    def set(self) -> None:
        c = self._c
        with c._lock:
            self._flag = True
            while self._waiters:
                c._make_ready(self._waiters.popleft())

    def is_set(self) -> bool:
        return self._flag

    def wait(self, timeout: Optional[float] = None) -> bool:
        c = self._c
        with c._lock:
            st = c._adopt_locked()
            timer = None
            expired = []
            if timeout is not None and not self._flag:
                def _expire():
                    with c._lock:
                        if not self._flag and st in self._waiters:
                            expired.append(True)
                            self._waiters.remove(st)
                            c._make_ready(st)
                timer = c._call_at_locked(c._now + timeout, _expire)
            while not self._flag and not expired:
                self._waiters.append(st)
                c._block_current(st)
            if timer is not None:
                timer.cancel()
            c._release_if_adopted(st)
            return not expired
