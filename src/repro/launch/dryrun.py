import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory/cost/collective analysis — the proof that the distribution
config is coherent on the production mesh without real hardware.

The two lines above MUST stay first: jax locks the device count on first
backend init, and this module (only) needs 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES, cells_for, get_config
from ..models import input_specs
from ..parallel.steps import RunConfig, build_serve_step, build_train_step, make_sharder
from ..roofline.analysis import from_compiled, model_flops_per_step
from .mesh import make_production_mesh


def default_runconfig(arch: str, shape_name: str, **overrides) -> RunConfig:
    import importlib

    kw = dict(microbatches=8, remat="dots", rules="baseline")
    try:
        mod = importlib.import_module(f"..configs.{arch.replace('-', '_')}", __package__)
        kw.update(getattr(mod, "DRYRUN", {}))
    except ModuleNotFoundError:
        pass
    if shape_name != "train_4k":
        kw.update(microbatches=1, remat="none")
    kw.update(overrides)
    return RunConfig(**kw)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               runcfg: RunConfig | None = None):
    """Returns (lowered, compiled, meta) for one cell."""
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    runcfg = runcfg or default_runconfig(arch, shape_name)
    n_dev = mesh.devices.size

    if cell.mode == "train":
        step, state_sh, batch_sh, abstract = build_train_step(cfg, runcfg, mesh)
        bspecs = input_specs(cfg, "train", cell.batch, cell.seq)
        lowered = step.lower(abstract, bspecs)
    elif cell.mode == "prefill":
        step, p_sh, abstract_p, _ = build_serve_step(
            cfg, runcfg, mesh, cell.batch, cell.seq, mode="prefill")
        bspecs = input_specs(cfg, "prefill", cell.batch, cell.seq)
        lowered = step.lower(abstract_p, bspecs)
    else:  # decode
        step, p_sh, abstract_p, (c_sh, abstract_c) = build_serve_step(
            cfg, runcfg, mesh, cell.batch, cell.seq, mode="decode")
        tspecs = input_specs(cfg, "decode", cell.batch, cell.seq)
        lowered = step.lower(abstract_p, abstract_c, tspecs["tokens"])
    compiled = lowered.compile()
    mf = model_flops_per_step(cfg, cell.mode, cell.batch, cell.seq, n_dev)
    return lowered, compiled, {"model_flops_per_device": mf, "n_devices": n_dev,
                               "runcfg": runcfg}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             runcfg: RunConfig | None = None) -> dict:
    t0 = time.time()
    try:
        lowered, compiled, meta = lower_cell(arch, shape_name, multi_pod, runcfg)
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug to report
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
    ma = compiled.memory_analysis()
    rf = from_compiled(compiled, meta["model_flops_per_device"])
    out = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "ok": True,
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes,
        },
        "roofline": rf.to_dict(),
    }
    # HBM check: v5e has 16 GiB
    out["memory"]["fits_16GiB"] = out["memory"]["peak_estimate_bytes"] < 16 * 2**30
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None, choices=ARCHS + [None])
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--rules", default=None)
    ap.add_argument("--dp-sync", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = args.arch or (ARCHS if args.all else [ARCHS[0]])
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    results = []
    for arch in archs:
        for cell in cells_for(arch):
            if args.shape and cell.name not in args.shape:
                continue
            for mp in meshes:
                over = {}
                if args.microbatches is not None:
                    over["microbatches"] = args.microbatches
                if args.remat:
                    over["remat"] = args.remat
                if args.rules:
                    over["rules"] = args.rules
                if args.dp_sync:
                    over["dp_sync"] = args.dp_sync
                rc = default_runconfig(arch, cell.name, **over) if over else None
                res = run_cell(arch, cell.name, mp, rc)
                results.append(res)
                status = "OK " if res["ok"] else "FAIL"
                extra = ""
                if res["ok"]:
                    r = res["roofline"]
                    extra = (f"dom={r['dominant']:10s} "
                             f"c/m/x={r['compute_s']:.3g}/{r['memory_s']:.3g}/"
                             f"{r['collective_s']:.3g}s "
                             f"mem={res['memory']['peak_estimate_bytes']/2**30:.2f}GiB "
                             f"compile={res['compile_s']}s")
                else:
                    extra = res["error"][:160]
                print(f"[{status}] {arch:20s} {cell.name:12s} {res['mesh']:8s} {extra}",
                      flush=True)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
