"""Jitted wrappers + platform dispatch for the kernel layer.

TPU (target): Pallas kernels.  CPU (this container): interpret-mode for
tests, and for the dry-run the models use ``blocked_attention`` — an
online-softmax scan that is the exact jnp twin of the flash kernel, so the
lowered HLO has the kernel's memory behaviour (no S x T materialization)
even where Pallas can't lower.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ------------------------------------------------------ blocked attention
def blocked_attention(q, k, v, *, causal: bool = True, block_k: int = 1024):
    """Online-softmax attention via lax.scan over KV blocks.

    q: [B,S,H,hd]  k,v: [B,T,H,hd].  Never materializes [S, T]; the live
    set is one [B,S,H,block_k] score tile — the flash-attention memory
    profile expressed in pure jnp (XLA fuses the tile pipeline).
    """
    B, S, H, hd = q.shape
    hd_v = v.shape[-1]                 # MLA: v head dim may differ from q/k
    T = k.shape[1]
    block_k = min(block_k, T)
    pad = (-T) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    nb = Tp // block_k
    scale = 1.0 / np.sqrt(hd)
    kb = k.reshape(B, nb, block_k, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block_k, H, hd_v).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(S)

    def step(carry, inp):
        m, l, acc = carry
        bi, k_blk, v_blk = inp
        s = jnp.einsum("bshd,bthd->bhst", q, k_blk).astype(jnp.float32) * scale
        k_pos = bi * block_k + jnp.arange(block_k)
        valid = k_pos[None, :] < T
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(valid[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p.astype(v_blk.dtype), v_blk).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nb), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# --------------------------------------------------------------- dispatch
# threshold above which the naive [S,T] materialization would blow VMEM/HBM
_BLOCK_THRESHOLD = 4096 * 4096


def flash_attention(q, k, v, mask=None, *, causal: bool = True):
    """Public attention entry used by models.  mask is accepted for parity
    with base.attend but only causal/full patterns route here."""
    if on_tpu():
        from .flash_attention import flash_attention as fa
        return fa(q, k, v, causal=causal)
    return blocked_attention(q, k, v, causal=causal)


def decode_attention(q, k, v, length):
    if on_tpu():
        from .decode_attention import decode_attention as da
        return da(q, k, v, length)
    from .ref import decode_attention_ref
    return decode_attention_ref(q, k, v, length)


def ssd_scan(x, dt, A, B_, C_, chunk: int = 256):
    """Returns (y, final_state) matching models.mamba2.ssd_chunked."""
    if on_tpu():
        from .ssd_scan import ssd_scan as ss
        return ss(x, dt, A, B_, C_, chunk)
    from .ref import ssd_scan_ref
    return ssd_scan_ref(x, dt, A, B_, C_)


def rmsnorm(x, w, eps: float = 1e-6):
    if on_tpu():
        from .rmsnorm import rmsnorm as rn
        return rn(x, w, eps)
    from .ref import rmsnorm_ref
    return rmsnorm_ref(x, w, eps)
