"""Serving driver: prefill + continuous-batched decode on a real model.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_8b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config
from ..models import init_params, ops_for
from ..parallel.sharding import Sharder
from ..serving import PrefixCache, Request, ServeEngine


def build_model_fns(cfg, max_seq: int):
    """Per-row prefill/greedy-decode callables over the family ops."""
    ops = ops_for(cfg)
    params = init_params(ops.specs(cfg), cfg)
    sh = Sharder(None)

    @jax.jit
    def prefill_one(tokens):
        _logits, cache = ops.prefill(params, {"tokens": tokens[None]}, cfg, sh)
        return cache

    @jax.jit
    def decode_one(cache, token):
        logits, cache = ops.decode_step(params, cache,
                                        jnp.asarray([[token]], jnp.int32), cfg, sh)
        return jnp.argmax(logits[0, -1]), cache

    def prefill_fn(prompt_np):
        return prefill_one(jnp.asarray(prompt_np, jnp.int32))

    def decode_fn(cache, last_token):
        tok, cache = decode_one(cache, last_token)
        return int(tok), cache

    return prefill_fn, decode_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b", choices=ARCHS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    prefill_fn, decode_fn = build_model_fns(cfg, args.prompt_len + args.max_new)
    engine = ServeEngine(prefill_fn, decode_fn, batch=args.batch, eos=-1,
                         prefix_cache=PrefixCache(capacity=8))
    rng = np.random.default_rng(0)
    shared_prefix = rng.integers(1, cfg.vocab, 16)  # one full prefix block
    reqs = []
    for i in range(args.requests):
        tail = rng.integers(1, cfg.vocab, args.prompt_len - len(shared_prefix))
        prompt = np.concatenate([shared_prefix, tail]).astype(np.int32)
        req = Request(rid=i, prompt=prompt, max_new=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s), {engine.steps} engine steps")
    print(f"prefix cache: {engine.cache.hits} hits / {engine.cache.misses} misses")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
