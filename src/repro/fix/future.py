"""Future: the handle to an in-flight Fix submission.

Dependency-light on purpose — the cluster scheduler imports this module, so
it must not import the runtime (or anything above the stdlib).  Completion
callbacks and :func:`as_completed` are the coordination surface the
:class:`~repro.fix.backend.Backend` protocol builds on.

Callbacks run on whichever thread completes the future (the cluster's
scheduler thread, or a local backend's worker) — keep them cheap and never
block in one.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Iterable, Iterator, Optional


class CancelledError(RuntimeError):
    """The future's job was cancelled before producing a result."""


class DeadlineExceeded(TimeoutError):
    """The job's submit-time deadline elapsed before it finished.

    Unlike the plain :class:`TimeoutError` from ``result(timeout=...)`` —
    which only bounds the *caller's wait* — a deadline cancels the job
    itself: orphaned child work is pruned and the future is failed with
    this error on every waiter."""


class Future:
    """Result of a submitted Fix program.

    ``result()`` returns the result *Handle* (use ``Backend.fetch`` to decode
    it into a Python value).  ``out_type`` carries the static result type the
    frontend inferred at submit time, if any — ``fetch`` uses it to decode.

    ``_clock`` (set by the cluster at submit time, duck-typed — this module
    must stay import-light) makes deadlines clock-aware: under a virtual
    clock a ``timeout`` is *simulated* seconds, waited via the clock's
    deterministic event loop, so a virtual-clock program can neither
    wall-block on a timeout that never elapses in simulated time nor burn
    real seconds waiting for one that does.
    """

    def __init__(self):
        self._ev = threading.Event()
        self._lock = threading.Lock()
        self._result = None
        self._exc: Optional[BaseException] = None
        self._callbacks: list[Callable[["Future"], Any]] = []
        self.out_type = None  # static result type, set by the frontend
        self._clock = None    # set by clock-owning backends (cluster)
        # Backends that can prune in-flight work install a canceller:
        # ``_canceller(future)`` must eventually fail the future (the
        # cluster routes it through the scheduler thread so child
        # submissions are pruned too).  Without one, cancel() just fails
        # the future in place.
        self._canceller: Optional[Callable[["Future"], Any]] = None

    # ------------------------------------------------------------- setters
    def set(self, result) -> None:
        with self._lock:
            if self._ev.is_set():
                return  # first write wins (determinism makes dupes identical)
            self._result = result
            self._ev.set()
            callbacks, self._callbacks = self._callbacks, []
        self._run_callbacks(callbacks)

    def set_exception(self, exc: BaseException) -> None:
        with self._lock:
            if self._ev.is_set():
                return
            self._exc = exc
            self._ev.set()
            callbacks, self._callbacks = self._callbacks, []
        self._run_callbacks(callbacks)

    def _run_callbacks(self, callbacks) -> None:
        for cb in callbacks:
            try:
                cb(self)
            except Exception:  # noqa: BLE001 — a callback must not kill the setter
                pass

    # ------------------------------------------------------------- getters
    def _wait(self, timeout: Optional[float]) -> bool:
        clk = self._clock
        if clk is None or not getattr(clk, "is_virtual", False) or self._ev.is_set():
            return self._ev.wait(timeout)
        # Virtual clock: park on a clock event whose timeout elapses in
        # *simulated* seconds — time advances straight to the deadline when
        # the cluster is quiescent, and never before something earlier
        # could happen.
        waker = clk.make_event()
        cb = lambda _f: waker.set()  # noqa: E731 — identity matters for removal
        self.add_done_callback(cb)
        waker.wait(timeout)
        self._discard_callback(cb)  # a timed-out poll must not leak its waker
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = 120.0):
        if not self._wait(timeout):
            raise TimeoutError("fix job timed out")
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = 120.0) -> Optional[BaseException]:
        if not self._wait(timeout):
            raise TimeoutError("fix job timed out")
        return self._exc

    def done(self) -> bool:
        return self._ev.is_set()

    # ------------------------------------------------------------ cancel
    def cancel(self) -> bool:
        """Request cancellation.  Returns False if the future already
        completed; True once cancellation is underway (the future will
        complete with :class:`CancelledError`, possibly asynchronously —
        the cluster prunes orphaned child jobs on its scheduler thread)."""
        if self.done():
            return False
        canceller = self._canceller
        if canceller is not None:
            canceller(self)
        else:
            self.set_exception(CancelledError("future cancelled"))
        return True

    def cancelled(self) -> bool:
        return self.done() and isinstance(self._exc, CancelledError)

    def add_done_callback(self, fn: Callable[["Future"], Any]) -> None:
        """``fn(future)`` runs when the future completes (immediately if it
        already has)."""
        with self._lock:
            if not self._ev.is_set():
                self._callbacks.append(fn)
                return
        self._run_callbacks([fn])

    def _discard_callback(self, fn: Callable[["Future"], Any]) -> None:
        """Unregister a pending callback (timed-out waits must not leak)."""
        with self._lock:
            if fn in self._callbacks:
                self._callbacks.remove(fn)


def as_completed(futures: Iterable[Future],
                 timeout: Optional[float] = None) -> Iterator[Future]:
    """Yield futures as they finish, whichever order that happens in.

    ``timeout`` bounds the *total* wait; expiry raises :class:`TimeoutError`
    with the futures still pending left unconsumed.  When the futures carry
    a virtual clock, the bound is *simulated* seconds (see
    :meth:`Future._wait`).
    """
    futs = list(futures)
    clk = next((f._clock for f in futs
                if getattr(f._clock, "is_virtual", False)), None)
    if clk is not None:
        yield from _as_completed_virtual(clk, futs, timeout)
        return
    done_q: "queue.Queue[Future]" = queue.Queue()
    for f in futs:
        f.add_done_callback(done_q.put)
    deadline = None if timeout is None else time.monotonic() + timeout
    try:
        for _ in range(len(futs)):
            if deadline is None:
                yield done_q.get()
            else:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("as_completed timed out")
                try:
                    yield done_q.get(timeout=remaining)
                except queue.Empty:
                    raise TimeoutError("as_completed timed out") from None
    finally:
        for f in futs:  # a timed-out/abandoned iteration must not leak
            f._discard_callback(done_q.put)


def _as_completed_virtual(clk, futs: list, timeout: Optional[float]) -> Iterator[Future]:
    """Completion-order iteration in simulated time: completions and the
    (virtual) deadline land in one clock queue, so the expiry can only win
    when nothing else can happen first."""
    done_q = clk.make_queue()
    expired = object()
    for f in futs:
        f.add_done_callback(done_q.put)
    timer = None
    if timeout is not None:
        timer = clk.call_at(clk.now() + timeout, lambda: done_q.put(expired))
    try:
        for _ in range(len(futs)):
            got = done_q.get()
            if got is expired:
                raise TimeoutError("as_completed timed out")
            yield got
    finally:
        if timer is not None:
            timer.cancel()
        for f in futs:
            f._discard_callback(done_q.put)
