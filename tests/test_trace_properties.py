"""Randomized-schedule property suite: fuzz the runtime with seeded
workload/topology generators (tests/workloads.py) under the virtual clock
and check trace invariants instead of end results.

Fixed seeds (hypothesis-style explicit examples) run in tier-1; the CI
``fuzz`` job additionally runs one rotating seed per build — its value is
printed in the log, and a failing seed dumps its trace JSONL under
``fuzz-artifacts/`` for upload, so every failure is replayable with::

    FIX_FUZZ_SEED=<seed> PYTHONPATH=src python -m pytest \
        tests/test_trace_properties.py -k rotating
"""
import os
from pathlib import Path

import pytest

from repro.runtime import TraceRecorder, starvation_intervals, verify_invariants

import sys
sys.path.insert(0, str(Path(__file__).resolve().parent))
from workloads import make_spec, run_ab_case, run_workload  # noqa: E402

pytestmark = pytest.mark.usefixtures("no_thread_leaks")

SEEDS = list(range(20))            # the fixed "examples" tier-1 runs
INTERNAL_SEEDS = [0, 1, 2]         # internal-I/O ablation cases
AB_SEEDS = list(range(20))         # placement A/B topologies
AB_TOLERANCE = 1.10                # locality may lose ≤10% to bytes-missing


def _dump_on_failure(recorders: dict, tag: str):
    """Write the failing case's trace(s) where CI can upload them."""
    out = Path(os.environ.get("FIX_FUZZ_ARTIFACTS", "fuzz-artifacts"))
    out.mkdir(parents=True, exist_ok=True)
    for name, rec in recorders.items():
        rec.save(out / f"{tag}-{name}.jsonl")


def _check_seed(seed: int, io_mode: str = "external") -> None:
    """The full property bundle for one seed:

    * two runs of the same spec produce byte-identical JSONL traces and
      identical schedule summaries (determinism);
    * the trace passes every invariant in ``verify_invariants`` — no
      transfer toward a node already holding the content, bytes delivered
      equal bytes enqueued (requested minus dedup), every enqueued
      (dst, key) delivered exactly once, every job completes;
    * internal-I/O runs starve, and every positive starvation interval is
      attributable to the arrival of a blob the job declared.
    """
    spec = make_spec(seed, io_mode=io_mode)
    r1, r2 = TraceRecorder(), TraceRecorder()
    try:
        o1 = run_workload(spec, trace=r1)
        o2 = run_workload(spec, trace=r2)
        assert r1.to_jsonl() == r2.to_jsonl(), \
            f"seed {seed}: double-run traces differ"
        assert o1 == o2, f"seed {seed}: schedule summaries differ"
        violations = verify_invariants(r1.events)
        assert not violations, f"seed {seed}: {violations}"
        if io_mode == "internal":
            ivs = starvation_intervals(r1.events)
            assert o1["starved_frac"] > 0
            assert ivs
            for iv in ivs:
                if iv["end"] > iv["start"]:
                    assert iv["attributed"] in iv["declared"]
    except BaseException:
        # any failure class — assertion, scheduler crash, Future timeout,
        # pytest-timeout interrupt — must leave its trace for CI to upload
        _dump_on_failure({"run1": r1, "run2": r2},
                         f"{io_mode}-seed{seed}")
        raise


@pytest.mark.parametrize("seed", SEEDS)
def test_fuzz_schedule_invariants(seed):
    _check_seed(seed)


@pytest.mark.parametrize("seed", INTERNAL_SEEDS)
def test_fuzz_internal_io_starvation(seed):
    _check_seed(seed, io_mode="internal")


@pytest.mark.parametrize("seed", AB_SEEDS)
def test_locality_not_worse_than_bytes(seed):
    """Pins the PR-3 seconds-to-stage result as a *property*: across
    anchored heterogeneous topologies, locality placement never loses to
    the bytes-missing ablation on makespan beyond a small tolerance
    (empirically it wins 4–45×; the tolerance absorbs degenerate
    topologies, not regressions)."""
    mk_bytes = run_ab_case(seed, "bytes")["makespan"]
    mk_loc = run_ab_case(seed, "locality")["makespan"]
    assert mk_loc <= mk_bytes * AB_TOLERANCE, (
        f"seed {seed}: locality makespan {mk_loc:.4f}s vs "
        f"bytes {mk_bytes:.4f}s exceeds tolerance {AB_TOLERANCE}")


def test_rotating_seed_fuzz(capsys):
    """CI-only: one fresh seed per build, printed for reproduction.  Local
    runs (no FIX_FUZZ_SEED in the environment) skip."""
    raw = os.environ.get("FIX_FUZZ_SEED")
    if raw is None:
        pytest.skip("rotating fuzz seed not set (CI fuzz job exports "
                    "FIX_FUZZ_SEED)")
    seed = int(raw)
    with capsys.disabled():
        print(f"\n[fuzz] rotating seed: {seed}  (repro: FIX_FUZZ_SEED={seed} "
              f"PYTHONPATH=src python -m pytest "
              f"tests/test_trace_properties.py -k rotating)")
    _check_seed(seed)
    _check_seed(seed, io_mode="internal")
    mk_bytes = run_ab_case(seed, "bytes")["makespan"]
    mk_loc = run_ab_case(seed, "locality")["makespan"]
    assert mk_loc <= mk_bytes * AB_TOLERANCE
