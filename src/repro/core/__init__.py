"""Fix core: the paper's computation model.

Handles (packed 32-byte ABI), content-addressed Repositories with memo
tables (plus complete-footprint caches feeding the runtime's transfer
scheduler), the Table-1 API as a sealed capability, the codelet registry,
and the Evaluator implementing Thunk/Encode reduction semantics.
"""
from .api import AccessViolation, FixAPI
from .evaluator import Evaluator, FixError
from .handle import (
    APPLICATION,
    BLOB,
    Handle,
    IDENTIFICATION,
    OBJECT,
    REF,
    SELECTION,
    SHALLOW,
    STRICT,
    TREE,
)
from .procedures import (
    handle_for,
    make_limits,
    name_of,
    parse_limits,
    procedure_blob,
    register,
    registered_names,
    resolve,
)
from .repository import CorruptData, Footprint, MissingData, Repository

__all__ = [
    "AccessViolation", "FixAPI", "Evaluator", "FixError", "Handle",
    "BLOB", "TREE", "OBJECT", "REF", "APPLICATION", "IDENTIFICATION",
    "SELECTION", "STRICT", "SHALLOW",
    "CorruptData", "Footprint", "MissingData", "Repository",
    "register", "resolve", "handle_for", "name_of", "procedure_blob",
    "registered_names", "make_limits", "parse_limits",
]
