"""One Backend protocol over the local Evaluator and the Cluster.

``fix.local()`` and ``fix.on(cluster)`` expose the same four operations —

* ``submit(program) -> Future``   — compile a :class:`~repro.fix.lazy.Lazy`
  graph (or accept a raw Handle) against the backend's client repository,
  wrap it in a strict Encode if needed, and hand it to the engine.  One
  submission per program, however deep.
* ``evaluate(program) -> Handle`` — submit + wait; the content-addressed
  result name.
* ``fetch(source) -> value``      — localize the result's bytes (charged
  with link costs on a cluster) and decode them using the program's static
  result type; ``run()`` is the submit+fetch convenience.
* ``as_completed(futures)``       — completion-order iteration.

The protocol deliberately has no escape hatch into engine internals: a
program that runs on ``fix.local()`` runs unchanged on ``fix.on(cluster)``
(asserted by tests/test_fix_backend.py), because both sides consume the
same compiled Table-1 representation.

This module must not import :mod:`repro.runtime` — the cluster imports
*us* (its ``submit``/``evaluate``/``fetch_result`` are thin delegates to
:class:`ClusterBackend`), so the cluster side is duck-typed here.
"""
from __future__ import annotations

import abc
import queue
import threading
from typing import Any, Iterable, Optional

from ..core import Evaluator, Repository
from ..core.handle import BLOB, TREE, Handle
from .future import DeadlineExceeded, Future, as_completed
from .lazy import Lazy
from .marshal import MarshalError, _element_hints, unmarshal

_USE_STATIC = object()  # sentinel: "decode with the program's static type"


class Backend(abc.ABC):
    """The one submission surface for Fix programs."""

    # ------------------------------------------------------------ protocol
    @property
    @abc.abstractmethod
    def repo(self) -> Repository:
        """The client repository programs compile against."""

    @abc.abstractmethod
    def submit(self, program, *, deadline_s: Optional[float] = None,
               tenant: Optional[str] = None) -> Future:
        """Compile ``program`` (Lazy or Handle) and start evaluating it.

        ``deadline_s`` bounds the whole job in backend-clock seconds from
        submission (simulated seconds on a virtual-clock cluster): expiry
        fails the future with :class:`~repro.fix.future.DeadlineExceeded`
        and — where the backend can — cancels orphaned child work.

        ``tenant`` is an opaque accounting tag: backends with a trace plane
        thread it onto the job's ``job_submit``/``job_memo_hit`` events (and
        child jobs inherit it), so per-tenant latency/starvation reports
        fall out of ordinary trace analysis
        (:func:`repro.runtime.trace.tenant_report`).  Semantics are
        unaffected — same program, same content keys, same memoization."""

    def evaluate(self, program, timeout: Optional[float] = 120.0) -> Handle:
        """Submit and wait; returns the result Handle."""
        return self.submit(program).result(timeout)

    def fetch(self, source, as_type: Any = _USE_STATIC,
              timeout: Optional[float] = 120.0) -> Any:
        """Result bytes, decoded to a Python value.

        ``source`` may be a Future (waits for it), a result Handle, or a
        Lazy program (submitted first).  ``as_type`` overrides the decode
        annotation; by default a Future's statically-inferred type is used,
        and with no type at all blobs decode to ``bytes`` and trees to
        tuples.
        """
        if isinstance(source, Lazy):
            source = self.submit(source)
        if isinstance(source, Future):
            handle = source.result(timeout)
            if as_type is _USE_STATIC:
                as_type = source.out_type
        else:
            handle = source
            if as_type is _USE_STATIC:
                as_type = None
        if not isinstance(handle, Handle):
            raise MarshalError(f"cannot fetch {type(handle).__name__}")
        if handle.is_ref():
            handle = handle.as_object()  # fetch = demand the bytes
        self._localize(handle)
        return unmarshal(self.repo, handle, as_type)

    def run(self, program, timeout: Optional[float] = 120.0) -> Any:
        """submit + fetch: the one-liner for "give me the value"."""
        return self.fetch(self.submit(program), timeout=timeout)

    def fetch_stream(self, source, as_type: Any = _USE_STATIC,
                     timeout: Optional[float] = 120.0):
        """Yield a Tree result's children as their bytes arrive.

        Where :meth:`fetch` localizes the whole closure before decoding
        anything, this generator pulls only the tree *node* up front
        (:meth:`_localize_shallow`), then localizes and decodes one child
        per iteration — so a consumer starts working on child 0 while
        children 1..n-1 are still remote, and a consumer that stops early
        never pays for the tail.  Non-tree results yield exactly one
        value (the plain ``fetch``), so callers can stream unconditionally.
        """
        if isinstance(source, Lazy):
            source = self.submit(source)
        if isinstance(source, Future):
            handle = source.result(timeout)
            if as_type is _USE_STATIC:
                as_type = source.out_type
        else:
            handle = source
            if as_type is _USE_STATIC:
                as_type = None
        if not isinstance(handle, Handle):
            raise MarshalError(f"cannot fetch {type(handle).__name__}")
        if handle.is_ref():
            handle = handle.as_object()
        if handle.content_type != TREE or not handle.is_data():
            self._localize(handle)
            yield unmarshal(self.repo, handle, as_type)
            return
        self._localize_shallow(handle)
        kids = self.repo.get_tree(handle)
        hints = (_element_hints(as_type, len(kids))
                 if as_type not in (None, tuple, list) else [None] * len(kids))
        for kid, hint in zip(kids, hints):
            child = kid.as_object() if kid.is_ref() else kid
            if child.is_data() and not child.is_literal:
                self._localize(child)
            yield unmarshal(self.repo, child, hint)

    @staticmethod
    def as_completed(futures: Iterable[Future],
                     timeout: Optional[float] = None):
        return as_completed(futures, timeout)

    def stats(self) -> dict:
        """One live telemetry snapshot, same shape on every backend:
        ``backend`` (which engine), ``metrics`` (a
        :class:`~repro.runtime.telemetry.MetricsRegistry` snapshot — may
        be empty), and ``codelets`` (per-codelet wall accounting,
        ``name -> {"count", "total_ns"}``), plus backend-specific
        sections.  This is what ``repro.obs.top`` renders."""
        return {"backend": "none", "metrics": {}, "codelets": {}}

    # ---------------------------------------------------------- internals
    @abc.abstractmethod
    def _localize(self, handle: Handle) -> None:
        """Make ``handle``'s bytes resident in :attr:`repo`."""

    def _localize_shallow(self, handle: Handle) -> None:
        """Make only ``handle``'s *own* content resident (a tree node
        without its children) — the streaming-fetch hop.  Backends without
        a cheaper path fall back to the full closure."""
        self._localize(handle)

    def _compile(self, program) -> tuple[Handle, Any]:
        """(top-level Encode handle, static result type) for a program."""
        out_type = None
        if isinstance(program, Lazy):
            h = program.compile(self.repo)
            out_type = program.out_type
        elif isinstance(program, Handle):
            h = program
        else:
            raise MarshalError(
                f"a program is a Lazy expression or a Handle, not "
                f"{type(program).__name__}")
        if h.is_thunk():
            h = h.strict()
        elif h.is_data():
            h = h.identification().strict()
        return h, out_type

    # --------------------------------------------------------- lifecycle
    def close(self) -> None:  # pragma: no cover - overridden where needed
        pass

    def __enter__(self) -> "Backend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class LocalBackend(Backend):
    """Single-process backend: the paper's semantics with zero deployment.

    Submissions run on one daemon worker thread over a private
    :class:`~repro.core.evaluator.Evaluator`, so ``submit`` is asynchronous
    and ``as_completed`` behaves like the cluster's."""

    def __init__(self, repo: Optional[Repository] = None):
        self._repo = repo if repo is not None else Repository("local")
        self.evaluator = Evaluator(self._repo)
        self._q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._closed = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="fix-local")
        self._thread.start()

    @property
    def repo(self) -> Repository:
        return self._repo

    def submit(self, program, *, deadline_s: Optional[float] = None,
               tenant: Optional[str] = None) -> Future:
        if self._closed:
            raise RuntimeError("backend is closed")
        del tenant  # no trace plane locally; accepted for portability
        encode, out_type = self._compile(program)
        fut = Future()
        fut.out_type = out_type
        if deadline_s is not None:
            # Local evaluation is uninterruptible (one synchronous
            # evaluator call), so a deadline can only fail the future;
            # the worker skips items whose future already completed.
            timer = threading.Timer(
                deadline_s, lambda: fut.set_exception(
                    DeadlineExceeded("job deadline exceeded")))
            timer.daemon = True
            timer.start()
            fut.add_done_callback(lambda _f: timer.cancel())
        self._q.put((encode, fut))
        return fut

    def evaluate(self, program, timeout: Optional[float] = 120.0) -> Handle:
        """With a timeout, runs through the worker so the bound is honored
        (same portability contract as the cluster).  ``timeout=None`` is the
        synchronous fast path: inline on the calling thread, unbounded
        (memoization is first-write-wins, so racing the worker is safe)."""
        if timeout is not None:
            return self.submit(program).result(timeout)
        encode, _ = self._compile(program)
        return self.evaluator.evaluate(encode)

    def _localize(self, handle: Handle) -> None:
        pass  # results are already local

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            encode, fut = item
            if fut.done():
                continue  # deadline expired (or cancelled) while queued
            try:
                fut.set(self.evaluator.evaluate(encode))
            except BaseException as e:  # noqa: BLE001 — delivered via the future
                fut.set_exception(e)

    def stats(self) -> dict:
        # codelet table inlined from the evaluator (this module cannot
        # import repro.runtime, where CodeletProfile lives)
        return {
            "backend": "local",
            "metrics": {},
            "codelets": {name: {"count": ent[0], "total_ns": ent[1]}
                         for name, ent
                         in sorted(self.evaluator.codelets.items())},
            "evaluator": self.evaluator.stats(),
        }

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._q.put(None)
            self._thread.join(timeout=5)


class ClusterBackend(Backend):
    """Backend over a :class:`~repro.runtime.cluster.Cluster` (duck-typed).

    Owns the client-facing halves the scheduler shouldn't: program
    compilation, result fetch (charged with link latency/serialization and
    *accounted* in ``cluster.transfers`` / ``cluster.bytes_moved``), and
    decode.  ``Cluster.submit/evaluate/fetch_result`` delegate here."""

    def __init__(self, cluster):
        self.cluster = cluster

    @property
    def repo(self) -> Repository:
        return self.cluster.client_repo

    def submit(self, program, *, deadline_s: Optional[float] = None,
               tenant: Optional[str] = None) -> Future:
        encode, out_type = self._compile(program)
        fut = self.cluster._submit_encode(encode, deadline_s=deadline_s,
                                          tenant=tenant)
        fut.out_type = out_type
        return fut

    def _localize(self, handle: Handle) -> None:
        self.fetch_result(handle)

    def _localize_shallow(self, handle: Handle) -> None:
        """One tree node's bytes (children stay remote), paying and
        accounting the link cost of just those bytes — what makes
        ``fetch_stream`` incremental on a cluster."""
        c = self.cluster
        if handle.is_ref():
            handle = handle.as_object()
        if handle.is_literal or c.client_repo.contains(handle):
            return
        src = c._find_source_name(handle)
        if src is None or src == "client":
            return
        size = handle.size if handle.content_type == BLOB else 32 * handle.size
        link = c.network.link(src, "client")
        c.clock.sleep(link.latency_s + link.serialized_s(size))
        payload = c.nodes[src].repo.raw_payload(handle)
        if c.client_repo.put_handle_data(handle, payload):
            c._account_transfer(1, size)

    def fetch_result(self, handle: Handle,
                     into: Optional[Repository] = None) -> Repository:
        """Pull a result's bytes to the client (or ``into``), paying and
        accounting the link costs — result-fetch traffic shows up in
        ``transfers``/``bytes_moved`` like any other movement."""
        c = self.cluster
        into = into if into is not None else c.client_repo
        if handle.is_ref():
            handle = handle.as_object()  # fetching = demanding the bytes
        src = c._find_source_name(handle)
        if src is not None and src != "client":
            link = c.network.link(src, "client")
            size = c._deep_size(handle)
            c.clock.sleep(link.latency_s + link.serialized_s(size))
            moved = c.nodes[src].repo.export(handle, into)
            if moved:
                c._account_transfer(1, moved)
        return into

    def stats(self) -> dict:
        return self.cluster.stats()

    def close(self) -> None:
        self.cluster.shutdown()


def local(repo: Optional[Repository] = None) -> LocalBackend:
    """A fresh single-process backend."""
    return LocalBackend(repo)


def on(cluster) -> ClusterBackend:
    """The backend view of a running cluster (``cluster.backend`` is the
    same object the cluster's own thin delegates use)."""
    backend = getattr(cluster, "backend", None)
    return backend if isinstance(backend, ClusterBackend) else ClusterBackend(cluster)
