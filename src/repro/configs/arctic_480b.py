"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base]: 35L d7168
56H GQA(kv=8) + dense residual MLP in parallel with a 128-expert top-2 MoE
(expert ff 4864), v32000."""
import jax.numpy as jnp

from ..models import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=4864, vocab=32000, n_experts=128, top_k=2,
    d_ff_expert=4864, dense_residual=True, rope_theta=1e4,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=96, vocab=512, n_experts=8, top_k=2, d_ff_expert=48,
    dense_residual=True,
)

# dry-run step configuration for the full-scale cells
DRYRUN = dict(microbatches=8, remat="full", optimizer="adafactor")
