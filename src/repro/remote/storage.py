"""Content-addressed object stores: the remote backend's storage plane.

The paper's externalized-I/O claim needs a *platform-owned* data plane:
workers never talk to each other — every byte a worker consumes comes from
the store, and every byte it produces goes back to the store before the
coordinator learns the result.  This module provides that plane:

* :class:`ObjectStore` — the small interface (put / get / contains), keyed
  by ``Handle.content_key()`` so an Object, a Ref and a Thunk over the same
  bytes share one entry and the strict-memo / dedup machinery works
  unchanged across process boundaries.  Payloads are canonical bytes (blob
  bytes, or the concatenation of a tree's 32-byte child handles), so every
  ``put`` is verified against the handle's own digest — the handle is its
  own checksum, exactly like ``Repository.put_handle_data``.
* :class:`MemoryStore` — in-memory dict store (the server-backed default).
* :class:`FileStore` — one file per content key under a directory, written
  atomically (tmp + rename); persistent across backends, so two runs of
  the same program share content — cross-run dedup for free.
* :class:`StoreServer` — serves worker connections over the framed
  protocol (`fetch`/`put`/`contains`), one thread per worker socket.  Put
  *notifications* fire on every fresh insert, whatever side it came from —
  this is what feeds the scheduler's LocationIndex instead of in-process
  repository listeners.
* :class:`StoreClient` — the worker-side stub.

Stores are deliberately ignorant of interpretations, memoization and
scheduling: content in, content out, notify on fresh.
"""
from __future__ import annotations

import abc
import os
import tempfile
import threading
from typing import Callable, Optional

from ..core.handle import BLOB, Handle, _hash
from ..core.repository import CorruptData
from .protocol import ProtocolError, recv_msg, send_msg


class StoreError(RuntimeError):
    """A payload failed content verification, or the store is unusable."""


def payload_nbytes(handle: Handle) -> int:
    """Wire/accounting size of a handle's canonical payload."""
    return handle.size if handle.content_type == BLOB else 32 * handle.size


def verify_payload(handle: Handle, payload: bytes) -> bool:
    """Canonical bytes hash to the handle's digest (and match its size)?

    Works uniformly for blobs and trees because a tree's canonical bytes
    *are* the concatenation of its children's raw handles.
    """
    if handle.is_literal:
        return payload == handle.literal_payload()
    if len(payload) != payload_nbytes(handle):
        return False
    return _hash(payload) == handle.digest


def decode_tree_payload(payload: bytes) -> tuple[Handle, ...]:
    """Concatenated 32-byte raws -> Handle tuple (for Repository install)."""
    if len(payload) % 32:
        raise StoreError(f"tree payload of {len(payload)} bytes is not 32-aligned")
    return tuple(Handle(payload[i:i + 32]) for i in range(0, len(payload), 32))


def encode_tree_payload(children) -> bytes:
    return b"".join(k.raw for k in children)


class ObjectStore(abc.ABC):
    """Content-addressed key/value store with fresh-put notifications.

    Listeners are called as ``fn(handle, nbytes, src)`` after every fresh
    insert — ``src`` names who produced the bytes ("client" or a worker
    id).  The remote scheduler subscribes here to feed its LocationIndex
    and emit trace ``put`` events: store notifications replace in-process
    repository put listeners as the residency ground truth.
    """

    def __init__(self):
        self._listeners: list[Callable[[Handle, int, str], None]] = []
        self.puts = 0
        self.gets = 0
        self.dup_puts = 0
        # Re-hash every payload on read; CorruptData instead of rot.  Off
        # by default (content is immutable and put-verified), switched on
        # by the backend when a chaos plane can rot payloads at rest —
        # parity with ``Repository.verify_reads``.
        self.verify_reads = False

    def add_put_listener(self, fn: Callable[[Handle, int, str], None]) -> None:
        self._listeners.append(fn)

    def _notify(self, handle: Handle, nbytes: int, src: str) -> None:
        for fn in self._listeners:
            fn(handle, nbytes, src)

    def put(self, handle: Handle, payload: bytes, src: str = "client") -> bool:
        """Install verified content; returns True when it was fresh."""
        if handle.is_literal:
            return False  # literals live inside their handles
        if not verify_payload(handle, payload):
            raise StoreError(f"payload does not match {handle!r}")
        self.puts += 1
        fresh = self._install(handle.content_key(), bytes(payload))
        if fresh:
            self._notify(handle, payload_nbytes(handle), src)
        else:
            self.dup_puts += 1
        return fresh

    def get(self, handle: Handle) -> Optional[bytes]:
        """Canonical payload bytes, or None when absent.

        With :attr:`verify_reads` on, the payload is re-hashed against the
        handle's digest and a mismatch raises
        :class:`~repro.core.repository.CorruptData` — rot is *detected*,
        never served."""
        if handle.is_literal:
            return handle.literal_payload()
        self.gets += 1
        payload = self._read(handle.content_key())
        if (payload is not None and self.verify_reads
                and not verify_payload(handle, payload)):
            raise CorruptData(handle)
        return payload

    def contains(self, handle: Handle) -> bool:
        if handle.is_literal:
            return True
        return self._has(handle.content_key())

    def delete(self, handle: Handle) -> bool:
        """Evict one object (quarantine of a rotten replica); True when an
        entry was actually removed.  A later ``put`` of verified content
        re-installs it as fresh."""
        if handle.is_literal:
            return False
        return self._delete(handle.content_key())

    # ------------------------------------------------------------- backend
    @abc.abstractmethod
    def _install(self, key: bytes, payload: bytes) -> bool:
        """Store payload under key; True when the key was new."""

    @abc.abstractmethod
    def _read(self, key: bytes) -> Optional[bytes]: ...

    @abc.abstractmethod
    def _has(self, key: bytes) -> bool: ...

    @abc.abstractmethod
    def _delete(self, key: bytes) -> bool:
        """Remove the entry; True when it existed."""

    @abc.abstractmethod
    def _corrupt(self, key: bytes) -> bool:
        """Flip a byte of the stored payload *in place* (at-rest rot) —
        the chaos plane's hook; True when an entry was rotted."""

    @abc.abstractmethod
    def stats(self) -> dict: ...

    def close(self) -> None:  # pragma: no cover - overridden where needed
        pass


class MemoryStore(ObjectStore):
    """The in-memory server-backed store (default for ``fix.remote()``)."""

    def __init__(self, *, verify_reads: bool = False):
        super().__init__()
        self._data: dict[bytes, bytes] = {}
        self._lock = threading.Lock()
        self.verify_reads = verify_reads

    def _install(self, key: bytes, payload: bytes) -> bool:
        with self._lock:
            if key in self._data:
                return False
            self._data[key] = payload
            return True

    def _read(self, key: bytes) -> Optional[bytes]:
        with self._lock:
            return self._data.get(key)

    def _has(self, key: bytes) -> bool:
        with self._lock:
            return key in self._data

    def _delete(self, key: bytes) -> bool:
        with self._lock:
            return self._data.pop(key, None) is not None

    def _corrupt(self, key: bytes) -> bool:
        with self._lock:
            payload = self._data.get(key)
            if not payload:
                return False
            rotted = bytearray(payload)
            rotted[0] ^= 0xFF
            self._data[key] = bytes(rotted)
            return True

    def stats(self) -> dict:
        with self._lock:
            return {
                "objects": len(self._data),
                "bytes": sum(len(v) for v in self._data.values()),
                "puts": self.puts, "gets": self.gets,
                "dup_puts": self.dup_puts,
            }


class FileStore(ObjectStore):
    """One file per content key under ``root`` — a local-filesystem store.

    Writes are durable *then* atomic: payload bytes are fsynced to the
    temp file before the rename installs it (and the directory entry is
    fsynced after), so a crashed writer never leaves a torn object and a
    machine crash never leaves an installed name pointing at unflushed
    bytes.  Because names are content keys a half-written temp file can
    never be served.  The directory outlives the backend: a second run of
    the same program finds its inputs (and any memoizable intermediate
    content) already present.

    ``verify_reads=True`` re-hashes every payload against its content key
    on read (:class:`~repro.core.repository.CorruptData` on mismatch) —
    bit-rot on disk is detected, quarantined and recomputed instead of
    silently feeding a computation.
    """

    def __init__(self, root: str, *, verify_reads: bool = False):
        super().__init__()
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self.verify_reads = verify_reads

    def _path(self, key: bytes) -> str:
        return os.path.join(self.root, key.hex())

    def _install(self, key: bytes, payload: bytes) -> bool:
        path = self._path(key)
        with self._lock:
            if os.path.exists(path):
                return False
            fd, tmp = tempfile.mkstemp(dir=self.root, prefix=".tmp-")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(payload)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
                self._fsync_dir()
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            return True

    def _fsync_dir(self) -> None:
        # the rename itself must survive a crash, not just the bytes
        try:
            dfd = os.open(self.root, os.O_RDONLY)
        except OSError:  # pragma: no cover - platform without dir-open
            return
        try:
            os.fsync(dfd)
        except OSError:  # pragma: no cover - fs without dir-fsync
            pass
        finally:
            os.close(dfd)

    def _read(self, key: bytes) -> Optional[bytes]:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def _has(self, key: bytes) -> bool:
        return os.path.exists(self._path(key))

    def _delete(self, key: bytes) -> bool:
        with self._lock:
            try:
                os.unlink(self._path(key))
                return True
            except FileNotFoundError:
                return False

    def _corrupt(self, key: bytes) -> bool:
        with self._lock:
            path = self._path(key)
            try:
                with open(path, "r+b") as f:
                    first = f.read(1)
                    if not first:
                        return False
                    f.seek(0)
                    f.write(bytes([first[0] ^ 0xFF]))
                return True
            except FileNotFoundError:
                return False

    def stats(self) -> dict:
        n = nbytes = 0
        with os.scandir(self.root) as it:
            for entry in it:
                if entry.name.startswith("."):
                    continue
                n += 1
                nbytes += entry.stat().st_size
        return {"objects": n, "bytes": nbytes, "puts": self.puts,
                "gets": self.gets, "dup_puts": self.dup_puts}


# ------------------------------------------------------------------ server
class StoreServer:
    """Serves worker store connections over the framed protocol.

    One daemon thread per worker socket, answering ``fetch`` / ``put`` /
    ``contains`` in order.  ``mutex`` (supplied by the backend) serializes
    worker puts against the coordinator's own staging so residency checks
    and put notifications interleave atomically — the trace invariant
    "never enqueue toward a node already holding the key" depends on it.
    """

    def __init__(self, store: ObjectStore, mutex: Optional[threading.Lock] = None):
        self.store = store
        self._mutex = mutex if mutex is not None else threading.Lock()
        self._threads: list[threading.Thread] = []
        self._socks: list = []
        # Called as ``fn(handle, peer)`` when a fetch hit rot (the store's
        # verify_reads tripped).  The backend installs its quarantine +
        # recovery hook here; the server itself just refuses to serve the
        # bytes (the peer sees "absent", never the rot).
        self.on_corrupt: Optional[Callable[[Handle, str], None]] = None

    def serve(self, sock, peer: str) -> None:
        t = threading.Thread(target=self._serve_loop, args=(sock, peer),
                             daemon=True, name=f"fix-store-{peer}")
        self._socks.append(sock)
        self._threads.append(t)
        t.start()

    def _serve_loop(self, sock, peer: str) -> None:
        try:
            while True:
                msg = recv_msg(sock)
                if msg is None:
                    return
                op = msg.get("op")
                if op == "fetch":
                    h = Handle(msg["raw"])
                    try:
                        payload = self.store.get(h)
                    except CorruptData:
                        payload = None
                        if self.on_corrupt is not None:
                            self.on_corrupt(h, peer)
                    send_msg(sock, {"payload": payload})
                elif op == "put":
                    h = Handle(msg["raw"])
                    try:
                        with self._mutex:
                            fresh = self.store.put(h, msg["payload"], src=peer)
                        send_msg(sock, {"ok": True, "fresh": fresh})
                    except StoreError as e:
                        send_msg(sock, {"ok": False, "error": str(e)})
                elif op == "contains":
                    send_msg(sock, {"ok": self.store.contains(Handle(msg["raw"]))})
                else:
                    send_msg(sock, {"ok": False, "error": f"unknown op {op!r}"})
        except (OSError, ProtocolError):
            return  # peer vanished: the backend reaps the worker separately

    def close(self) -> None:
        for s in self._socks:
            try:
                s.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=5)


# ------------------------------------------------------------------ client
class StoreClient:
    """Worker-side store stub: synchronous request/response on one socket."""

    def __init__(self, sock):
        self._sock = sock

    def fetch(self, handle: Handle) -> Optional[bytes]:
        if handle.is_literal:
            return handle.literal_payload()
        send_msg(self._sock, {"op": "fetch", "raw": handle.raw})
        reply = recv_msg(self._sock)
        if reply is None:
            raise StoreError("store connection closed")
        return reply.get("payload")

    def put(self, handle: Handle, payload: bytes) -> bool:
        if handle.is_literal:
            return False
        send_msg(self._sock, {"op": "put", "raw": handle.raw, "payload": payload})
        reply = recv_msg(self._sock)
        if reply is None:
            raise StoreError("store connection closed")
        if not reply.get("ok"):
            raise StoreError(reply.get("error", "store put rejected"))
        return bool(reply.get("fresh"))

    def contains(self, handle: Handle) -> bool:
        if handle.is_literal:
            return True
        send_msg(self._sock, {"op": "contains", "raw": handle.raw})
        reply = recv_msg(self._sock)
        if reply is None:
            raise StoreError("store connection closed")
        return bool(reply.get("ok"))
