"""Virtual-clock engine tests: primitive semantics, run-to-run determinism,
wall/virtual equivalence, clock-aware deadlines, and the seconds-to-stage
placement model the clock makes affordable to exercise.

Every test here runs under the shared ``no_thread_leaks`` flake guard
(tests/conftest.py): clusters and clocks must drain all of their threads —
scheduler, node workers, link workers, timer threads — before the test
ends, so one test's parked participants can never corrupt another's
timeline."""
import time

import pytest

import repro.fix as fix
from repro.core import Handle
from repro.core.stdlib import add, checksum_tree, fib, inc_chain
from repro.fix.future import Future, as_completed
from repro.runtime import Cluster, Link, Network, VirtualClock

pytestmark = pytest.mark.usefixtures("no_thread_leaks")


def _staged_jobs(c: Cluster, n_jobs: int, inputs_per_job: int = 6,
                 blob_kb: int = 24):
    """Per-job private input trees parked on s0 (placement-independent
    bytes: everything ships from storage whatever the schedule)."""
    store = c.nodes["s0"].repo
    jobs = []
    for j in range(n_jobs):
        blobs = [store.put_blob(bytes([j % 251, i % 251]) + b"v" * (blob_kb * 1024 - 2))
                 for i in range(inputs_per_job)]
        jobs.append(checksum_tree(store.put_tree(blobs)))
    return jobs


def _run_staged(c: Cluster, n_jobs: int = 8) -> dict:
    try:
        be = fix.on(c)
        jobs = _staged_jobs(c, n_jobs)
        c.reset_accounting()
        t0 = c.clock.now()
        futs = [be.submit(j) for j in jobs]
        results = [f.result(timeout=120) for f in futs]
        makespan = c.clock.now() - t0
        util = c.utilization(makespan)
        return {
            "makespan": makespan,
            "transfers": c.transfers,
            "bytes_moved": c.bytes_moved,
            "busy_frac": util["busy_frac"],
            "starved_frac": util["starved_frac"],
            "idle_frac": util["idle_iowait_frac"],
            "results": tuple(h.raw for h in results),
        }
    finally:
        c.shutdown()
        if c.clock.is_virtual:
            c.clock.close()


class TestVirtualClockPrimitives:
    def test_sleep_advances_simulated_time_instantly(self):
        clk = VirtualClock()
        clk.register_current()
        t0 = time.perf_counter()
        clk.sleep(30.0)
        assert time.perf_counter() - t0 < 1.0  # real time: none of the 30 s
        assert clk.now() == pytest.approx(30.0)
        clk.close()

    def test_call_at_fires_in_time_then_seq_order(self):
        clk = VirtualClock()
        clk.register_current()
        fired = []
        clk.call_at(2.0, lambda: fired.append("b"))
        clk.call_at(1.0, lambda: fired.append("a"))
        clk.call_at(2.0, lambda: fired.append("c"))  # same time: submit order
        clk.sleep(5.0)  # quiescent; the heap drains in (time, seq) order
        assert fired == ["a", "b", "c"]
        assert clk.now() == pytest.approx(5.0)
        clk.close()

    def test_cancelled_timer_does_not_fire(self):
        clk = VirtualClock()
        clk.register_current()
        fired = []
        t = clk.call_at(1.0, lambda: fired.append("x"))
        t.cancel()
        clk.sleep(2.0)
        assert fired == []
        clk.close()

    def test_event_wait_timeout_in_simulated_seconds(self):
        clk = VirtualClock()
        clk.register_current()
        ev = clk.make_event()
        t0 = time.perf_counter()
        assert ev.wait(timeout=10.0) is False  # expires in simulated time
        assert clk.now() == pytest.approx(10.0)
        assert time.perf_counter() - t0 < 1.0
        clk.call_at(12.0, ev.set)
        assert ev.wait(timeout=100.0) is True  # set beats the deadline
        assert clk.now() == pytest.approx(12.0)
        clk.close()

    def test_spawned_thread_sleeps_in_virtual_time(self):
        clk = VirtualClock()
        clk.register_current()
        log = []
        def worker():
            clk.sleep(1.0)
            log.append(("worker", clk.now()))
        clk.spawn(worker, name="t")
        clk.sleep(2.0)
        log.append(("main", clk.now()))
        assert log == [("worker", 1.0), ("main", 2.0)]
        clk.close()

    def test_foreign_thread_sleep_on_idle_clock_does_not_hang(self):
        """A never-registered thread sleeping while every participant is
        quiescent must still wake (adopted threads ride the event heap)."""
        import threading
        clk = VirtualClock()
        woke = []
        t = threading.Thread(target=lambda: (clk.sleep(0.5), woke.append(clk.now())),
                             daemon=True)
        t.start()
        t.join(timeout=5)
        assert woke == [0.5] and not t.is_alive()
        clk.close()

    def test_register_after_adoption_promotes_instead_of_hanging(self):
        """A thread adopted by an earlier primitive wait can later register
        as the driver (e.g. two clusters built on one shared clock)."""
        clk = VirtualClock()
        clk.sleep(0.25)       # adopts the calling thread
        clk.register_current()  # must promote, not deadlock
        clk.sleep(0.25)       # and the promoted driver still participates
        assert clk.now() == pytest.approx(0.5)
        clk.close()

    def test_shutdown_leaves_shared_clock_running(self):
        """One clock, two clusters: the first shutdown must not freeze the
        second cluster's timeline."""
        clk = VirtualClock()
        c1 = Cluster(n_nodes=1, clock=clk)
        c2 = Cluster(n_nodes=2, clock=clk)
        try:
            assert fix.on(c1).run(add(1, 2), timeout=30) == 3
            c1.shutdown()
            assert fix.on(c2).run(add(20, 22), timeout=30) == 42
        finally:
            c2.shutdown()
            clk.close()


class TestDeterminism:
    def test_identical_virtual_runs_bit_identical(self):
        """Two runs of the same workload on fresh virtual clusters agree on
        makespan, transfer count, bytes moved, utilization fractions and
        results — exactly, not approximately."""
        runs = []
        for _ in range(2):
            net = Network(Link(latency_s=0.002, gbps=0.5),
                          overrides={("s0", "n1"): Link(0.02, 0.1)})
            c = Cluster(n_nodes=3, workers_per_node=1, storage_nodes=("s0",),
                        network=net, clock=VirtualClock())
            runs.append(_run_staged(c))
        assert runs[0] == runs[1]
        assert runs[0]["makespan"] > 0

    def test_internal_io_starvation_deterministic(self):
        """Virtual starved-time accounting (slots held during modeled
        fetches) reproduces exactly across runs."""
        runs = []
        for _ in range(2):
            net = Network(Link(latency_s=0.01, gbps=0.5))
            c = Cluster(n_nodes=2, workers_per_node=1, storage_nodes=("s0",),
                        io_mode="internal", network=net, clock=VirtualClock())
            runs.append(_run_staged(c, n_jobs=6))
        assert runs[0] == runs[1]
        assert runs[0]["starved_frac"] > 0


class TestWallEquivalence:
    def test_same_transfer_schedule_wall_vs_virtual(self):
        """A small topology moves exactly the same bytes in the same number
        of wire transfers whether time is real or simulated."""
        outs = {}
        for label, clock in (("wall", None), ("virtual", VirtualClock())):
            net = Network(Link(latency_s=0.002, gbps=1.0))
            c = Cluster(n_nodes=2, workers_per_node=1, storage_nodes=("s0",),
                        network=net, clock=clock)
            outs[label] = _run_staged(c, n_jobs=6)
        assert outs["wall"]["transfers"] == outs["virtual"]["transfers"]
        assert outs["wall"]["bytes_moved"] == outs["virtual"]["bytes_moved"]
        assert outs["wall"]["results"] == outs["virtual"]["results"]


class TestClockAwareDeadlines:
    def test_future_timeout_elapses_in_simulated_time(self):
        """A timeout on a never-completing future fires after *simulated*
        seconds — immediately in real time — instead of wall-blocking."""
        clk = VirtualClock()
        c = Cluster(n_nodes=1, clock=clk)
        try:
            fut = Future()
            fut._clock = clk
            t0 = time.perf_counter()
            with pytest.raises(TimeoutError):
                fut.result(timeout=75.0)
            assert time.perf_counter() - t0 < 2.0
            assert clk.now() >= 75.0
        finally:
            c.shutdown()
            clk.close()

    def test_as_completed_timeout_elapses_in_simulated_time(self):
        clk = VirtualClock()
        c = Cluster(n_nodes=1, clock=clk)
        try:
            done = Future()
            done._clock = clk
            done.set(Handle.blob(b"x"))
            never = Future()
            never._clock = clk
            t0 = time.perf_counter()
            got = []
            with pytest.raises(TimeoutError):
                for f in as_completed([done, never], timeout=30.0):
                    got.append(f)
            assert got == [done]  # finished futures still yielded first
            assert time.perf_counter() - t0 < 2.0
        finally:
            c.shutdown()
            clk.close()

    def test_timed_out_waits_leak_no_callbacks(self):
        """Polling result()/as_completed in a retry loop must not grow the
        pending future's callback list."""
        clk = VirtualClock()
        c = Cluster(n_nodes=1, clock=clk)
        try:
            never = Future()
            never._clock = clk
            for _ in range(3):
                with pytest.raises(TimeoutError):
                    never.result(timeout=1.0)
                with pytest.raises(TimeoutError):
                    list(as_completed([never], timeout=1.0))
            assert never._callbacks == []
        finally:
            c.shutdown()
            clk.close()

    def test_completed_future_beats_timeout(self):
        clk = VirtualClock()
        c = Cluster(n_nodes=2, clock=clk)
        try:
            be = fix.on(c)
            assert be.run(add(20, 22), timeout=60.0) == 42
            assert clk.now() < 60.0  # deadline timer never had to fire
        finally:
            c.shutdown()
            clk.close()


class TestVirtualCluster:
    def test_programs_run_under_virtual_clock(self):
        clk = VirtualClock()
        c = Cluster(n_nodes=3, clock=clk)
        try:
            be = fix.on(c)
            assert be.run(fib(10), timeout=60) == 55
            assert be.run(inc_chain(0, 40), timeout=60) == 40
        finally:
            c.shutdown()
            clk.close()

    def test_speculation_wakeups_under_virtual_clock(self):
        """Clock-scheduled speculation ticks neither spin nor hang a
        virtual run (the seed's sleep-loop poller would livelock it)."""
        clk = VirtualClock()
        c = Cluster(n_nodes=2, speculate_after_s=0.05, clock=clk)
        try:
            assert fix.on(c).run(fib(8), timeout=60) == 21
        finally:
            c.shutdown()
            clk.close()


class TestSecondsToStagePlacement:
    def _hetero_cluster(self, placement: str) -> Cluster:
        """n0 behind a fat 10 Gb/s pipe, n1 an edge site behind a thin
        0.05 Gb/s pipe to everyone."""
        thin = Link(latency_s=0.005, gbps=0.05)
        overrides = {}
        for other in ("n0", "s0", "client"):
            overrides[("n1", other)] = thin
            overrides[(other, "n1")] = thin
        net = Network(Link(latency_s=0.001, gbps=10.0), overrides=overrides)
        return Cluster(n_nodes=2, workers_per_node=1, storage_nodes=("s0",),
                       network=net, placement=placement, clock=VirtualClock())

    def _anchored_job(self, c: Cluster):
        """Bulk inputs on s0, one small anchor blob on the thin node — the
        bytes-missing bait."""
        store = c.nodes["s0"].repo
        blobs = [store.put_blob(bytes([i]) * 200_000) for i in range(4)]
        blobs.append(c.nodes["n1"].repo.put_blob(b"a" * 50_000))
        return checksum_tree(store.put_tree(blobs))

    def test_bytes_missing_takes_the_bait(self):
        c = self._hetero_cluster("bytes")
        try:
            fix.on(c).evaluate(self._anchored_job(c), timeout=120)
            assert c.nodes["n1"].jobs_run >= 1  # ran behind the thin pipe
        finally:
            c.shutdown()
            c.clock.close()

    def test_seconds_to_stage_prefers_idle_fat_pipe(self):
        c = self._hetero_cluster("locality")
        try:
            fix.on(c).evaluate(self._anchored_job(c), timeout=120)
            assert c.nodes["n0"].jobs_run >= 1
            assert c.nodes["n1"].jobs_run == 0  # thin node never ran it
        finally:
            c.shutdown()
            c.clock.close()

    def test_seconds_to_stage_beats_bytes_on_makespan(self):
        makespans = {}
        for placement in ("bytes", "locality"):
            c = self._hetero_cluster(placement)
            try:
                be = fix.on(c)
                jobs = [self._anchored_job(c) for _ in range(1)]
                t0 = c.clock.now()
                for f in [be.submit(j) for j in jobs]:
                    f.result(timeout=120)
                makespans[placement] = c.clock.now() - t0
            finally:
                c.shutdown()
                c.clock.close()
        assert makespans["locality"] < makespans["bytes"]
