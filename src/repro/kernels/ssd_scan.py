"""Mamba2 SSD chunk scan as a Pallas TPU kernel.

The SSD duality splits the recurrence into (a) within-chunk dense matmuls
(MXU work: C B^T masked by the decay kernel, times dt-weighted X) and (b) a
sequential inter-chunk state pass.  The kernel walks chunks as the minor
grid axis, carrying the [P, N] state in VMEM scratch — so the O(S) history
never round-trips HBM and each chunk's tiles are read once.

Grid: (batch*heads, chunks).  Per-cell tiles: x [Q, P], dt [Q, 1],
B/C [Q, N] with Q = chunk length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_scr, *, chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)       # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)     # [Q, 1]
    b = b_ref[0].astype(jnp.float32)       # [Q, N]
    c = c_ref[0].astype(jnp.float32)       # [Q, N]
    a = a_ref[0]                            # scalar decay rate (negative)

    dA = dt * a                             # [Q, 1]
    cum = jnp.cumsum(dA, axis=0)            # inclusive within-chunk
    # within-chunk causal decay kernel
    diff = cum - cum.T                      # [Q, Q] = cum_i - cum_j
    q_i = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 0)
    k_j = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1)
    L = jnp.where(q_i >= k_j, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)      # [Q, Q]
    y_diag = jax.lax.dot_general(cb * L, x * dt, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q, P]
    # inter-chunk: contribution of the entering state
    state = state_scr[...]                  # [N, P]
    y_off = jax.lax.dot_general(c, state, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) * jnp.exp(cum)
    # state update: decay then add this chunk's outer products
    decay_chunk = jnp.exp(cum[-1:])         # [1, 1] total chunk decay
    w = jnp.exp(cum[-1:] - cum) * dt        # [Q, 1] decay-to-end * dt
    s_new = jax.lax.dot_general(b, x * w, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # [N, P]
    state_scr[...] = state * decay_chunk + s_new

    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ci == chunks - 1)
    def _finish():
        state_out_ref[0] = state_scr[...].astype(state_out_ref.dtype)


def ssd_scan(x, dt, A, B_, C_, chunk: int = 256, *, interpret: bool = False):
    """x: [B,S,H,P]  dt: [B,S,H]  A: [H]  B_,C_: [B,S,N].

    Returns (y [B,S,H,P], final_state [B,H,P,N]).  B_/C_ are shared across
    heads (broadcast into the per-(batch,head) grid).
    """
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    chunks = S // Q

    xt = x.transpose(0, 2, 1, 3).reshape(Bsz * H, S, P)
    dtt = dt.transpose(0, 2, 1).reshape(Bsz * H, S, 1)
    bt = jnp.broadcast_to(B_[:, None], (Bsz, H, S, N)).reshape(Bsz * H, S, N)
    ct = jnp.broadcast_to(C_[:, None], (Bsz, H, S, N)).reshape(Bsz * H, S, N)
    at = jnp.broadcast_to(A[None, :], (Bsz, H)).reshape(Bsz * H, 1)

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunks=chunks),
        grid=(Bsz * H, chunks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz * H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(at, xt, dtt, bt, ct)
    y = y.reshape(Bsz, H, S, P).transpose(0, 2, 1, 3)
    state = state.reshape(Bsz, H, N, P).transpose(0, 1, 3, 2)  # [B,H,P,N]
    return y, state
