"""Pure-jnp oracles for every Pallas kernel (the ground truth the
interpret-mode sweeps assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, causal: bool = True):
    """q: [B,S,H,hd]  k,v: [B,T,H,hd] -> [B,S,H,hd] (f32 softmax)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def decode_attention_ref(q, k, v, length):
    """Single-query attention.  q: [B,1,H,hd]  k,v: [B,T,H,hd],
    length: valid prefix (static or traced scalar)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    mask = (jnp.arange(k.shape[1]) < length)[None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * w.astype(jnp.float32)).astype(x.dtype)


def ssd_scan_ref(x, dt, A, B_, C_):
    """Sequential SSD recurrence (the exact semantics the chunked kernel
    must match).  x: [B,S,H,P]  dt: [B,S,H]  A: [H]  B_,C_: [B,S,N].
    Returns (y [B,S,H,P], final state [B,H,P,N])."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dt_t * A[None, :])[..., None, None]       # [B,H,1,1]
        upd = jnp.einsum("bhp,bn->bhpn", dt_t[..., None] * x_t.astype(jnp.float32),
                         b_t.astype(jnp.float32))
        h = h * decay + upd
        y_t = jnp.einsum("bhpn,bn->bhp", h, c_t.astype(jnp.float32))
        return h, y_t

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B_, 1, 0), jnp.moveaxis(C_, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final
