"""Type-driven marshalling between Python values and Fix handles.

The encodings are exactly the repo-wide Table-1 conventions — nothing new
on the wire, so values marshalled here are byte-identical to hand-built
blobs/trees (the content-key-equivalence guarantee):

* ``int`` / ``bool``  — 8-byte little-endian signed blob (the ``create_int``
  convention every existing codelet and test uses).
* ``bytes``           — blob, verbatim.
* ``str``             — UTF-8 blob.
* ``tuple[...]`` / ``list[T]`` — Tree of marshalled children, nested freely.
* ``Handle``          — passthrough: the caller already speaks Table-1.

Marshalling is expressed against two tiny structural interfaces so the same
code runs client-side (against a :class:`~repro.core.repository.Repository`)
and inside a sealed codelet (against the :class:`~repro.core.api.FixAPI`
capability — which stays the codelet's only I/O path).
"""
from __future__ import annotations

import typing
from typing import Any, Optional

from ..core.handle import BLOB, TREE, Handle


class MarshalError(TypeError):
    """A value or annotation the typed frontend cannot (un)marshal."""


#: Annotations the frontend accepts, for error messages.
_SCALARS = (int, bool, bytes, str)


# ---------------------------------------------------------------- emitters
class ApiEmitter:
    """Adapts the sealed FixAPI to the put_blob/put_tree emitter shape
    (used when a codelet returns values or tail-call expressions)."""

    __slots__ = ("_api",)

    def __init__(self, api):
        self._api = api

    def put_blob(self, payload: bytes) -> Handle:
        return self._api.create_blob(payload)

    def put_tree(self, children) -> Handle:
        return self._api.create_tree(children)


class ApiReader:
    """Adapts the sealed FixAPI to the get_blob/get_tree reader shape
    (used to unmarshal a codelet's arguments)."""

    __slots__ = ("_api",)

    def __init__(self, api):
        self._api = api

    def get_blob(self, handle: Handle) -> bytes:
        return self._api.read_blob(handle)

    def get_tree(self, handle: Handle):
        return self._api.read_tree(handle)


# ------------------------------------------------------------- validation
def validate_hint(hint: Any) -> None:
    """Reject annotations the frontend cannot marshal, at decoration time."""
    if hint is None or hint is type(None):
        raise MarshalError("None is not a marshallable Fix type")
    if hint in _SCALARS or hint is Handle:
        return
    origin = typing.get_origin(hint)
    if origin in (tuple, list):
        args = typing.get_args(hint)
        for a in args:
            if a is Ellipsis:
                continue
            validate_hint(a)
        return
    if hint in (tuple, list):
        return  # bare containers: element types inferred per value
    raise MarshalError(
        f"unsupported annotation {hint!r}: use int, bool, bytes, str, "
        f"Handle, or tuples/lists thereof")


# ---------------------------------------------------------------- marshal
def _int_blob(emitter, value: int) -> Handle:
    try:
        return emitter.put_blob(int(value).to_bytes(8, "little", signed=True))
    except OverflowError as e:
        raise MarshalError(f"int {value!r} does not fit 8 bytes") from e


def marshal(emitter, value: Any, hint: Any = None) -> Handle:
    """Encode ``value`` as a Handle via ``emitter`` (put_blob/put_tree).

    ``hint`` is the annotation driving the encoding; Handles pass through
    regardless of hint, and with no hint the encoding is inferred from the
    runtime type.
    """
    if isinstance(value, Handle):
        return value  # raw Table-1 passthrough
    if hint is Handle or hint is None or hint in (tuple, list):
        return _marshal_inferred(emitter, value)
    if hint is bool or hint is int:
        if not isinstance(value, int):
            raise MarshalError(f"expected {hint.__name__}, got {type(value).__name__}")
        return _int_blob(emitter, value)
    if hint is bytes:
        if not isinstance(value, (bytes, bytearray, memoryview)):
            raise MarshalError(f"expected bytes, got {type(value).__name__}")
        return emitter.put_blob(bytes(value))
    if hint is str:
        if not isinstance(value, str):
            raise MarshalError(f"expected str, got {type(value).__name__}")
        return emitter.put_blob(value.encode("utf-8"))
    origin = typing.get_origin(hint)
    if origin in (tuple, list):
        if not isinstance(value, (tuple, list)):
            raise MarshalError(f"expected {hint!r}, got {type(value).__name__}")
        hints = _element_hints(hint, len(value))
        kids = [marshal(emitter, v, h) for v, h in zip(value, hints)]
        return emitter.put_tree(kids)
    raise MarshalError(f"unsupported annotation {hint!r}")


def _marshal_inferred(emitter, value: Any) -> Handle:
    if isinstance(value, bool) or isinstance(value, int):
        return _int_blob(emitter, value)
    if isinstance(value, (bytes, bytearray, memoryview)):
        return emitter.put_blob(bytes(value))
    if isinstance(value, str):
        return emitter.put_blob(value.encode("utf-8"))
    if isinstance(value, (tuple, list)):
        return emitter.put_tree([marshal(emitter, v) for v in value])
    raise MarshalError(f"cannot marshal {type(value).__name__}: {value!r}")


def _element_hints(hint: Any, n: int) -> list:
    """Per-element annotations for a container hint of ``n`` elements."""
    origin = typing.get_origin(hint)
    args = typing.get_args(hint)
    if origin is list:
        elem = args[0] if args else None
        return [elem] * n
    # tuple
    if not args:
        return [None] * n
    if len(args) == 2 and args[1] is Ellipsis:
        return [args[0]] * n
    if len(args) != n:
        raise MarshalError(f"{hint!r} expects {len(args)} elements, got {n}")
    return list(args)


# -------------------------------------------------------------- unmarshal
def unmarshal(reader, handle: Handle, hint: Any = None) -> Any:
    """Decode ``handle`` into a Python value per ``hint`` via ``reader``
    (get_blob/get_tree).  ``hint`` of ``Handle`` (or None on a non-data
    handle) passes the handle through unread — laziness survives typing.
    """
    if hint is Handle:
        return handle
    if not handle.is_data():
        if hint is None:
            return handle  # thunk/encode: opaque without a value annotation
        raise MarshalError(f"cannot decode non-data handle {handle!r} as {hint!r}")
    if hint is None or hint in (tuple, list):
        if handle.content_type == BLOB:
            return reader.get_blob(handle)
        kids = reader.get_tree(handle)
        return tuple(unmarshal(reader, k, None) for k in kids)
    if hint is bool:
        return int.from_bytes(reader.get_blob(handle), "little", signed=True) != 0
    if hint is int:
        return int.from_bytes(reader.get_blob(handle), "little", signed=True)
    if hint is bytes:
        return bytes(reader.get_blob(handle))
    if hint is str:
        return reader.get_blob(handle).decode("utf-8")
    origin = typing.get_origin(hint)
    if origin in (tuple, list):
        if handle.content_type != TREE:
            raise MarshalError(f"expected a tree for {hint!r}, got a blob")
        kids = reader.get_tree(handle)
        hints = _element_hints(hint, len(kids))
        vals = [unmarshal(reader, k, h) for k, h in zip(kids, hints)]
        return vals if origin is list else tuple(vals)
    raise MarshalError(f"unsupported annotation {hint!r}")


# ----------------------------------------------------------- type algebra
def element_type(hint: Any, index) -> Optional[Any]:
    """Static type of ``hint[index]`` for selection sugar (None = unknown)."""
    if hint is None:
        return None
    origin = typing.get_origin(hint)
    args = typing.get_args(hint)
    if origin is list and args:
        return list[args[0]] if isinstance(index, slice) else args[0]
    if origin is tuple and args:
        if len(args) == 2 and args[1] is Ellipsis:
            return hint if isinstance(index, slice) else args[0]
        if isinstance(index, slice):
            return None  # a subrange of a heterogeneous tuple: re-annotate
        if isinstance(index, int) and 0 <= index < len(args):
            return args[index]
    return None
