"""SeamlessM4T-medium [arXiv:2308.11596] backbone: 12L enc + 12L dec,
d1024 16H MHA ff4096 v256206.  Audio frontend is a stub (precomputed
fbank-frame features)."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=0, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206, n_enc_layers=12,
    n_dec_layers=12, cross_len=4096, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec", n_layers=0, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, n_enc_layers=2, n_dec_layers=2,
    cross_len=16,
)
