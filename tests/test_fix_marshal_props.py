"""Hypothesis property tests for the typed frontend's marshalling layer.

The pinned deterministic cases live in tests/test_fix_frontend.py; this
module widens them to generated inputs (nested tuples, negative ints,
empty bytes, unicode, Handle passthrough) wherever hypothesis is present.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

import repro.fix as fix  # noqa: E402
from repro.core import Handle, Repository  # noqa: E402
from repro.fix.marshal import marshal, unmarshal  # noqa: E402
from test_fix_frontend import NESTED, t_echo_list, t_echo_nested  # noqa: E402

FAST = settings(max_examples=50, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])
I64 = st.integers(-(2**63), 2**63 - 1)


@given(I64)
@FAST
def test_int_roundtrip(v):
    repo = Repository()
    assert unmarshal(repo, marshal(repo, v, int), int) == v


@given(st.binary(max_size=200))
@FAST
def test_bytes_roundtrip(b):
    repo = Repository()
    assert unmarshal(repo, marshal(repo, b, bytes), bytes) == b


@given(st.text(max_size=80))
@FAST
def test_str_roundtrip(s):
    repo = Repository()
    assert unmarshal(repo, marshal(repo, s, str), str) == s


@given(st.lists(I64, max_size=8))
@FAST
def test_list_roundtrip(xs):
    repo = Repository()
    assert unmarshal(repo, marshal(repo, xs, list[int]), list[int]) == xs


@given(st.tuples(st.tuples(I64, st.binary(max_size=60)),
                 st.text(max_size=20), st.booleans()))
@FAST
def test_nested_tuple_roundtrip(v):
    repo = Repository()
    assert unmarshal(repo, marshal(repo, v, NESTED), NESTED) == v


@given(st.binary(min_size=31, max_size=100))
@FAST
def test_handle_passthrough(payload):
    repo = Repository()
    h = repo.put_blob(payload)
    assert marshal(repo, h, bytes) is h
    assert unmarshal(repo, h, Handle) is h


@given(st.tuples(st.tuples(I64, st.binary(max_size=40)),
                 st.text(max_size=12), st.booleans()))
@FAST
def test_echo_codelet_end_to_end(v):
    with fix.local() as be:
        assert be.run(t_echo_nested(v)) == v


@given(st.lists(I64, max_size=6))
@FAST
def test_echo_list_end_to_end(xs):
    with fix.local() as be:
        assert be.run(t_echo_list(xs)) == xs


@given(st.integers(0, 4), st.lists(st.binary(min_size=1, max_size=60),
                                   min_size=5, max_size=5))
@FAST
def test_selection_sugar_matches_handbuilt(idx, payloads):
    """lit(tree)[i] compiles to the exact hand-built pair-tree selection."""
    import struct

    repo = Repository()
    tree = repo.put_tree([repo.put_blob(p) for p in payloads])
    typed = fix.lit(tree)[idx].compile(repo)
    pair = repo.put_tree([tree, repo.put_blob(struct.pack("<q", idx))])
    assert typed.raw == pair.selection_of().raw
