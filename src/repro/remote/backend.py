"""``fix.remote(...)`` — the first off-simulation deployment path.

The coordinator runs the same scheduling algorithm as the in-process
:class:`~repro.runtime.cluster.Cluster` (one ``think``/``strictify`` step
per dispatch, children as jobs, memoized encodes folded into the step's
minimum repository), but places steps on **real worker processes** over
local sockets, with every byte of data movement routed through a
content-addressed :class:`~repro.remote.storage.ObjectStore`:

* **invocation plane** — one control socket per worker carrying framed
  ``submit`` / ``ran`` / ``error`` / ``heartbeat`` messages (names and
  memo pairs only, never content);
* **storage plane** — one store socket per worker.  The coordinator pushes
  a step's needs client→store before dispatch; the worker pre-stages
  store→worker before computing and pushes everything it creates
  worker→store before replying.  Workers never talk to each other, so all
  inter-worker movement is two observable hops through the platform-owned
  store — the paper's externalized I/O across a real process boundary.

Residency ground truth is the store's put *notifications* plus the
workers' per-reply fetched/created reports — not in-process repository
listeners — feeding the same :class:`~repro.runtime.transfers.LocationIndex`
the simulated cluster uses.  With ``trace=`` the run emits the PR-4 JSONL
schema (job_submit/place/start/finish, stage_request, transfer_deliver,
put) and passes ``verify_invariants``, so ``diff_traces`` can line a remote
run up against its simulated twin.

Content addressing is what makes this backend small: a handle is its own
checksum, so every hop verifies its delivery, and content keys are
process-independent, so strict-memo and dedup work unchanged across the
boundary.
"""
from __future__ import annotations

import builtins
import itertools
import multiprocessing
import os
import queue
import socket
import tempfile
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Optional

from ..core.handle import (
    APPLICATION,
    BLOB,
    IDENTIFICATION,
    SELECTION,
    STRICT,
    TREE,
    Handle,
)
from ..core.repository import MissingData, Repository, walk_object_closure
from ..fix.backend import Backend
from ..fix.future import DeadlineExceeded, Future
from ..runtime.transfers import LocationIndex
from .protocol import ProtocolError, recv_msg, send_msg
from .storage import (
    FileStore,
    MemoryStore,
    ObjectStore,
    StoreServer,
    decode_tree_payload,
    encode_tree_payload,
    payload_nbytes,
)
from .worker import worker_main

RESOLVE, WAIT_CHILDREN, RUNNING, STRICT_WAIT, DONE = range(5)


class WorkerCrashed(RuntimeError):
    """A worker process died with steps outstanding (typed, not a hang)."""


class RemoteError(RuntimeError):
    """A worker-side failure that has no builtin exception to rebuild."""

    def __init__(self, etype: str, emsg: str):
        super().__init__(f"{etype}: {emsg}")
        self.etype = etype
        self.emsg = emsg


class _MonotonicClock:
    """now() for TraceRecorder.bind: wall-monotonic seconds since start."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0


@dataclass
class _RJob:
    id: int
    encode: Handle
    thunk: Handle
    strict: bool
    phase: int = RESOLVE
    epoch: int = 0
    node: Optional[str] = None
    kind: str = "think"            # op of the in-flight dispatch
    futures: list = field(default_factory=list)
    parents: list = field(default_factory=list)
    pending_children: set = field(default_factory=set)
    whnf: Optional[Handle] = None
    result: Optional[Handle] = None
    strict_children: list = field(default_factory=list)
    strict_stage: list = field(default_factory=list)


class _Worker:
    __slots__ = ("wid", "proc", "ctl", "send_lock", "reader", "alive",
                 "outstanding", "log_path")

    def __init__(self, wid: str, proc, ctl, log_path: str):
        self.wid = wid
        self.proc = proc
        self.ctl = ctl
        self.send_lock = threading.Lock()
        self.reader: Optional[threading.Thread] = None
        self.alive = True
        self.outstanding: set[int] = set()
        self.log_path = log_path


class RemoteBackend(Backend):
    """Real worker processes + pluggable content-addressed object storage.

    ``store`` is ``"memory"`` (server-backed, default), ``"file"`` (a
    :class:`FileStore` under ``store_dir`` — persistent, so two runs of the
    same program share content), or any :class:`ObjectStore` instance.
    Worker stdout/stderr land in per-worker files under ``log_dir``
    (default: ``$FIX_REMOTE_LOGDIR`` or a fresh temp dir) — these are what
    CI uploads when the smoke job fails.
    """

    def __init__(self, n_workers: int = 2, *, store="memory",
                 store_dir: Optional[str] = None, trace=None,
                 log_dir: Optional[str] = None):
        if n_workers < 1:
            raise ValueError("need at least one worker process")
        self._repo = Repository("client")
        self.trace = trace
        if trace is not None:
            trace.bind(_MonotonicClock())
        self._locs = LocationIndex()
        self._store_mutex = threading.Lock()
        self.store = self._resolve_store(store, store_dir)
        self.store.add_put_listener(self._on_store_put)
        self._repo.add_put_listener(self._on_client_put)
        self.log_dir = (log_dir or os.environ.get("FIX_REMOTE_LOGDIR")
                        or tempfile.mkdtemp(prefix="fix-remote-logs-"))
        os.makedirs(self.log_dir, exist_ok=True)

        # scheduler state (coordinator thread only, except _memo reads)
        self._jobs: dict[int, _RJob] = {}
        self._by_encode: dict[bytes, int] = {}
        self._memo: dict[bytes, Handle] = {}
        self._reach: dict[bytes, tuple] = {}
        self._ids = itertools.count()
        self._nonces = itertools.count()
        self._pongs: dict[tuple, threading.Event] = {}
        self._events: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self.transfers = 0
        self.bytes_moved = 0
        self._closed = False
        self._closing = False

        self._store_server = StoreServer(self.store, mutex=self._store_mutex)
        self._workers: dict[str, _Worker] = {}
        ctx = multiprocessing.get_context("fork")
        for i in range(n_workers):
            self._spawn_worker(ctx, f"w{i}")
        self._coord = threading.Thread(target=self._loop, daemon=True,
                                       name="fix-remote-coord")
        self._coord.start()

    # ----------------------------------------------------------- lifecycle
    @staticmethod
    def _resolve_store(store, store_dir: Optional[str]) -> ObjectStore:
        if isinstance(store, ObjectStore):
            return store
        if store == "memory":
            return MemoryStore()
        if store == "file":
            return FileStore(store_dir or tempfile.mkdtemp(prefix="fix-store-"))
        raise ValueError(f"store must be 'memory', 'file' or an ObjectStore, "
                         f"not {store!r}")

    def _spawn_worker(self, ctx, wid: str) -> None:
        ctl_parent, ctl_child = socket.socketpair()
        store_parent, store_child = socket.socketpair()
        log_path = os.path.join(self.log_dir, f"{wid}.log")
        proc = ctx.Process(target=worker_main,
                           args=(ctl_child, store_child, wid, log_path),
                           daemon=True, name=f"fix-remote-{wid}")
        proc.start()
        # Close the child ends NOW, before the next worker forks: a later
        # child inheriting these fds would keep this worker's sockets open
        # past its death and break EOF-based crash detection.
        ctl_child.close()
        store_child.close()
        w = _Worker(wid, proc, ctl_parent, log_path)
        self._workers[wid] = w
        self._store_server.serve(store_parent, wid)
        w.reader = threading.Thread(target=self._read_loop, args=(w,),
                                    daemon=True, name=f"fix-remote-rx-{wid}")
        w.reader.start()

    def _read_loop(self, w: _Worker) -> None:
        try:
            while True:
                msg = recv_msg(w.ctl)
                if msg is None:
                    break
                if msg.get("op") == "pong":
                    ev = self._pongs.pop((w.wid, msg.get("nonce")), None)
                    if ev is not None:
                        ev.set()
                    continue
                self._events.put(("msg", w.wid, msg))
        except (OSError, ProtocolError):
            pass
        self._events.put(("worker_died", w.wid))

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._closing = True
        for w in self._workers.values():
            if w.alive:
                try:
                    send_msg(w.ctl, {"op": "shutdown"}, lock=w.send_lock)
                except OSError:
                    pass
        for w in self._workers.values():
            w.proc.join(timeout=5)
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2)
            if w.proc.is_alive():  # pragma: no cover - last resort
                w.proc.kill()
                w.proc.join(timeout=2)
        self._events.put(None)
        self._coord.join(timeout=5)
        for w in self._workers.values():
            try:
                w.ctl.close()
            except OSError:
                pass
            if w.reader is not None:
                w.reader.join(timeout=5)
        self._store_server.close()
        self.store.close()

    # --------------------------------------------------------------- public
    @property
    def repo(self) -> Repository:
        return self._repo

    def submit(self, program, *, deadline_s: Optional[float] = None) -> Future:
        if self._closed:
            raise RuntimeError("backend is closed")
        encode, out_type = self._compile(program)
        fut = Future()
        fut.out_type = out_type
        if deadline_s is not None:
            timer = threading.Timer(
                deadline_s, lambda: fut.set_exception(
                    DeadlineExceeded("job deadline exceeded")))
            timer.daemon = True
            timer.start()
            fut.add_done_callback(lambda _f: timer.cancel())
        self._events.put(("submit", encode, fut, None, False))
        return fut

    def ping(self, timeout: float = 5.0) -> dict[str, bool]:
        """Heartbeat every live worker; {worker id: answered in time}.

        Workers answer between steps (they are single-threaded by design),
        so a pong bounds liveness, not latency."""
        waits: list[tuple[str, threading.Event]] = []
        out: dict[str, bool] = {}
        for wid, w in self._workers.items():
            if not w.alive:
                out[wid] = False
                continue
            nonce = next(self._nonces)
            ev = threading.Event()
            self._pongs[(wid, nonce)] = ev
            try:
                send_msg(w.ctl, {"op": "heartbeat", "nonce": nonce},
                         lock=w.send_lock)
            except OSError:
                self._pongs.pop((wid, nonce), None)
                out[wid] = False
                continue
            waits.append((wid, ev))
        deadline = time.monotonic() + timeout
        for wid, ev in waits:
            out[wid] = ev.wait(max(0.0, deadline - time.monotonic()))
        return out

    def stats(self) -> dict:
        return {
            "store": self.store.stats(),
            "workers": {wid: {"alive": w.alive, "pid": w.proc.pid,
                              "log": w.log_path}
                        for wid, w in self._workers.items()},
            "transfers": self.transfers,
            "bytes_moved": self.bytes_moved,
        }

    # ------------------------------------------------------ event loop
    def _loop(self) -> None:
        while True:
            ev = self._events.get()
            if ev is None:
                return
            try:
                kind = ev[0]
                if kind == "submit":
                    self._on_submit(*ev[1:])
                elif kind == "msg":
                    self._on_msg(ev[1], ev[2])
                elif kind == "worker_died":
                    self._on_worker_died(ev[1])
            except BaseException:  # pragma: no cover - coordinator must live
                traceback.print_exc()

    def _on_submit(self, encode: Handle, fut: Optional[Future],
                   parent: Optional[int], ignore_memo: bool) -> None:
        tr = self.trace
        if not ignore_memo:
            memo = self._memo.get(encode.raw)
            if memo is not None:
                # the content universe (client repo ∪ store) never evicts,
                # so a memoized result is always fetchable
                if tr is not None:
                    tr.emit("job_memo_hit", encode=encode.raw.hex())
                if fut is not None:
                    fut.set(memo)
                if parent is not None:
                    self._child_resolved(parent, encode)
                return
            existing = self._by_encode.get(encode.raw)
            if existing is not None and self._jobs[existing].phase != DONE:
                job = self._jobs[existing]
                if fut is not None:
                    fut._jid = existing
                    job.futures.append(fut)
                if parent is not None:
                    job.parents.append(parent)
                return
        jid = next(self._ids)
        job = _RJob(jid, encode, encode.unwrap_encode(),
                    encode.interp == STRICT)
        if fut is not None:
            fut._jid = jid
            job.futures.append(fut)
        if parent is not None:
            job.parents.append(parent)
        self._jobs[jid] = job
        if not ignore_memo:
            self._by_encode[encode.raw] = jid
        if tr is not None:
            tr.emit("job_submit", job=jid, encode=encode.raw.hex(),
                    strict=job.strict, parent=parent, recompute=ignore_memo)
        self._advance_guarded(job)

    def _advance_guarded(self, job: _RJob) -> None:
        try:
            self._advance(job)
        except BaseException as e:  # noqa: BLE001 — failures stay job-scoped
            self._fail_job(job, e)

    # ------------------------------------------------------------- advance
    def _advance(self, job: _RJob) -> None:
        thunk = job.thunk
        if thunk.is_data():  # encode over an already-data handle
            job.whnf = thunk
            if job.strict:
                self._begin_strictify(job)
            else:
                self._finalize(job, thunk.as_ref())
            return
        needs, children, memo_pairs = self._step_needs(thunk)
        unresolved = [c for c in children if self._memo.get(c.raw) is None]
        if unresolved:
            job.phase = WAIT_CHILDREN
            job.pending_children = {c.raw for c in unresolved}
            for c in unresolved:
                self._events.put(("submit", c, None, job.id, False))
            return
        for enc in children:
            res = self._memo[enc.raw]
            memo_pairs.append((enc, res))
            needs.extend(self._deep_object_handles(res))
        self._dispatch(job, "think", job.thunk, needs, memo_pairs)

    def _child_resolved(self, parent_id: int, child_encode: Handle) -> None:
        job = self._jobs.get(parent_id)
        if job is None or job.phase == DONE:
            return
        job.pending_children.discard(child_encode.raw)
        if job.pending_children or job.phase not in (WAIT_CHILDREN,
                                                     STRICT_WAIT):
            return
        if job.phase == WAIT_CHILDREN:
            job.phase = RESOLVE
            self._advance_guarded(job)
        else:  # children of the WHNF walk resolved: re-walk, now memoized
            try:
                self._begin_strictify(job)
            except BaseException as e:  # noqa: BLE001
                self._fail_job(job, e)

    # --------------------------------------------------------- strictify
    def _begin_strictify(self, job: _RJob) -> None:
        """Deep-evaluate the WHNF result (mirror of the cluster's walk):
        nested thunks/encodes become child jobs, Ref'd data is staged."""
        whnf = job.whnf
        children: list[Handle] = []
        stage: list[Handle] = []
        stack = [whnf]
        seen: set[bytes] = set()
        while stack:
            h = stack.pop()
            if h.raw in seen or h.is_literal:
                continue
            seen.add(h.raw)
            if h.is_encode():
                res = self._memo.get(h.raw)
                if res is None:
                    children.append(h)
                else:
                    stack.append(res)
                continue
            if h.is_thunk():
                children.append(h.strict())
                continue
            stage.append(h)
            if h.content_type == TREE:
                kids = self._tree_children(h)
                if kids is not None:
                    stack.extend(kids)
        job.strict_stage = stage
        job.strict_children = children
        unresolved = [c for c in children if self._memo.get(c.raw) is None]
        if unresolved:
            job.phase = STRICT_WAIT
            job.pending_children = {c.raw for c in unresolved}
            for c in unresolved:
                self._events.put(("submit", c, None, job.id, False))
            return
        self._advance_strict(job)

    def _advance_strict(self, job: _RJob) -> None:
        if job.whnf.content_type == BLOB and job.whnf.is_data():
            # a blob is its own strict form: no worker round-trip
            self._finalize(job, job.whnf.as_object())
            return
        needs = list(job.strict_stage)
        memo_pairs: list[tuple] = []
        for c in job.strict_children:
            res = self._memo[c.raw]
            memo_pairs.append((c, res))
            needs.extend(self._deep_object_handles(res))
        self._dispatch(job, "strictify", job.whnf, needs, memo_pairs)

    # ---------------------------------------------------------- stepneeds
    def _step_needs(self, thunk: Handle):
        """(stage handles, child encodes, memo pairs) for one reduction —
        the cluster's algorithm verbatim, over client repo ∪ store."""
        interp = thunk.interp
        if interp == IDENTIFICATION:
            return [], [], []
        if interp == SELECTION:
            pair_h = thunk.unwrap_thunk()
            needs = [pair_h]
            pair = self._tree_children(pair_h)
            if pair is None:
                raise MissingData(pair_h)
            target, idx = pair
            if not idx.is_literal:
                needs.append(idx)
            children: list[Handle] = []
            memo_pairs: list[tuple] = []
            if target.is_encode():
                res = self._memo.get(target.raw)
                if res is None:
                    return needs, [target], []
                memo_pairs.append((target, res))
                target = res
            if target.is_thunk():
                res = self._memo.get(target.shallow().raw)
                if res is None:
                    return needs, [target.shallow()], []
                memo_pairs.append((target.shallow(), res))
                target = res
            if not target.is_literal:
                needs.append(target)  # the node itself; children stay put
            return needs, children, memo_pairs
        if interp == APPLICATION:
            defn = thunk.unwrap_thunk()
            needs, children, memo_pairs = [], [], []
            stack = [defn]
            seen: set[bytes] = set()
            while stack:
                h = stack.pop()
                if h.raw in seen or h.is_literal:
                    continue
                seen.add(h.raw)
                if h.is_encode():
                    res = self._memo.get(h.raw)
                    if res is None:
                        children.append(h)
                    else:
                        memo_pairs.append((h, res))
                        stack.append(res)
                    continue
                if h.is_thunk() or h.is_ref():
                    continue  # lazy / metadata-only
                needs.append(h)
                if h.content_type == TREE:
                    kids = self._tree_children(h)
                    if kids is None:
                        raise MissingData(h)
                    stack.extend(kids)
            return needs, children, memo_pairs
        raise ValueError(f"not a thunk: {thunk!r}")

    def _tree_children(self, h: Handle) -> Optional[tuple]:
        try:
            return self._repo.get_tree(h)
        except MissingData:
            payload = self.store.get(h)
            if payload is None:
                return None
            return decode_tree_payload(payload)

    def _deep_object_handles(self, handle: Handle) -> list[Handle]:
        return list(walk_object_closure(
            handle, lambda h: self._memo.get(h.raw),
            self._tree_children, self._reach))

    # ----------------------------------------------------------- dispatch
    def _dispatch(self, job: _RJob, kind: str, target: Handle,
                  needs: list, memo_pairs: list) -> None:
        uniq: list[Handle] = []
        seen: set[bytes] = set()
        for h in needs:
            if h.is_literal or h.raw in seen:
                continue
            seen.add(h.raw)
            uniq.append(h)
        wid = self._pick_worker(uniq)
        if wid is None:
            self._fail_job(job, WorkerCrashed("no live worker processes"))
            return
        # Storage plane first: every need must be servable from the store
        # before the step is dispatched (client→store is an accounted,
        # traced transfer like any other).  The mutex makes the residency
        # check and the trace choreography atomic against worker pushes.
        with self._store_mutex:
            for h in uniq:
                self._ensure_in_store_locked(job.id, h)
        missing = [h for h in uniq
                   if wid not in self._locs.nodes_for(h.content_key())]
        tr = self.trace
        job.node = wid
        job.kind = kind
        if tr is not None:
            tr.emit("job_place", job=job.id, node=wid, epoch=job.epoch,
                    n_missing=len(missing),
                    missing_nbytes=sum(payload_nbytes(h) for h in missing))
        job.phase = RUNNING
        if tr is not None:
            tr.emit("job_start", job=job.id, node=wid, epoch=job.epoch,
                    op="run" if kind == "think" else "strictify", internal=0)
        w = self._workers[wid]
        w.outstanding.add(job.id)
        try:
            send_msg(w.ctl, {
                "op": "submit", "job": job.id, "epoch": job.epoch,
                "kind": kind, "target": target.raw,
                "memos": [[e.raw, r.raw] for e, r in memo_pairs],
                "needs": [h.raw for h in uniq],
            }, lock=w.send_lock)
        except OSError:
            # the reader's worker_died event will fail the job; nothing to
            # do here — failing twice would race the reader thread
            pass

    def _pick_worker(self, uniq: list) -> Optional[str]:
        """Place where the fewest bytes of the step's needs are missing
        (the location index knows worker residency), breaking ties toward
        the shorter outstanding queue, then by worker order."""
        live = [w for w in self._workers.values() if w.alive]
        if not live:
            return None
        best, best_cost = None, None
        for w in live:
            missing = sum(payload_nbytes(h) for h in uniq
                          if w.wid not in self._locs.nodes_for(h.content_key()))
            cost = (missing, len(w.outstanding))
            if best_cost is None or cost < best_cost:
                best, best_cost = w, cost
        return best.wid

    def _ensure_in_store_locked(self, jid: int, h: Handle) -> None:
        """Client→store movement for one handle (store mutex held)."""
        if self.store.contains(h):
            return
        if h.content_type == BLOB:
            payload = self._repo.get_blob(h)
        else:
            payload = encode_tree_payload(self._repo.get_tree(h))
        nbytes = payload_nbytes(h)
        tr = self.trace
        key_hex = h.content_key().hex()
        if tr is not None:
            tr.emit("stage_request", job=jid, dst="store", key=key_hex,
                    nbytes=nbytes, action="enqueue", src="client")
        self.store.put(h, payload, src="client")  # fires put(node="store")
        if tr is not None:
            tr.emit("transfer_deliver", src="client", dst="store", n=1,
                    nbytes=nbytes, keys=[key_hex], ok=True, via="store")
        self.transfers += 1
        self.bytes_moved += nbytes

    # ------------------------------------------------------------- replies
    def _on_msg(self, wid: str, msg: dict) -> None:
        jid = msg.get("job")
        w = self._workers.get(wid)
        if w is not None:
            w.outstanding.discard(jid)
        # Residency/trace accounting first — the movement happened whether
        # or not the job is still current.
        self._record_movement(wid, msg, jid)
        job = self._jobs.get(jid)
        if job is None or job.phase != RUNNING or msg.get("epoch") != job.epoch:
            return  # stale reply (job failed over or already finished)
        if msg["op"] == "error":
            self._fail_job(job, self._rebuild_exc(msg))
            return
        result = Handle(bytes(msg["result"]))
        if job.kind == "strictify":
            self._finalize(job, result)
            return
        if result.is_thunk():  # tail call: fresh placement (paper §4.2.2)
            job.thunk = result
            job.epoch += 1
            job.phase = RESOLVE
            self._advance_guarded(job)
            return
        job.whnf = result
        job.epoch += 1
        if not job.strict:
            self._finalize(job, result.as_ref() if result.is_data() else result)
            return
        try:
            self._begin_strictify(job)
        except BaseException as e:  # noqa: BLE001
            self._fail_job(job, e)

    def _record_movement(self, wid: str, msg: dict, jid) -> None:
        """Fold a reply's fetched/created reports into the trace and the
        location index — the worker's ground truth of what actually moved
        store→worker and what fresh content it produced."""
        tr = self.trace
        resident = self._locs
        for raw, nbytes in msg.get("fetched", ()):
            h = Handle(bytes(raw))
            key = h.content_key()
            if tr is not None:
                key_hex = key.hex()
                tr.emit("stage_request", job=jid, dst=wid, key=key_hex,
                        nbytes=nbytes, action="enqueue", src="store")
                tr.emit("transfer_deliver", src="store", dst=wid, n=1,
                        nbytes=nbytes, keys=[key_hex], ok=True, via="store")
                tr.emit("put", node=wid, key=key_hex, nbytes=nbytes)
            resident.add(key, wid)
            self.transfers += 1
            self.bytes_moved += nbytes
        for raw, nbytes in msg.get("created", ()):
            h = Handle(bytes(raw))
            key = h.content_key()
            if wid in resident.nodes_for(key):
                continue  # already accounted (identical content re-derived)
            if tr is not None:
                tr.emit("put", node=wid, key=key.hex(), nbytes=nbytes)
            resident.add(key, wid)

    @staticmethod
    def _rebuild_exc(msg: dict) -> BaseException:
        etype, emsg = msg.get("etype", "Exception"), msg.get("emsg", "")
        cls = getattr(builtins, etype, None)
        if not (isinstance(cls, type) and issubclass(cls, BaseException)):
            # the repro exception types a shim can raise — rebuilding them
            # keeps error behavior identical to fix.local()
            from ..core.evaluator import FixError
            from ..fix.marshal import MarshalError
            cls = {"FixError": FixError,
                   "MarshalError": MarshalError}.get(etype)
        if cls is not None:
            try:
                return cls(emsg)
            except Exception:  # noqa: BLE001 - exotic signature
                pass
        if etype == "MissingData":
            return RemoteError(etype, emsg or "content unavailable at worker")
        return RemoteError(etype, emsg)

    # ------------------------------------------------------------ terminal
    def _finalize(self, job: _RJob, result: Handle) -> None:
        job.result = result
        job.phase = DONE
        if self.trace is not None:
            self.trace.emit("job_finish", job=job.id, node=job.node,
                            result=result.raw.hex())
        self._memo.setdefault(job.encode.raw, result)
        for f in job.futures:
            f.set(result)
        for pid in job.parents:
            self._child_resolved(pid, job.encode)

    def _fail_job(self, job: _RJob, exc: BaseException) -> None:
        if job.phase == DONE:
            return
        job.phase = DONE
        if self.trace is not None:
            self.trace.emit("job_fail", job=job.id, error=type(exc).__name__)
        for f in job.futures:
            f.set_exception(exc)
        self._notify_parents_exc(job, exc)

    def _notify_parents_exc(self, job: _RJob, exc: BaseException) -> None:
        for pid in job.parents:
            parent = self._jobs.get(pid)
            if parent is not None and parent.phase != DONE:
                self._fail_job(parent, exc)

    def _on_worker_died(self, wid: str) -> None:
        w = self._workers.get(wid)
        if w is None or not w.alive:
            return
        w.alive = False
        if self._closing:
            return
        self._locs.drop_node(wid)
        exc = WorkerCrashed(f"worker {wid} (pid {w.proc.pid}) died; "
                            f"log: {w.log_path}")
        for jid in list(w.outstanding):
            job = self._jobs.get(jid)
            if job is not None and job.phase == RUNNING and job.node == wid:
                self._fail_job(job, exc)
        w.outstanding.clear()

    # ------------------------------------------------------------ localize
    def _localize(self, handle: Handle) -> None:
        """Pull a result's object closure store→client (the accounted,
        traced fetch hop — the remote analogue of the cluster's
        ``fetch_result`` link charges)."""
        if handle.is_ref():
            handle = handle.as_object()
        closure = walk_object_closure(
            handle, lambda h: self._memo.get(h.raw),
            self._tree_children, {})
        for h in closure:
            self._pull_to_client(h)

    def _localize_shallow(self, handle: Handle) -> None:
        """Pull only this handle's own content (a tree node, not its
        children) — the streaming-fetch hop."""
        if handle.is_ref():
            handle = handle.as_object()
        self._pull_to_client(handle)

    def _pull_to_client(self, h: Handle) -> None:
        if h.is_literal or self._repo.contains(h):
            return
        payload = self.store.get(h)
        if payload is None:
            raise MissingData(h)
        nbytes = payload_nbytes(h)
        data = (payload if h.content_type == BLOB
                else decode_tree_payload(payload))
        tr = self.trace
        key_hex = h.content_key().hex()
        with self._store_mutex:
            if self._repo.contains(h):
                return
            if tr is not None:
                tr.emit("stage_request", job=None, dst="client", key=key_hex,
                        nbytes=nbytes, action="enqueue", src="store")
            self._repo.put_handle_data(h, data)  # fires put(node="client")
            if tr is not None:
                tr.emit("transfer_deliver", src="store", dst="client", n=1,
                        nbytes=nbytes, keys=[key_hex], ok=True, via="store")
        self.transfers += 1
        self.bytes_moved += nbytes

    # ----------------------------------------------------------- listeners
    def _on_store_put(self, handle: Handle, nbytes: int, src: str) -> None:
        self._locs.add(handle.content_key(), "store")
        if self.trace is not None:
            self.trace.emit("put", node="store", key=handle.content_key().hex(),
                            nbytes=nbytes)

    def _on_client_put(self, handle: Handle) -> None:
        self._locs.add(handle.content_key(), "client")
        if self.trace is not None:
            self.trace.emit("put", node="client",
                            key=handle.content_key().hex(),
                            nbytes=payload_nbytes(handle))


def remote(n_workers: int = 2, **kwargs) -> RemoteBackend:
    """Spawn a multi-process backend: ``fix.remote(n_workers=4)``."""
    return RemoteBackend(n_workers, **kwargs)
