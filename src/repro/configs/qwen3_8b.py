"""Qwen3-8B [hf:Qwen/Qwen3-8B]: 36L d4096 32H GQA(kv=8) ff12288 v151936,
qk-norm.  head_dim=128 (Qwen3 uses 128 explicitly)."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense", n_layers=36, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=12288, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-8b-smoke", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, head_dim=32, qk_norm=True,
)
