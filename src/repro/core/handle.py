"""Fix Handle ABI: the packed 32-byte representation of every Fix value.

This is the paper's binary representation (sec 3.2): a truncated 192-bit
hash of the referent's canonical bytes, a 48-bit size field, and 16 bits of
type/metadata.  Blobs of 30 bytes or fewer are stored as *literals*, with the
payload placed directly inside the handle.

Layout (32 bytes, little-endian fields)::

    non-literal:  [ 0:24] blake2b-192 digest of canonical content
                  [24:30] size (uint48)   blob: byte length / tree: child count
                  [30:32] metadata (uint16)
    literal:      [ 0:30] payload, zero padded
                  [30:32] metadata (uint16, literal bit set, length in meta)

Metadata bits::

    bits  0-1   content type        0=BLOB  1=TREE
    bits  2-4   interpretation      0=OBJECT 1=REF 2=APPLICATION
                                    3=IDENTIFICATION 4=SELECTION
                                    5=STRICT 6=SHALLOW
    bits  5-6   encode sub-kind     (underlying thunk interp - 2;
                                     valid when interpretation is an Encode)
    bit   7     literal flag
    bits  8-12  literal length (0..30)

A Handle is a *value*: equality and hashing are over the full 32 bytes, so a
Tree's canonical bytes are simply the concatenation of its children's
handles, and an Application Thunk over a Tree is the Tree's digest with
different metadata — creating a Thunk or an Encode is a metadata bit-flip,
never a hash or a copy.  This is what lets Fix ship dependency information
*with* the data defining a function ("parsed anywhere, no round-trips").

The real Fix uses BLAKE3; we use ``hashlib.blake2b(digest_size=24)`` which is
the same construction family, keyed availability in the stdlib, and the same
truncated-192-bit strength.
"""
from __future__ import annotations

import hashlib
from typing import Iterable

HANDLE_SIZE = 32
DIGEST_SIZE = 24
LITERAL_MAX = 30

# content types
BLOB = 0
TREE = 1

# interpretations
OBJECT = 0
REF = 1
APPLICATION = 2
IDENTIFICATION = 3
SELECTION = 4
STRICT = 5
SHALLOW = 6

_THUNK_INTERPS = (APPLICATION, IDENTIFICATION, SELECTION)
_ENCODE_INTERPS = (STRICT, SHALLOW)

_INTERP_NAMES = {
    OBJECT: "object", REF: "ref", APPLICATION: "application",
    IDENTIFICATION: "identification", SELECTION: "selection",
    STRICT: "strict", SHALLOW: "shallow",
}


def _hash(data: bytes) -> bytes:
    return hashlib.blake2b(data, digest_size=DIGEST_SIZE).digest()


class Handle:
    """An immutable 32-byte Fix handle."""

    __slots__ = ("raw",)

    def __init__(self, raw: bytes):
        if len(raw) != HANDLE_SIZE:
            raise ValueError(f"handle must be {HANDLE_SIZE} bytes, got {len(raw)}")
        object.__setattr__(self, "raw", bytes(raw))

    # -- construction -----------------------------------------------------
    @staticmethod
    def _pack(digest: bytes, size: int, meta: int) -> "Handle":
        if size >= 1 << 48:
            raise ValueError("size exceeds 48 bits")
        return Handle(digest + size.to_bytes(6, "little") + meta.to_bytes(2, "little"))

    @staticmethod
    def literal_blob(payload: bytes) -> "Handle":
        if len(payload) > LITERAL_MAX:
            raise ValueError("literal blobs hold at most 30 bytes")
        meta = (BLOB) | (OBJECT << 2) | (1 << 7) | (len(payload) << 8)
        body = payload + b"\x00" * (LITERAL_MAX - len(payload))
        return Handle(body + meta.to_bytes(2, "little"))

    @staticmethod
    def blob(payload: bytes) -> "Handle":
        """Canonical handle for a blob (literal if small enough)."""
        if len(payload) <= LITERAL_MAX:
            return Handle.literal_blob(payload)
        meta = (BLOB) | (OBJECT << 2)
        return Handle._pack(_hash(payload), len(payload), meta)

    @staticmethod
    def tree(children: Iterable["Handle"]) -> "Handle":
        kids = list(children)
        canon = b"".join(k.raw for k in kids)
        meta = (TREE) | (OBJECT << 2)
        return Handle._pack(_hash(canon), len(kids), meta)

    # -- metadata accessors ------------------------------------------------
    @property
    def meta(self) -> int:
        return int.from_bytes(self.raw[30:32], "little")

    @property
    def content_type(self) -> int:
        return self.meta & 0b11

    @property
    def interp(self) -> int:
        return (self.meta >> 2) & 0b111

    @property
    def encode_subkind(self) -> int:
        """Underlying thunk interpretation for an Encode handle."""
        return ((self.meta >> 5) & 0b11) + 2

    @property
    def is_literal(self) -> bool:
        return bool(self.meta & (1 << 7))

    @property
    def size(self) -> int:
        """Blob: byte length.  Tree: number of children."""
        if self.is_literal:
            return (self.meta >> 8) & 0b11111
        return int.from_bytes(self.raw[24:30], "little")

    @property
    def digest(self) -> bytes:
        if self.is_literal:
            raise ValueError("literal handles have no digest")
        return self.raw[0:24]

    def literal_payload(self) -> bytes:
        if not self.is_literal:
            raise ValueError("not a literal handle")
        return self.raw[0 : self.size]

    # -- type predicates ----------------------------------------------------
    def is_blob(self) -> bool:
        return self.content_type == BLOB and self.interp in (OBJECT, REF)

    def is_tree(self) -> bool:
        return self.content_type == TREE and self.interp in (OBJECT, REF)

    def is_object(self) -> bool:
        return self.interp == OBJECT

    def is_ref(self) -> bool:
        return self.interp == REF

    def is_thunk(self) -> bool:
        return self.interp in _THUNK_INTERPS

    def is_encode(self) -> bool:
        return self.interp in _ENCODE_INTERPS

    def is_data(self) -> bool:
        return self.interp in (OBJECT, REF)

    # -- metadata bit-flips (the cheap Fix constructors) --------------------
    def _with_meta(self, meta: int) -> "Handle":
        return Handle(self.raw[:30] + meta.to_bytes(2, "little"))

    def _base_meta(self) -> int:
        """Metadata minus interpretation/subkind bits (keeps literal bits)."""
        return self.meta & ~((0b111 << 2) | (0b11 << 5))

    def as_object(self) -> "Handle":
        """Reinterpret data as accessible (used by the runtime, not users)."""
        if not self.is_data():
            raise ValueError("only data handles have object/ref forms")
        return self._with_meta(self._base_meta() | (OBJECT << 2))

    def as_ref(self) -> "Handle":
        if not self.is_data():
            raise ValueError("only data handles have object/ref forms")
        return self._with_meta(self._base_meta() | (REF << 2))

    def identification(self) -> "Handle":
        """Thunk applying the identity function to this data handle."""
        if not self.is_data():
            raise ValueError("identification target must be data")
        return self._with_meta(self._base_meta() | (IDENTIFICATION << 2))

    def application(self) -> "Handle":
        """Thunk applying the combination described by this Tree.

        The tree is the thunk's *definition*: by convention
        ``[resource_limits, procedure, arg...]``.
        """
        if self.content_type != TREE or not self.is_data():
            raise ValueError("application target must be a tree")
        return self._with_meta(self._base_meta() | (APPLICATION << 2))

    def selection_of(self) -> "Handle":
        """Thunk selecting from the pair-tree ``[target, index]`` (see api.py)."""
        if self.content_type != TREE or not self.is_data():
            raise ValueError("selection target must be a pair tree")
        return self._with_meta(self._base_meta() | (SELECTION << 2))

    def strict(self) -> "Handle":
        if not self.is_thunk():
            raise ValueError("encodes may only refer to thunks")
        sub = self.interp - 2
        return self._with_meta(self._base_meta() | (STRICT << 2) | (sub << 5))

    def shallow(self) -> "Handle":
        if not self.is_thunk():
            raise ValueError("encodes may only refer to thunks")
        sub = self.interp - 2
        return self._with_meta(self._base_meta() | (SHALLOW << 2) | (sub << 5))

    def unwrap_encode(self) -> "Handle":
        """Encode -> the Thunk it requests evaluation of."""
        if not self.is_encode():
            raise ValueError("not an encode")
        sub = self.encode_subkind
        return self._with_meta(self._base_meta() | (sub << 2))

    def unwrap_thunk(self) -> "Handle":
        """Thunk -> its target data handle (definition tree / identified value)."""
        if not self.is_thunk():
            raise ValueError("not a thunk")
        return self._with_meta(self._base_meta() | (OBJECT << 2))

    # -- identity ------------------------------------------------------------
    def content_key(self) -> bytes:
        """Key identifying the underlying *content* (ignores interpretation).

        Used by repositories: an Object and a Ref to the same bytes share
        storage; a Thunk shares storage with its definition Tree.
        """
        if self.is_literal:
            return self.raw[0:30] + bytes([self.meta & 0b11, 1])
        return self.raw[0:24] + bytes([self.meta & 0b11, 0])

    def __eq__(self, other) -> bool:
        return isinstance(other, Handle) and self.raw == other.raw

    def __hash__(self) -> int:
        return hash(self.raw)

    def __repr__(self) -> str:
        kind = "blob" if self.content_type == BLOB else "tree"
        interp = _INTERP_NAMES[self.interp]
        if self.is_literal:
            return f"<{interp} literal-{kind} {self.literal_payload()!r}>"
        return f"<{interp} {kind} size={self.size} {self.raw[:6].hex()}>"
