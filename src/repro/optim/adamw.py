"""Sharded AdamW with optional low-precision moments.

Optimizer state inherits each parameter's sharding (ZeRO-3 via the p_embed
FSDP axis), so memory scales down with the data axis.  State is a plain
pytree — content-addressable per leaf for checkpoint dedup.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models.base import ParamSpec, ps, tree_map_specs


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32   # bf16 halves optimizer HBM
    warmup_steps: int = 100
    max_steps: int = 10_000


def state_specs(param_specs, ocfg: AdamWConfig) -> dict:
    """ParamSpecs for (mu, nu) mirroring the parameter tree's sharding."""
    def mom(_path, s: ParamSpec) -> ParamSpec:
        return ps(s.shape, s.axes, init="zeros", dtype=ocfg.moment_dtype)

    return {
        "mu": tree_map_specs(mom, param_specs),
        "nu": tree_map_specs(mom, param_specs),
        "step": ps((), (), init="zeros", dtype=jnp.int32),
    }


def lr_at(step, ocfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(ocfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - ocfg.warmup_steps)
                    / jnp.maximum(ocfg.max_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return ocfg.lr * warm * (0.1 + 0.9 * cos)


def apply_updates(params, grads, opt_state, ocfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, lr)."""
    step = opt_state["step"] + 1
    lr = lr_at(step, ocfg)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + ocfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + ocfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return (new_p.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype))

    def chunked(p, g, mu, nu):
        # layer-stacked big leaves update under lax.map: bounds the f32
        # temporaries to one layer slice (see adafactor._chunked); the
        # barrier stops XLA:CPU hoisting the f32 converts out of the loop
        if p.ndim >= 3 and p.size > 32 * 2**20 and p.shape[0] > 1:
            return jax.lax.map(
                lambda a: upd(*jax.lax.optimization_barrier(a)), (p, g, mu, nu))
        return upd(p, g, mu, nu)

    out = jax.tree.map(chunked, params, grads, opt_state["mu"], opt_state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, lr
