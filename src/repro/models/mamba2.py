"""Mamba2: the SSD (state-space duality) block, arXiv:2405.21060.

Training uses the chunked SSD algorithm: within-chunk terms are dense
matmuls (MXU-friendly — this is the hot-spot our Pallas ssd_scan kernel
tiles for VMEM), and inter-chunk state propagation is a parallel
associative scan.  Decode is the O(1)-per-token recurrence
``h = exp(dt·A)·h + dt·B⊗x`` — which is why ``long_500k`` runs for SSM
archs while pure-attention archs skip it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .base import ModelConfig, apply_remat, embed_tokens, ps, rmsnorm, scan_layers, unembed


# ------------------------------------------------------------------- specs
def mamba_layer_specs(cfg: ModelConfig, n_layers: int,
                      layer_axis: str = "p_layers") -> dict:
    L, D = n_layers, cfg.d_model
    Din, H, N, W = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_state, cfg.conv_width
    la = layer_axis
    return {
        "norm": ps((L, D), (la, "p_none"), init="ones"),
        "in_z": ps((L, D, Din), (la, "p_embed", "p_conv_dim")),
        "in_x": ps((L, D, Din), (la, "p_embed", "p_conv_dim")),
        "in_B": ps((L, D, N), (la, "p_embed", "p_none")),
        "in_C": ps((L, D, N), (la, "p_embed", "p_none")),
        "in_dt": ps((L, D, H), (la, "p_embed", "p_ssm_heads")),
        "conv_x": ps((L, cfg.conv_width, Din), (la, "p_none", "p_conv_dim"),
                     init="normal", scale=1.0),
        "conv_b": ps((L, Din), (la, "p_conv_dim"), init="zeros"),
        "A_log": ps((L, H), (la, "p_ssm_heads"), init="zeros"),
        "dt_bias": ps((L, H), (la, "p_ssm_heads"), init="zeros"),
        "D_skip": ps((L, H), (la, "p_ssm_heads"), init="ones"),
        "gate_norm": ps((L, Din), (la, "p_conv_dim"), init="ones"),
        "out": ps((L, Din, D), (la, "p_conv_dim", "p_embed")),
    }


def mamba_specs(cfg: ModelConfig) -> dict:
    Vp, D = cfg.vocab_padded, cfg.d_model
    return {
        "embed": ps((Vp, D), ("p_vocab", "p_embed"), init="embed", scale=0.02),
        "layers": mamba_layer_specs(cfg, cfg.n_layers),
        "final_norm": ps((D,), ("p_none",), init="ones"),
        "unembed": ps((D, Vp), ("p_embed", "p_vocab")),
    }


# ------------------------------------------------------------ SSD training
def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: [B,S,C], w: [W,C], b: [C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(W))
    return out + b[None, None, :]


def ssd_chunked(x, dt, A, B_, C_, chunk: int, use_kernel: bool = False):
    """SSD forward.  x: [B,S,H,P]  dt: [B,S,H]  A: [H]  B_,C_: [B,S,N].

    Returns y: [B,S,H,P] and the final state [B,H,P,N].
    """
    if use_kernel:
        from ..kernels import ops as kops
        return kops.ssd_scan(x, dt, A, B_, C_, chunk)
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    f32 = jnp.float32
    # pad to a chunk multiple; dt=0 on padding makes it a no-op (decay 1,
    # zero state update), so states and unpadded outputs are exact
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // Q

    xr = x.reshape(Bsz, nc, Q, H, P)
    dtr = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Br = B_.reshape(Bsz, nc, Q, N).astype(f32)
    Cr = C_.reshape(Bsz, nc, Q, N).astype(f32)
    dA = dtr * A[None, None, None, :]                      # [B,nc,Q,H] (<=0)
    cum = jnp.cumsum(dA, axis=2)                           # within-chunk cumsum

    # within-chunk (diagonal) term: causal decay kernel  L[i,j]=exp(cum_i-cum_j)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cr, Br)             # [B,nc,Q,Q]
    scores = cb[:, :, :, :, None] * Lmat                    # [B,nc,Q,Q,H]
    xdt = xr * dtr[..., None].astype(x.dtype)               # dt_j · x_j
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp",
                        scores.astype(x.dtype), xdt)

    # chunk-local end states: S_c = sum_j exp(cum_Q - cum_j) * dt_j B_j x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # [B,nc,Q,H]
    wx = xr * (dtr * decay_to_end)[..., None].astype(x.dtype)
    s_local = jnp.einsum("bcqn,bcqhp->bchpn", Br.astype(x.dtype), wx)  # [B,nc,H,P,N]

    # inter-chunk: associative scan of (decay, state) pairs
    a_chunk = jnp.exp(cum[:, :, -1, :]).astype(f32)         # [B,nc,H]

    def combine(l, r):
        al, sl = l
        ar, sr = r
        return al * ar, sr + sl * ar[..., None, None].astype(sl.dtype)

    _, s_cum = jax.lax.associative_scan(combine, (a_chunk, s_local), axis=1)
    s_prev = jnp.concatenate(
        [jnp.zeros_like(s_cum[:, :1]), s_cum[:, :-1]], axis=1)  # state entering chunk

    # off-diagonal: y_off[j] = exp(cum_j) * C_j . S_prev, weighted by dt? no —
    # state already carries dt·B·x; contribution is C_j (decay_in) S_prev
    decay_in = jnp.exp(cum).astype(x.dtype)                  # [B,nc,Q,H]
    y_off = jnp.einsum("bcqn,bchpn->bcqhp", Cr.astype(x.dtype), s_prev)
    y_off = y_off * decay_in[..., None]

    y = (y_diag + y_off).reshape(Bsz, S_pad, H, P)[:, :S]
    final_state = s_cum[:, -1]                               # [B,H,P,N]
    return y, final_state


def mamba_block(x, lp, cfg: ModelConfig, sh, ssm_state=None, conv_state=None,
                use_kernel: bool = False):
    """One Mamba2 block.  Train: ssm_state None.  Decode: states provided,
    S must be 1.  Returns (residual out, (ssm_state, conv_state))."""
    Bsz, S, D = x.shape
    H, P, N = cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    dt_ = x.dtype
    h = rmsnorm(x, lp["norm"], cfg.norm_eps)
    z = jnp.einsum("bsd,di->bsi", h, lp["in_z"].astype(dt_))
    xc = jnp.einsum("bsd,di->bsi", h, lp["in_x"].astype(dt_))
    B_ = jnp.einsum("bsd,dn->bsn", h, lp["in_B"].astype(dt_))
    C_ = jnp.einsum("bsd,dn->bsn", h, lp["in_C"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", h, lp["in_dt"].astype(dt_))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"][None, None, :])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))

    if ssm_state is None:  # train / prefill
        xc = _causal_conv(xc, lp["conv_x"].astype(dt_), lp["conv_b"].astype(dt_))
        xc = jax.nn.silu(xc)
        xc = sh(xc, "batch", "seq", "conv_dim")
        xh = xc.reshape(Bsz, S, H, P)
        xh = sh(xh, "batch", "seq", "ssm_heads", None)
        y, final_state = ssd_chunked(xh, dt, A, B_, C_, cfg.ssm_chunk, use_kernel)
        y = y + xh * lp["D_skip"].astype(dt_)[None, None, :, None]
        new_conv = None  # prefill conv-state emission handled by caller if needed
    else:  # decode: O(1) recurrence
        conv_state = jnp.concatenate([conv_state[:, 1:], xc], axis=1)  # [B,W,Din]
        w = lp["conv_x"].astype(dt_)
        xc = (conv_state * w[None]).sum(1, keepdims=True) + lp["conv_b"].astype(dt_)
        xc = jax.nn.silu(xc)
        xh = xc.reshape(Bsz, 1, H, P)
        dA = jnp.exp(dt[:, 0] * A[None, :])                       # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", (dt[:, 0, :, None] * xh[:, 0].astype(jnp.float32)),
                         B_[:, 0].astype(jnp.float32))
        new_state = ssm_state * dA[..., None, None] + upd          # [B,H,P,N]
        y = jnp.einsum("bhpn,bn->bhp", new_state, C_[:, 0].astype(jnp.float32))
        y = y[:, None].astype(dt_) + xh * lp["D_skip"].astype(dt_)[None, None, :, None]
        final_state = new_state
        new_conv = conv_state

    y = y.reshape(Bsz, S, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), lp["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, lp["out"].astype(dt_))
    return x + sh(out, "batch", "seq", "embed"), (final_state, new_conv)


# ----------------------------------------------------------------- forward
def mamba_forward(params, batch, cfg: ModelConfig, sh, remat_policy=None,
                  use_kernel: bool = False, remat_group: int = 1):
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), batch["tokens"], sh)

    def body(x, lp):
        x, _ = mamba_block(x, lp, cfg, sh, use_kernel=use_kernel)
        return x, None

    x, _ = scan_layers(body, x, params["layers"], remat_policy, remat_group)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["unembed"].astype(x.dtype), sh)


def mamba_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """The SSM 'KV cache' is O(1) in sequence length: the recurrent state
    plus the conv window.  max_seq only sets the position counter's range."""
    L, H, P, N = cfg.n_layers, cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    return {
        "ssm": ps((L, batch, H, P, N),
                  ("p_layers", "batch", "ssm_heads", "p_none", "p_none"),
                  init="zeros", dtype=jnp.float32),
        "conv": ps((L, batch, cfg.conv_width, cfg.d_inner),
                   ("p_layers", "batch", "p_none", "conv_dim"),
                   init="zeros", dtype=cfg.compute_dtype),
        "pos": ps((), (), init="zeros", dtype=jnp.int32),
    }


def mamba_decode_step(params, cache, tokens, cfg: ModelConfig, sh):
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), tokens, sh)

    def body(x, layer):
        lp, s, c = layer
        x, (s2, c2) = mamba_block(x, lp, cfg, sh, ssm_state=s, conv_state=c)
        return x, (s2, c2)

    x, (s_stack, c_stack) = jax.lax.scan(body, x, (params["layers"], cache["ssm"], cache["conv"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"].astype(x.dtype), sh)
    return logits, {"ssm": s_stack, "conv": c_stack, "pos": cache["pos"] + 1}


def mamba_block_prefill(x, lp, cfg: ModelConfig, sh, use_kernel: bool = False):
    """Block forward that also emits decode-ready (ssm, conv) states."""
    S = x.shape[1]
    h = rmsnorm(x, lp["norm"], cfg.norm_eps)
    xc = jnp.einsum("bsd,di->bsi", h, lp["in_x"].astype(x.dtype))
    conv_tail = xc[:, S - (cfg.conv_width - 1):]  # last W-1 pre-conv inputs
    pad = jnp.zeros((x.shape[0], 1, cfg.d_inner), xc.dtype)
    conv_state = jnp.concatenate([pad, conv_tail], axis=1)
    x, (state, _) = mamba_block(x, lp, cfg, sh, use_kernel=use_kernel)
    return x, state, conv_state


def mamba_prefill(params, batch, cfg: ModelConfig, sh):
    """Prefill: chunked forward, emitting final SSM + conv states."""
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), batch["tokens"], sh)
    S = x.shape[1]

    def body(x, lp):
        x, state, conv_state = mamba_block_prefill(x, lp, cfg, sh)
        return x, (state, conv_state)

    x, (s_stack, c_stack) = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x[:, -1:], params["unembed"].astype(x.dtype), sh)
    cache = {"ssm": s_stack, "conv": c_stack, "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache
