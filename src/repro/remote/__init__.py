"""``repro.remote`` — real worker processes + content-addressed storage.

The first off-simulation deployment path: the same ``Backend`` protocol as
``fix.local()`` and the simulated cluster, implemented over forked worker
processes (framed socket protocol, :mod:`repro.remote.protocol`) and a
pluggable object store (:mod:`repro.remote.storage`).  The VirtualClock
cluster stays the deterministic CI twin; this package is where the paper's
externalized-I/O claims meet a real process boundary.

Entry point: ``fix.remote(n_workers=...)`` (or :func:`remote` here).
"""
from .backend import RemoteBackend, RemoteError, WorkerCrashed, remote
from .chaos import RemoteChaos, seeded_chaos
from .protocol import (
    BadTag,
    FrameTooLarge,
    FrameTruncated,
    ProtocolError,
    retriable,
)
from .storage import FileStore, MemoryStore, ObjectStore, StoreError

__all__ = [
    "RemoteBackend", "RemoteError", "WorkerCrashed", "remote",
    "RemoteChaos", "seeded_chaos",
    "ObjectStore", "MemoryStore", "FileStore", "StoreError",
    "ProtocolError", "FrameTruncated", "FrameTooLarge", "BadTag",
    "retriable",
]
