"""Chrome/Perfetto ``trace_event`` export for PR-4 trace streams.

The trace plane already records everything a timeline viewer needs —
job stage/run intervals, link serialization windows, and (with
``spans=True``) causal spans.  This module maps those onto the
``trace_event`` JSON format that ``chrome://tracing`` and
https://ui.perfetto.dev load directly:

* every :func:`repro.runtime.trace.waterfall` interval becomes an ``X``
  (complete) event on its lane — node lanes carry ``stage``/``run``
  slices, link lanes carry ``xfer`` slices;
* job lifecycle and transfer events that aren't intervals
  (``job_submit``, ``job_memo_hit``, ``job_fail``, ... ,
  ``transfer_deliver``, ``stage_request``) become ``i`` (instant)
  events, so *every* job/transfer trace event is represented in the
  export — the round-trip test's coverage invariant;
* ``span_begin``/``span_end`` pairs become ``X`` events on a dedicated
  ``spans`` lane, with the parent span id in ``args``.

Lanes map to Perfetto threads (one ``M`` thread-name metadata record
per lane, tids assigned in sorted lane order), all under ``pid`` 1.
Timestamps are trace-clock seconds scaled to integer microseconds;
output is ``json.dumps(sort_keys=True, separators=(",", ":"))`` so the
same trace always exports byte-identically.
"""
from __future__ import annotations

import json
import sys

from ..runtime.trace import event_dicts, waterfall

# job/transfer kinds exported as instants (interval kinds — job_place,
# job_start, job_finish, link_acquire — are consumed by waterfall())
_INSTANT_KINDS = frozenset({
    "job_submit", "job_memo_hit", "job_fail", "job_cancel", "job_resubmit",
    "stage_request", "transfer_deliver", "transfer_retry", "transfer_gaveup",
})

_SCHED_LANE = "scheduler"
_SPAN_LANE = "spans"


def _us(t: float) -> int:
    return int(round(t * 1e6))


def _instant_lane(ev: dict) -> str:
    if ev.get("node") is not None:
        return str(ev["node"])
    src, dst = ev.get("src"), ev.get("dst")
    if src is not None and dst is not None:
        return f"{src}->{dst}"
    if dst is not None:
        return str(dst)
    return _SCHED_LANE


def _instant_name(ev: dict) -> str:
    k = ev["kind"]
    if k.startswith("job_") and ev.get("job") is not None:
        return f"{k}:{ev['job']}"
    return k


def to_trace_events(events) -> list[dict]:
    """Build the ``traceEvents`` list (metadata first, then sorted
    slices/instants) from an iterable of trace events or dicts."""
    evs = event_dicts(events)
    out: list[dict] = []
    lanes: set[str] = set()

    for lane, slices in waterfall(evs).items():
        lanes.add(lane)
        for s in slices:
            args = {k: v for k, v in s.items() if k not in ("start", "end")}
            name = (f"job:{s['job']} {s['phase']}" if "job" in s
                    else s["phase"])
            out.append({"ph": "X", "name": name, "cat": s["phase"],
                        "ts": _us(s["start"]),
                        "dur": max(_us(s["end"]) - _us(s["start"]), 1),
                        "pid": 1, "lane": lane, "args": args})

    open_spans: dict[int, dict] = {}
    for ev in evs:
        k = ev["kind"]
        if k in _INSTANT_KINDS:
            lane = _instant_lane(ev)
            lanes.add(lane)
            args = {kk: vv for kk, vv in ev.items()
                    if kk not in ("t", "seq", "kind") and vv is not None}
            out.append({"ph": "i", "name": _instant_name(ev), "cat": k,
                        "ts": _us(ev["t"]), "s": "t",
                        "pid": 1, "lane": lane, "args": args})
        elif k == "span_begin":
            open_spans[ev["span"]] = ev
        elif k == "span_end":
            begin = open_spans.pop(ev.get("span"), None)
            if begin is None:
                continue
            lanes.add(_SPAN_LANE)
            args = {"span": begin["span"]}
            if begin.get("parent") is not None:
                args["parent"] = begin["parent"]
            for kk, vv in ev.items():
                if kk not in ("t", "seq", "kind", "span") and vv is not None:
                    args[kk] = vv
            out.append({"ph": "X", "name": begin.get("name", "span"),
                        "cat": "span", "ts": _us(begin["t"]),
                        "dur": max(_us(ev["t"]) - _us(begin["t"]), 1),
                        "pid": 1, "lane": _SPAN_LANE, "args": args})

    tid = {lane: i + 1 for i, lane in enumerate(sorted(lanes))}
    for e in out:
        e["tid"] = tid[e.pop("lane")]
    out.sort(key=lambda e: (e["ts"], e["tid"], e["name"]))
    meta = [{"ph": "M", "name": "thread_name", "pid": 1, "tid": n,
             "args": {"name": lane}}
            for lane, n in sorted(tid.items(), key=lambda kv: kv[1])]
    return meta + out


def export_json(events) -> str:
    """Byte-stable ``trace_event`` JSON document for an event stream."""
    doc = {"displayTimeUnit": "ms", "traceEvents": to_trace_events(events)}
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


def export_file(jsonl_path: str, out_path: str) -> int:
    """Export a saved JSONL trace to a Perfetto JSON file; returns the
    number of ``traceEvents`` written."""
    from ..runtime.trace import load_trace
    evs = load_trace(jsonl_path)
    text = export_json(evs)
    with open(out_path, "w") as f:
        f.write(text)
    return len(json.loads(text)["traceEvents"])


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2:
        print("usage: python -m repro.obs.perfetto TRACE.jsonl OUT.json",
              file=sys.stderr)
        return 2
    n = export_file(argv[0], argv[1])
    print(f"wrote {n} trace events to {argv[1]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
