from .adamw import AdamWConfig, apply_updates, lr_at, state_specs
from .compress import ef_int8_allreduce, ef_state_specs

__all__ = ["AdamWConfig", "apply_updates", "lr_at", "state_specs",
           "ef_int8_allreduce", "ef_state_specs"]
