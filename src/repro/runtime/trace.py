"""Deterministic trace capture, replay verification and schedule analysis.

The virtual clock (PR 3) serializes every runtime event deterministically;
this module *records* them.  A :class:`TraceRecorder` passed to
``Cluster(trace=...)`` captures a typed, ordered event stream from
instrumentation points threaded through the scheduler, the transfer
manager, the worker pools and the blocking-fetch path.  Tracing is opt-in
and zero-cost when off: every emit site is guarded by an ``is None`` check
and no recorder object exists unless the caller made one.

Event vocabulary (``kind`` + fields; keys are content-key hex, ``nbytes``
counts blob bytes / 32 bytes per tree child, like the rest of the runtime):

===================  ======================================================
``job_submit``       new job created: ``job``, ``encode``, ``strict``,
                     ``parent`` (submitting job id or null), ``recompute``,
                     plus ``tenant`` *only when the submission was tagged*
                     (``Backend.submit(..., tenant=...)``; children
                     inherit) — untagged runs stay byte-identical
``job_memo_hit``     a submission satisfied from the cluster memo table
                     (``tenant`` again only when tagged)
``job_place``        placement decision: ``job``, ``node``, ``epoch``,
                     ``n_missing``, ``missing_nbytes``
``job_start``        run bound to a worker queue: ``job``, ``node``,
                     ``epoch``, ``op`` ("run" | "strictify"), ``internal``
``job_finish``       result finalized: ``job``, ``node``, ``result``
``job_fail``         job failed: ``job``, ``error`` (exception type name)
``put``              content landed in a node repository: ``node``,
                     ``key``, ``nbytes``
``stage_request``    scheduler wants a handle moved: ``job`` (null for
                     prefetch), ``dst``, ``key``, ``nbytes``, ``action``
                     ("enqueue" | "join" | "recompute"), ``src`` (enqueue)
``transfer_enqueue`` a TransferPlan submitted: ``src``, ``dst``, ``n``,
                     ``nbytes``, ``keys``, ``mode``
``link_acquire``     source NIC acquired, serialization starts: ``src``,
                     ``dst``, ``nbytes``, ``ser_s``, ``via``
``transfer_deliver`` payload installed at the destination: ``src``,
                     ``dst``, ``n``, ``nbytes``, ``keys``, ``ok``, ``via``
                     (``via``: "batched" | "per_handle" | "blocking")
``prefetch``         a prefetch pass staged toward ``node``: ``n`` handles
``spec_wakeup``      a speculation deadline fired for ``job``
``spec_duplicate``   a straggler run duplicated onto ``node``
``starve_begin``     internal-I/O worker slot blocks on fetches: ``node``,
                     ``job``, ``declared`` (keys the job needs)
``starve_end``       the slot's fetches completed: ``node``, ``job``
``span_begin``       causal span opened (opt-in: ``Cluster(spans=True)``):
                     ``span`` (id), ``parent`` (enclosing span id or
                     null), ``name`` ("job" | "stage" | "run" |
                     "transfer"), ``wall_ns`` (monotonic wall clock) plus
                     span-specific fields.  Not a fault kind — spans are
                     annotations, like ``job_resubmit``
``span_end``         the matching close: ``span``, ``wall_ns``, and an
                     optional ``status``
===================  ======================================================

Fault injection (``Cluster(faults=FaultSchedule()...)``) adds a second
family.  ``stage_request`` gains an optional ``retry`` field (attempt
number) on restages, and ``transfer_deliver`` with ``ok=true`` may cover
only the surviving subset of a plan whose other items failed verification:

======================  ===================================================
``fault``               a schedule entry fired: ``fault`` (kind), ``node``,
                        ``src``, ``dst``, ``count``, ``factor``,
                        ``applied`` (false == no-op, e.g. crashing a dead
                        node), ``key`` (corrupt_blob only)
``node_join``           a node (re)joined: ``node``, ``fresh`` (new id vs
                        revived crash victim)
``transfer_drop``       a transfer was lost in flight: ``src``, ``dst``,
                        ``n``, ``nbytes``, ``keys``, ``reason``
                        ("src_crash" | "link_down" | "dropped"), ``via``
``corruption_detected`` delivered bytes failed content-key verification:
                        ``src``, ``dst``, ``key``, ``via``
``quarantine``          a source's at-rest replica failed verification and
                        was evicted: ``node``, ``key``
``transfer_retry``      staging rescheduled with backoff: ``dst``, ``key``,
                        ``attempt``, ``delay_s``, ``reason``
``transfer_gaveup``     retry budget exhausted: ``dst``, ``key``,
                        ``attempts``, ``reason``, ``jobs`` (ids failed)
``job_cancel``          a job was torn down: ``job``, ``reason``
                        ("cancel" | "deadline")
``worker_respawn``      the remote backend replaced a dead worker process
                        under the same node id: ``node``, ``pid``, ``gen``,
                        ``reason`` (always paired with a ``node_join``)
``job_resubmit``        a step was rescheduled after a worker death,
                        content loss or dispatch timeout: ``job``,
                        ``epoch``, ``attempt``, ``delay_s``, ``reason``
                        (recovery bookkeeping — not itself a fault, so it
                        does not flip a trace into fault mode)
======================  ===================================================

The remote backend (``fix.remote``) emits the same vocabulary from real
processes — ``fault`` kinds there include the chaos shim's injections
(``kill_worker``, ``truncate_frame``, ``drop_frame``, ``delay_frame``,
``stall_heartbeat``, ``rot_store``) alongside the backend's observed
``crash`` — so ``verify_invariants`` checks a chaotic real run unchanged.

Serialization is JSONL with sorted keys and no whitespace, so *identical
schedules produce byte-identical files* — the double-run determinism the
property suite (tests/test_trace_properties.py) pins, and what makes the
committed golden fixture (tests/fixtures/quickstart_trace.jsonl) a
regression net for every later scheduler change.
"""
from __future__ import annotations

import itertools
import json
import math
import threading
from collections import Counter, defaultdict
from dataclasses import dataclass
from typing import Callable, Iterable, Optional, Union


# ------------------------------------------------------------------ events
@dataclass(frozen=True)
class TraceEvent:
    """One runtime event: global sequence number, clock time, kind, fields."""

    seq: int
    t: float
    kind: str
    fields: dict

    def to_dict(self) -> dict:
        d = {"seq": self.seq, "t": self.t, "kind": self.kind}
        d.update(self.fields)
        return d


def _as_dict(ev: Union[TraceEvent, dict]) -> dict:
    return ev.to_dict() if isinstance(ev, TraceEvent) else ev


def event_dicts(events: Iterable[Union[TraceEvent, dict]]) -> list[dict]:
    """Normalize a trace (live events or loaded JSONL rows) to dicts."""
    return [_as_dict(e) for e in events]


# ---------------------------------------------------------------- recorder
class TraceRecorder:
    """Collects :class:`TraceEvent`s from every runtime layer.

    ``Cluster(trace=recorder)`` binds the recorder to the cluster's clock
    (timestamps are ``clock.now()`` — simulated seconds under a
    ``VirtualClock``, where two identical runs yield byte-identical
    traces).  ``emit`` is called from scheduler, worker, link-worker and
    timer threads; the lock makes the sequence numbering atomic, and under
    a virtual clock the cooperative run token already serializes callers,
    so event order is deterministic.
    """

    def __init__(self):
        self.events: list[TraceEvent] = []
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self._clock = None

    def bind(self, clock) -> None:
        """Timestamps come from ``clock.now()`` from here on."""
        self._clock = clock

    def emit(self, kind: str, **fields) -> None:
        t = self._clock.now() if self._clock is not None else 0.0
        with self._lock:
            self.events.append(TraceEvent(next(self._seq), t, kind, fields))

    def __len__(self) -> int:
        return len(self.events)

    # ------------------------------------------------------- serialization
    def to_jsonl(self) -> str:
        """Byte-stable JSONL: sorted keys, no whitespace, one event/line."""
        return "".join(
            json.dumps(e.to_dict(), sort_keys=True, separators=(",", ":"))
            + "\n"
            for e in self.events)

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())


def load_trace(path) -> list[dict]:
    """Load a JSONL trace file back into event dicts."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# -------------------------------------------------------------------- diff
@dataclass
class TraceDiff:
    """First divergence between two traces (``identical`` when none)."""

    index: Optional[int]          # first differing event index, or None
    left: Optional[dict]          # event at that index (None = missing)
    right: Optional[dict]
    len_left: int
    len_right: int

    @property
    def identical(self) -> bool:
        return self.index is None

    def __bool__(self) -> bool:  # truthy == "there IS a difference"
        return not self.identical

    def explain(self) -> str:
        if self.identical:
            return f"traces identical ({self.len_left} events)"
        return (f"traces diverge at event {self.index} "
                f"(lengths {self.len_left} vs {self.len_right}):\n"
                f"  left : {self.left}\n"
                f"  right: {self.right}")


def diff_traces(left: Iterable, right: Iterable) -> TraceDiff:
    a, b = event_dicts(left), event_dicts(right)
    for i, (x, y) in enumerate(zip(a, b)):
        if x != y:
            return TraceDiff(i, x, y, len(a), len(b))
    if len(a) != len(b):
        i = min(len(a), len(b))
        return TraceDiff(i, a[i] if i < len(a) else None,
                         b[i] if i < len(b) else None, len(a), len(b))
    return TraceDiff(None, None, None, len(a), len(b))


def replay_check(run: Callable[[TraceRecorder], object],
                 golden: Union[str, Iterable]) -> TraceDiff:
    """Re-run a workload and diff its trace against a recorded one.

    ``run(recorder)`` must build its own ``VirtualClock`` cluster with
    ``trace=recorder`` and drive the workload to completion (see
    tests/workloads.py for the canonical shape).  ``golden`` is a JSONL
    path or an iterable of events.  Returns the :class:`TraceDiff`;
    ``diff.identical`` is the replay assertion.
    """
    rec = TraceRecorder()
    run(rec)
    want = load_trace(golden) if isinstance(golden, str) else golden
    return diff_traces(rec.events, want)


# ---------------------------------------------------------------- analysis
def waterfall(events: Iterable) -> dict[str, list[dict]]:
    """Per-lane schedule intervals derived from a trace.

    Node lanes (``"n0"``...) carry job intervals: ``phase="stage"`` from
    placement to run start, ``phase="run"`` from run start to finish.
    Link lanes (``"n0->n1"``) carry ``phase="xfer"`` serialization
    intervals from ``link_acquire`` events.  This is the data behind
    ``benchmarks --fig waterfall``.
    """
    lanes: dict[str, list[dict]] = defaultdict(list)
    placed: dict[int, tuple[float, str]] = {}
    started: dict[int, tuple[float, str]] = {}
    for ev in event_dicts(events):
        k = ev["kind"]
        if k == "job_place":
            placed[ev["job"]] = (ev["t"], ev["node"])
        elif k == "job_start":
            job = ev["job"]
            if job in placed and placed[job][1] == ev["node"]:
                t0 = placed.pop(job)[0]
                if ev["t"] > t0:
                    lanes[ev["node"]].append(
                        {"job": job, "phase": "stage",
                         "start": t0, "end": ev["t"]})
            started[job] = (ev["t"], ev["node"])
        elif k == "job_finish":
            job = ev["job"]
            if job in started:
                t0, node = started.pop(job)
                lanes[node].append({"job": job, "phase": "run",
                                    "start": t0, "end": ev["t"]})
        elif k == "link_acquire":
            lanes[f"{ev['src']}->{ev['dst']}"].append(
                {"phase": "xfer", "start": ev["t"],
                 "end": ev["t"] + ev["ser_s"], "nbytes": ev["nbytes"]})
    return dict(lanes)


def link_utilization(events: Iterable, horizon_s: float) -> dict[str, float]:
    """Fraction of ``horizon_s`` each (src → dst) link spent serializing."""
    busy: dict[str, float] = defaultdict(float)
    for ev in event_dicts(events):
        if ev["kind"] == "link_acquire":
            busy[f"{ev['src']}->{ev['dst']}"] += ev["ser_s"]
    if horizon_s <= 0:
        return {k: 0.0 for k in busy}
    return {k: min(v / horizon_s, 1.0) for k, v in busy.items()}


def starvation_intervals(events: Iterable) -> list[dict]:
    """Starvation windows (internal-I/O slots held during fetches), each
    attributed to the blob arrivals that ended it.

    ``attributed`` is the key of the last *declared* blob that landed on
    the starved node inside the window — the arrival that released the
    slot.  A window with no arrivals (every declared handle was already
    resident) has ``attributed=None`` and ~zero duration.
    """
    open_: dict[tuple[str, int], dict] = {}
    out: list[dict] = []
    for ev in event_dicts(events):
        k = ev["kind"]
        if k == "starve_begin":
            open_[(ev["node"], ev["job"])] = {
                "node": ev["node"], "job": ev["job"], "start": ev["t"],
                "declared": set(ev["declared"]), "arrivals": []}
        elif k == "put":
            for iv in open_.values():
                if iv["node"] == ev["node"]:
                    iv["arrivals"].append((ev["t"], ev["key"]))
        elif k == "starve_end":
            iv = open_.pop((ev["node"], ev["job"]), None)
            if iv is None:
                continue
            iv["end"] = ev["t"]
            attributed = None
            for _t, key in iv["arrivals"]:
                if key in iv["declared"]:
                    attributed = key
            iv["attributed"] = attributed
            iv["declared"] = sorted(iv["declared"])
            out.append(iv)
    return out


def percentile(values: list, p: float) -> float:
    """Nearest-rank percentile of ``values``.

    Well-defined on every input: 0.0 on an empty population, the single
    sample on a singleton, the minimum for ``p <= 0`` and the maximum for
    ``p >= 100``.  The rank is computed with a small epsilon so float
    round-up (e.g. ``0.55 * 20 == 11.000000000000002``) cannot bump a
    percentile one rank too high."""
    if not values:
        return 0.0
    vals = sorted(values)
    n = len(vals)
    if p <= 0:
        return float(vals[0])
    if p >= 100:
        return float(vals[-1])
    rank = max(1, min(n, math.ceil(p * n / 100.0 - 1e-9)))
    return float(vals[rank - 1])


def tenant_report(events: Iterable) -> dict[str, dict]:
    """Per-tenant SLO report, joined from tenant-tagged trace events.

    Serving (and any other tagged workload) threads a ``tenant`` tag
    through ``Backend.submit``; the schedulers stamp it on ``job_submit``
    / ``job_memo_hit`` and children inherit it — so fairness auditing is
    ordinary trace analysis, not new machinery.  For every tenant seen:
    job counts (submitted / finished / failed / memo hits), job latency
    percentiles (submit → finish, backend-clock seconds), and the
    starvation seconds charged to the tenant's jobs (the
    :func:`starvation_intervals` windows whose starved job it owns).
    Untagged jobs land under the pseudo-tenant ``"-"`` so the report
    always partitions the run.
    """
    evs = event_dicts(events)
    owner: dict[int, str] = {}
    submit_t: dict[int, float] = {}
    stats: dict[str, dict] = defaultdict(lambda: {
        "jobs": 0, "finished": 0, "failed": 0, "memo_hits": 0,
        "latencies": []})
    for ev in evs:
        k = ev["kind"]
        if k == "job_submit":
            ten = ev.get("tenant") or "-"
            owner[ev["job"]] = ten
            submit_t[ev["job"]] = ev["t"]
            stats[ten]["jobs"] += 1
        elif k == "job_memo_hit":
            stats[ev.get("tenant") or "-"]["memo_hits"] += 1
        elif k == "job_finish":
            ten = owner.get(ev["job"], "-")
            stats[ten]["finished"] += 1
            t0 = submit_t.get(ev["job"])
            if t0 is not None:
                stats[ten]["latencies"].append(ev["t"] - t0)
        elif k == "job_fail":
            stats[owner.get(ev["job"], "-")]["failed"] += 1
    starved: dict[str, float] = defaultdict(float)
    for iv in starvation_intervals(evs):
        starved[owner.get(iv["job"], "-")] += iv["end"] - iv["start"]
    for ten in starved:
        stats[ten]  # materialize starved-only tenants (partial traces)
    report: dict[str, dict] = {}
    for ten in sorted(stats):
        s = stats[ten]
        report[ten] = {
            "jobs": s["jobs"], "finished": s["finished"],
            "failed": s["failed"], "memo_hits": s["memo_hits"],
            "p50_latency_s": percentile(s["latencies"], 50),
            "p99_latency_s": percentile(s["latencies"], 99),
            "starved_s": starved.get(ten, 0.0),
        }
    return report


# -------------------------------------------------------------- invariants
_FAULT_KINDS = frozenset({
    "fault", "node_join", "transfer_drop", "corruption_detected",
    "quarantine", "transfer_retry", "transfer_gaveup", "job_cancel",
    "worker_respawn"})


def verify_invariants(events: Iterable) -> list[str]:
    """Check a run's trace against schedule invariants.

    Returns a list of human-readable violations (empty == all hold):

    * **no redundant transfer** — no handle is enqueued toward a node
      where its content was already resident at enqueue time;
    * **conservation** — bytes delivered by the transfer subsystem equal
      bytes the scheduler enqueued (requested minus dedup joins and
      recomputes), and each (dst, key) enqueue has exactly one delivery;
    * **completeness** — every submitted job finishes, fails or is
      cancelled;
    * **starvation attribution** — every starvation interval of positive
      duration ends with the arrival of a blob the job declared (exempting
      jobs that failed: a fetch that exhausted its retries ends starved
      with nothing delivered, by design).

    Traces containing fault-injection events (crashes, drops, corruption
    — see the module docstring's second table) are auto-detected and
    checked against the fault-mode contract instead of strict
    conservation, whose per-(dst, key) equality faults deliberately break:

    * **per-key accounting** — deliveries never exceed enqueues for any
      (dst, key), and nothing is delivered that was never requested;
    * **every loss answered** — each ``transfer_drop`` /
      ``corruption_detected`` is followed by a recovery action (a retry,
      or the key landing at the destination anyway) or an attributed
      failure (``transfer_gaveup``), unless the destination itself
      crashed;
    * **the dead stay silent** — no ``ok`` delivery sources from a node
      after its crash instant (until a ``node_join`` revives it);
    * **quarantine honored** — a quarantined (node, key) replica is never
      used as a transfer source until a fresh ``put`` re-installs verified
      content there.
    """
    violations: list[str] = []
    resident: dict[str, set] = defaultdict(set)
    enq_counts: Counter = Counter()
    del_counts: Counter = Counter()
    enq_bytes = 0
    del_bytes = 0
    submitted: set[int] = set()
    completed: set[int] = set()
    failed_jobs: set[int] = set()
    evs = event_dicts(events)
    fault_mode = any(e["kind"] in _FAULT_KINDS for e in evs)
    # fault-mode bookkeeping (all empty / unused in failure-free traces)
    dead: set[str] = set()
    quarantined: set[tuple] = set()             # (node, key)
    puts: dict[tuple, list] = defaultdict(list)  # (node, key) -> [seq]
    retries: dict[tuple, list] = defaultdict(list)
    gaveups: dict[tuple, list] = defaultdict(list)
    crashes: dict[str, list] = defaultdict(list)  # node -> [crash seq]
    fail_seqs: list[int] = []
    term_seqs: list[int] = []                   # any job terminal event
    pending: list[dict] = []                    # unresolved losses
    for ev in evs:
        k = ev["kind"]
        if k == "put":
            resident[ev["node"]].add(ev["key"])
            puts[(ev["node"], ev["key"])].append(ev["seq"])
            quarantined.discard((ev["node"], ev["key"]))
        elif k == "stage_request" and ev["action"] == "enqueue":
            if ev["key"] in resident[ev["dst"]]:
                violations.append(
                    f"seq {ev['seq']}: transfer enqueued for key "
                    f"{ev['key'][:12]}… already resident at {ev['dst']}")
            src = ev.get("src")
            if src is not None and (src, ev["key"]) in quarantined:
                violations.append(
                    f"seq {ev['seq']}: quarantined replica of "
                    f"{ev['key'][:12]}… at {src} used as transfer source")
            enq_bytes += ev["nbytes"]
            enq_counts[(ev["dst"], ev["key"])] += 1
        elif k == "transfer_deliver" and ev.get("via") != "blocking":
            del_bytes += ev["nbytes"]
            for key in ev["keys"]:
                del_counts[(ev["dst"], key)] += 1
        elif k == "job_submit":
            submitted.add(ev["job"])
        elif k in ("job_finish", "job_fail", "job_cancel"):
            completed.add(ev["job"])
            term_seqs.append(ev["seq"])
            if k != "job_finish":
                failed_jobs.add(ev["job"])
                fail_seqs.append(ev["seq"])
        if not fault_mode:
            continue
        if k == "fault" and ev["fault"] == "crash" and ev["applied"]:
            dead.add(ev["node"])
            crashes[ev["node"]].append(ev["seq"])
            resident[ev["node"]].clear()  # fail-stop: the store is gone
        elif k == "node_join":
            dead.discard(ev["node"])
        elif k == "transfer_deliver" and ev.get("ok") and ev["src"] in dead:
            violations.append(
                f"seq {ev['seq']}: ok delivery {ev['src']}→{ev['dst']} "
                f"sourced from a crashed node")
        elif k == "transfer_drop":
            for key in ev["keys"]:
                pending.append({"seq": ev["seq"], "dst": ev["dst"],
                                "key": key, "via": ev.get("via"),
                                "what": "transfer_drop"})
        elif k == "corruption_detected":
            pending.append({"seq": ev["seq"], "dst": ev["dst"],
                            "key": ev["key"], "via": ev.get("via"),
                            "what": "corruption_detected"})
        elif k == "transfer_retry":
            retries[(ev["dst"], ev["key"])].append(ev["seq"])
        elif k == "transfer_gaveup":
            gaveups[(ev["dst"], ev["key"])].append(ev["seq"])
            for jid in ev["jobs"]:
                if jid not in failed_jobs:
                    violations.append(
                        f"seq {ev['seq']}: transfer_gaveup blames job "
                        f"{jid} which never failed")
        elif k == "quarantine":
            quarantined.add((ev["node"], ev["key"]))
            resident[ev["node"]].discard(ev["key"])
    if fault_mode:
        over = [(dk, del_counts[dk] - enq_counts[dk])
                for dk in del_counts if del_counts[dk] > enq_counts[dk]]
        if over:
            violations.append(
                f"deliveries exceed enqueues for {len(over)} (dst, key) "
                f"pairs, e.g. {over[0][0][1][:12]}… at {over[0][0][0]}")
        for p in pending:
            dk = (p["dst"], p["key"])
            answered = (
                any(s > p["seq"] for s in puts[dk])
                or any(s > p["seq"] for s in retries[dk])
                or any(s > p["seq"] for s in gaveups[dk])
                or any(s > p["seq"] for s in crashes[p["dst"]])
                # blocking-mode fetches retry in-worker (no transfer_retry
                # event); exhaustion surfaces as the starved job failing
                or (p["via"] == "blocking"
                    and any(s > p["seq"] for s in fail_seqs))
                # at-rest corruption caught at dispatch or read replays the
                # job from its current step; re-placement may land the key
                # on a *different* node (or one already holding a good
                # replica), so accept any later put of the key or any later
                # job terminal event
                or (p["via"] in ("dispatch", "read")
                    and (any(s > p["seq"]
                             for (_n, kk), ss in puts.items()
                             if kk == p["key"] for s in ss)
                         or any(s > p["seq"] for s in term_seqs))))
            if not answered:
                violations.append(
                    f"seq {p['seq']}: {p['what']} of {p['key'][:12]}… "
                    f"toward {p['dst']} never answered by retry, "
                    f"delivery or attributed failure")
    else:
        if enq_bytes != del_bytes:
            violations.append(
                f"bytes delivered ({del_bytes}) != bytes enqueued "
                f"({enq_bytes})")
        if enq_counts != del_counts:
            missing = set(enq_counts) - set(del_counts)
            extra = set(del_counts) - set(enq_counts)
            violations.append(
                f"per-(dst,key) enqueue/delivery mismatch: "
                f"{len(missing)} undelivered, {len(extra)} unrequested")
    unfinished = submitted - completed
    if unfinished:
        violations.append(f"jobs never completed: {sorted(unfinished)}")
    for iv in starvation_intervals(evs):
        if (iv["end"] - iv["start"] > 0 and iv["attributed"] is None
                and iv["job"] not in failed_jobs):
            violations.append(
                f"starvation interval on {iv['node']} (job {iv['job']}, "
                f"{iv['start']:.6f}→{iv['end']:.6f}) not ended by a "
                f"declared blob arrival")
    return violations
