"""Model substrate: config, parameter-spec machinery, and shared layers.

Parameters are declared as ParamSpecs carrying *logical axis names*; the
parallel.sharding resolver turns those into NamedShardings per mesh.  This
is the bridge between Fix's worldview (every tensor's placement declared
before execution) and XLA SPMD (the platform performs all resulting I/O).

All model families are pure functions over pytrees — no module framework —
so ``jax.eval_shape`` gives the dry-run's abstract params for free and
checkpointing sees a plain dict of arrays.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------------------ config
@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | mamba2 | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 512
    vocab: int = 1024
    head_dim: int = 0           # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    dense_residual: bool = False      # Arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    # MLA (DeepSeek-V3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False                  # extra multi-token-prediction head
    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256
    # Hybrid (Zamba2)
    attn_every: int = 0                # shared attn block every k ssm layers
    attn_window: int = 0               # KV window for long-context decode
    # Enc-dec (Seamless backbone)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    cross_len: int = 4096              # encoder-memory length at decode time
    # VLM (InternVL backbone)
    n_patches: int = 0
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    @property
    def head_dim_eff(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Pad vocab so the 'model' axis always divides it (MaxText-style)."""
        return -(-self.vocab // 128) * 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim


# ------------------------------------------------------------- param specs
@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                 # logical names (len == len(shape))
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float = 1.0          # multiplies the fan-in-scaled std
    dtype: Any = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def ps(shape, axes, init="normal", scale=1.0, dtype=None) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def _leaf_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (k,))
    else:
        yield prefix, tree


def tree_map_specs(fn, specs):
    """Map fn(path, ParamSpec) over a nested dict of specs."""
    def rec(node, prefix):
        if isinstance(node, dict):
            return {k: rec(v, prefix + (k,)) for k, v in node.items()}
        return fn(prefix, node)
    return rec(specs, ())


def abstract_params(specs, cfg: ModelConfig):
    return tree_map_specs(
        lambda _p, s: jax.ShapeDtypeStruct(s.shape, s.dtype or cfg.param_dtype), specs
    )


def init_params(specs, cfg: ModelConfig, seed: int = 0):
    """Deterministic init: each leaf's key derives from its path (content-
    addressable — the Fix angle: params are a pure function of (specs, seed))."""

    def init_leaf(path, s: ParamSpec):
        dtype = s.dtype or cfg.param_dtype
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        digest = hashlib.blake2b("/".join(path).encode() + str(seed).encode(),
                                 digest_size=4).digest()
        key = jax.random.PRNGKey(int.from_bytes(digest, "little"))
        if s.init == "embed":
            std = s.scale
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = s.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(dtype)

    return tree_map_specs(init_leaf, specs)


def param_pspecs(specs, sharder):
    """Nested dict of PartitionSpecs resolved from each leaf's logical axes."""
    return tree_map_specs(lambda _p, s: sharder.spec(s.axes, s.shape), specs)


def param_shardings(specs, sharder):
    return tree_map_specs(lambda _p, s: sharder.named(s.axes, s.shape), specs)


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _leaf_paths(specs))


# ------------------------------------------------------------------ remat
def apply_remat(body, remat_policy):
    """Wrap a scan body in jax.checkpoint.  ``remat_policy`` is None (off),
    "full" (save nothing — recompute everything in backward), or a
    jax.checkpoint_policies policy object."""
    if remat_policy is None:
        return body
    policy = None if remat_policy == "full" else remat_policy
    return jax.checkpoint(body, policy=policy)


def scan_layers(body, x, layers, remat_policy, remat_group: int = 1):
    """Scan a stacked layer pytree with grouped activation checkpointing.

    remat_group=G saves activations only every G layers (sqrt(L)-style):
    the residual-save stack shrinks Gx at the cost of one extra in-group
    forward during backward — the standard memory-term lever for deep
    stacks (95-layer deepseek-67b: 12.7 GiB of saves at G=1).
    Only for ys-free bodies (training forwards).
    """
    if remat_group <= 1:
        return jax.lax.scan(apply_remat(body, remat_policy), x, layers)
    L = jax.tree.leaves(layers)[0].shape[0]
    G = remat_group
    assert L % G == 0, (L, G)
    grouped = jax.tree.map(lambda a: a.reshape((L // G, G) + a.shape[1:]), layers)

    def group_body(x, gp):
        x, _ = jax.lax.scan(body, x, gp)
        return x, None

    return jax.lax.scan(apply_remat(group_body, remat_policy), x, grouped)


# ----------------------------------------------------------------- layers
@jax.custom_vjp
def _rmsnorm_core(x, w, eps):
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)[..., None]
    inv = jax.lax.rsqrt(ss / x.shape[-1] + eps).astype(x.dtype)
    return x * inv * w.astype(x.dtype)


def _rmsnorm_fwd(x, w, eps):
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)[..., None]
    inv = jax.lax.rsqrt(ss / x.shape[-1] + eps)
    return x * inv.astype(x.dtype) * w.astype(x.dtype), (x, w, inv)


def _rmsnorm_bwd(res, dy):
    x, w, inv = res
    D = x.shape[-1]
    g = dy * w.astype(dy.dtype)
    gx = jnp.einsum("...d,...d->...", g, x,
                    preferred_element_type=jnp.float32)[..., None]
    inv_b = inv.astype(x.dtype)
    coef = (inv ** 3 * gx / D).astype(x.dtype)
    dx = g * inv_b - x * coef
    dw_shape = w.shape
    dw = jnp.einsum("...d,...d->...d" if w.ndim == 1 else "...d,...d->...d",
                    dy, x * inv_b)
    # reduce leading dims down to w's shape
    while dw.ndim > w.ndim:
        dw = dw.sum(0)
    return dx, dw.astype(w.dtype), None


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(x, w, eps: float = 1e-6):
    """RMSNorm with f32 statistics kept strictly inside reductions.

    Hand-written VJP: the naive autodiff of an f32-stats norm promotes the
    backward residual stream to f32 (f32 d_stats x bf16 x -> f32 dx), which
    makes XLA materialize an f32 copy of every remat-saved activation
    (measured: +2x activation memory and +60% backward FLOP time).  The
    custom rule returns dx in x's dtype with f32 used only in the two
    sum-of-squares/inner-product reductions."""
    return _rmsnorm_core(x, w, eps)


def rope(x, positions, theta: float):
    """Rotate-half RoPE.  x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# above this, materializing [S,T] scores is a memory cliff; route causal /
# full patterns through the flash path (Pallas on TPU, blocked jnp here)
_ATTN_BLOCK_THRESHOLD = 2048 * 8192  # (perf iter 1 refuted: at S=4k
# the jnp blocked twin costs MORE HBM traffic than one S^2 tile; the win is
# Pallas-on-TPU keeping tiles in VMEM, or S>=32k where S^2 is prohibitive)


def attend(q, k, v, mask, sh, pattern: Optional[str] = None):
    """Softmax attention.  q: [B,S,H,hd]  k,v: [B,T,H,hd]  mask: [.., S, T]
    broadcastable boolean (True = attend).  f32 softmax for stability.

    ``pattern`` ("causal" | "full") marks masks expressible by the flash
    kernel; large instances stream KV blocks instead of materializing
    [S, T] scores (arctic-480b prefill_32k: 997 GiB -> < 16 GiB)."""
    S, T = q.shape[1], k.shape[1]
    if pattern in ("causal", "full") and S > 1 and S * T >= _ATTN_BLOCK_THRESHOLD:
        from ..kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=(pattern == "causal"))
    if mask is None:  # lazily build small masks (callers pass None with a
        # pattern so the 32k x 32k boolean never materializes on the flash path)
        mask = causal_mask(S, T) if pattern == "causal" else \
            jnp.ones((1, 1, S, T), bool)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out


def repeat_kv(k, n_heads: int):
    """[B,T,Kv,hd] -> [B,T,H,hd] by repeating each kv head H/Kv times."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def causal_mask(s: int, t: Optional[int] = None):
    t = t or s
    return jnp.tril(jnp.ones((s, t), dtype=bool), k=t - s)[None, None]


def swiglu(x, w_gate, w_up, w_down, sh):
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = jax.nn.silu(g) * u
    h = sh(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, w_down)


def embed_tokens(embedding, tokens, sh):
    x = jnp.take(embedding, tokens, axis=0)
    return sh(x, "batch", "seq", "embed")


def unembed(x, w, sh):
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return sh(logits, "batch", "seq", "vocab")


def ce_loss(logits, labels, cfg: ModelConfig, mask=None):
    """Stable cross-entropy in f32; ignores padded-vocab tail and masked
    positions.  Returns (mean loss, metrics)."""
    logits = logits.astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        pad = jnp.arange(logits.shape[-1]) >= cfg.vocab
        logits = jnp.where(pad[None, None, :], -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    return loss, {"loss": loss, "tokens": denom}
