"""Model-family registry: one dispatch point for specs / forward / prefill /
decode across all assigned architectures, plus ``input_specs`` — the
ShapeDtypeStruct stand-ins every dry-run cell lowers against (the Fix
"minimum repository" of a step, declared before any byte is allocated).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import encdec, hybrid, mamba2, moe, transformer
from .base import ModelConfig


@dataclass(frozen=True)
class FamilyOps:
    specs: Callable              # cfg -> param ParamSpec tree
    forward: Callable            # (params, batch, cfg, sh, remat_policy) -> logits
    prefill: Optional[Callable]  # (params, batch, cfg, sh) -> (logits, cache)
    decode_step: Optional[Callable]
    cache_specs: Optional[Callable]  # (cfg, batch, max_seq) -> ParamSpec tree


FAMILIES: dict[str, FamilyOps] = {
    "dense": FamilyOps(transformer.dense_specs, transformer.dense_forward,
                       transformer.dense_prefill, transformer.dense_decode_step,
                       transformer.dense_cache_specs),
    "vlm": FamilyOps(transformer.dense_specs, transformer.dense_forward,
                     transformer.dense_prefill, transformer.dense_decode_step,
                     transformer.dense_cache_specs),
    "moe": FamilyOps(moe.moe_specs, moe.moe_forward, moe.moe_prefill,
                     moe.moe_decode_step, moe.moe_cache_specs),
    "mamba2": FamilyOps(mamba2.mamba_specs, mamba2.mamba_forward,
                        mamba2.mamba_prefill, mamba2.mamba_decode_step,
                        mamba2.mamba_cache_specs),
    "hybrid": FamilyOps(hybrid.hybrid_specs, hybrid.hybrid_forward,
                        hybrid.hybrid_prefill, hybrid.hybrid_decode_step,
                        hybrid.hybrid_cache_specs),
    "encdec": FamilyOps(encdec.encdec_specs, encdec.encdec_forward,
                        encdec.encdec_prefill, encdec.encdec_decode_step,
                        encdec.encdec_cache_specs),
}


def ops_for(cfg: ModelConfig) -> FamilyOps:
    return FAMILIES[cfg.family]


# ------------------------------------------------------------- input specs
VIT_DIM = 3200  # InternViT-6B hidden size (frontend stub provides embeddings)


def input_specs(cfg: ModelConfig, mode: str, batch: int, seq: int) -> dict:
    """Abstract batch for (arch, shape) — ShapeDtypeStructs, no allocation.

    Modes: 'train' (tokens+labels), 'prefill' (prompt), 'decode' (one token).
    """
    i32, f = jnp.int32, cfg.compute_dtype
    sd = jax.ShapeDtypeStruct
    if mode == "decode":
        return {"tokens": sd((batch, 1), i32)}
    if cfg.family == "vlm":
        P = cfg.n_patches
        out = {"tokens": sd((batch, seq - P), i32),
               "patch_embeds": sd((batch, P, VIT_DIM), f)}
        if mode == "train":
            out["labels"] = sd((batch, seq), i32)
        return out
    if cfg.family == "encdec":
        out = {"frames": sd((batch, seq, encdec.FRAME_DIM), f)}
        if mode == "train":
            out["tokens"] = sd((batch, seq), i32)
            out["labels"] = sd((batch, seq), i32)
        return out
    out = {"tokens": sd((batch, seq), i32)}
    if mode == "train":
        out["labels"] = sd((batch, seq), i32)
    return out


def input_shardings(cfg: ModelConfig, mode: str, batch_specs: dict, sharder) -> dict:
    """NamedShardings matching input_specs' structure."""
    out = {}
    for name, s in batch_specs.items():
        axes = ["batch", "seq"] + [None] * (len(s.shape) - 2)
        out[name] = sharder.named(tuple(axes), s.shape)
    return out


def concrete_batch(cfg: ModelConfig, mode: str, batch: int, seq: int, seed: int = 0):
    """Small concrete batch for smoke tests (same structure as input_specs)."""
    key = jax.random.PRNGKey(seed)
    specs = input_specs(cfg, mode, batch, seq)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab, jnp.int32)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)
    return out


def loss_mask(cfg: ModelConfig, labels) -> Optional[object]:
    """VLM: no loss on the patch prefix.  Others: all positions."""
    if cfg.family == "vlm" and cfg.n_patches:
        mask = jnp.ones(labels.shape, jnp.float32)
        return mask.at[:, : cfg.n_patches].set(0.0)
    return None
