"""Streaming fetch: a Tree's children decode as their bytes arrive.

``Backend.fetch`` localizes a result's whole closure before decoding
anything; ``Backend.fetch_stream`` pulls the tree node shallowly, then
localizes one child per iteration — on a cluster each step is charged
its own link cost, so ``bytes_moved`` grows *between* yields and an
early-exiting consumer never pays for the tail.
"""
import pytest

import repro.fix as fix
from repro.core.stdlib import add, identity
from repro.runtime import Cluster, VirtualClock

pytestmark = pytest.mark.usefixtures("no_thread_leaks")


def test_local_stream_values_match_fetch():
    with fix.local() as be:
        tree = be.repo.put_tree(
            [be.repo.put_blob(bytes([i]) * 100) for i in range(5)])
        prog = fix.lit(identity(tree))
        assert (list(be.fetch_stream(prog, as_type=None))
                == list(be.fetch(prog, as_type=None)))


def test_non_tree_result_streams_one_value():
    with fix.local() as be:
        assert list(be.fetch_stream(add(40, 2))) == [42]


def test_typed_elements_decode_per_child():
    with fix.local() as be:
        prog = fix.lit(identity(be.repo.put_tree(
            [be.repo.put_blob((i).to_bytes(8, "little", signed=True))
             for i in range(4)])))
        assert list(be.fetch_stream(prog, as_type=list[int])) == [0, 1, 2, 3]


class TestClusterIncremental:
    def _cluster(self):
        clk = VirtualClock()
        c = Cluster(n_nodes=2, workers_per_node=1, storage_nodes=("s0",),
                    clock=clk, seed=0)
        return c, clk

    def test_bytes_move_between_yields(self):
        c, clk = self._cluster()
        try:
            be = fix.on(c)
            store = c.nodes["s0"].repo
            kids = [store.put_blob(bytes([i]) * 8192) for i in range(4)]
            tree = store.put_tree(kids)
            gen = be.fetch_stream(fix.lit(identity(tree)), as_type=None,
                                  timeout=300)
            moved_at = []
            out = []
            for v in gen:
                out.append(v)
                moved_at.append(c.bytes_moved)
            assert out == [bytes([i]) * 8192 for i in range(4)]
            # each child's localization is charged as it is consumed:
            # the counter strictly grows across yields (per-child hops),
            # rather than jumping once up front
            assert moved_at == sorted(moved_at)
            assert moved_at[0] < moved_at[-1]
        finally:
            c.shutdown()
            clk.close()

    def test_early_exit_skips_the_tail(self):
        c, clk = self._cluster()
        try:
            be = fix.on(c)
            store = c.nodes["s0"].repo
            tree = store.put_tree(
                [store.put_blob(bytes([i]) * 8192) for i in range(6)])
            gen = be.fetch_stream(fix.lit(identity(tree)), as_type=None,
                                  timeout=300)
            next(gen)
            gen.close()
            partial = c.bytes_moved
            # a full fetch of the same tree moves strictly more
            be.fetch(fix.lit(identity(tree)), as_type=None, timeout=300)
            assert c.bytes_moved > partial
        finally:
            c.shutdown()
            clk.close()


def test_remote_stream_matches_fetch():
    with fix.remote(n_workers=2) as be:
        tree = be.repo.put_tree(
            [be.repo.put_blob(bytes([i]) * 600) for i in range(4)])
        prog = fix.lit(identity(tree))
        streamed = list(be.fetch_stream(prog, as_type=None, timeout=120))
        assert streamed == list(be.fetch(prog, as_type=None, timeout=120))
