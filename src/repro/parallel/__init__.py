"""Parallelism substrate: sharding rules + step builders."""
from .sharding import BASE_RULES, RULE_VARIANTS, Sharder, compat_shard_map, make_rules

__all__ = ["BASE_RULES", "RULE_VARIANTS", "Sharder", "compat_shard_map",
           "make_rules"]
