"""Content-addressed repository: Fix's storage substrate.

A Repository holds Blobs (bytes) and Trees (tuples of Handles), keyed by
``Handle.content_key()`` so an Object, a Ref, and a Thunk over the same bytes
share storage.  It also holds the *memo table* — the map from Thunks/Encodes
to their evaluation results — which is what makes Fix's deterministic
computations memoizable ("pay-for-results": a result computed anywhere is a
result computed everywhere).

The reachability analysis here is the paper's "minimum repository" (§3.3):
the complete set of data an invocation may touch, computable from the handle
alone before the task runs.  Footprints and object closures are cached by
content key once *complete* (all reachable trees resident, all encountered
Encodes memoized): content addressing makes such results immutable, so the
hot scheduler paths (``footprint`` / ``missing`` / staging walks) stop
re-traversing shared subtrees.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

from .handle import (
    BLOB,
    TREE,
    Handle,
    OBJECT,
    REF,
)


@dataclass
class Footprint:
    """The statically-computable data needs of evaluating a handle.

    ``data`` — content keys of Blobs/Trees that must be resident (Objects
    reachable through the definition).  ``refs`` — content keys referenced
    only as Refs (metadata visible, bytes not needed here).  ``encodes`` —
    Encode handles whose referent Thunks must be *evaluated* before the
    enclosing Application can run; their own footprints become visible once
    the runtime descends into them.
    """

    data: set = field(default_factory=set)
    refs: set = field(default_factory=set)
    encodes: list = field(default_factory=list)

    def merge(self, other: "Footprint") -> None:
        self.data |= other.data
        self.refs |= other.refs
        self.encodes.extend(other.encodes)

    def copy(self) -> "Footprint":
        return Footprint(set(self.data), set(self.refs), list(self.encodes))


class MissingData(KeyError):
    """Raised when data for a handle is not resident in this repository."""

    def __init__(self, handle: Handle):
        super().__init__(repr(handle))
        self.handle = handle


class CorruptData(RuntimeError):
    """A read-time content verification failed: the resident bytes no longer
    hash to the handle's digest (at-rest corruption).  Only raised when the
    repository's ``verify_reads`` flag is on — the fault-injection plane
    enables it so a rotted blob can never silently feed a computation."""

    def __init__(self, handle: Handle):
        super().__init__(repr(handle))
        self.handle = handle


def walk_object_closure(root: Handle, memo_get: Callable,
                        tree_children: Callable, cache: dict) -> tuple:
    """Every non-literal handle reachable as an Object from ``root``.

    The one walker behind :meth:`Repository.reachable_objects` and the
    cluster's staging closure — the cache-correctness rules live here only.
    ``memo_get(handle)`` resolves Encodes (None = unresolved);
    ``tree_children(handle)`` yields a Tree's children (None = content not
    available).  *Complete* closures — no unresolved Encode, no unreadable
    Tree — are cached by ``root.raw``: content addressing plus
    first-write-wins memoization make them immutable."""
    cached = cache.get(root.raw)
    if cached is not None:
        return cached
    out: list[Handle] = []
    complete = True
    stack = [root]
    seen: set[bytes] = set()
    while stack:
        h = stack.pop()
        if h.raw in seen or h.is_literal:
            continue
        seen.add(h.raw)
        if h.is_encode():
            res = memo_get(h)
            if res is not None:
                stack.append(res)
            else:
                complete = False  # closure grows once this memoizes
            continue
        if h.is_thunk() or h.is_ref():
            continue  # lazy / metadata-only
        sub = cache.get(h.raw)
        if sub is not None and h.raw != root.raw:
            out.extend(sub)  # shared subtree: reuse, don't re-walk
            continue
        out.append(h)
        if h.content_type == TREE:
            kids = tree_children(h)
            if kids is not None:
                stack.extend(kids)
            else:
                complete = False  # children unknown until the tree lands
    # cached subtrees may overlap: dedup by raw, preserving order
    uniq: list[Handle] = []
    uniq_seen: set[bytes] = set()
    for h in out:
        if h.raw not in uniq_seen:
            uniq_seen.add(h.raw)
            uniq.append(h)
    result = tuple(uniq)
    if complete:
        cache.setdefault(root.raw, result)
    return result


class Repository:
    """A thread-safe content-addressed store plus memo table."""

    def __init__(self, name: str = "repo"):
        self.name = name
        self._blobs: dict[bytes, bytes] = {}
        self._trees: dict[bytes, tuple[Handle, ...]] = {}
        # memo: raw handle bytes of a Thunk or Encode -> result Handle
        self._memo: dict[bytes, Handle] = {}
        self._lock = threading.RLock()
        self._blob_bytes = 0  # maintained counter; stats() stays O(1)
        # Content keys evicted after failing verification; never served as
        # a transfer source until a verified replacement lands.
        self.quarantined: set[bytes] = set()
        # Put listeners: called with the new content's Handle after every
        # insert (blob/tree, local or network).  The cluster's location
        # index subscribes here so source lookup never scans repositories.
        self._put_listeners: list[Callable[[Handle], None]] = []
        # Complete-footprint / complete-reachability caches, keyed by
        # (content_key, follow_memo).  Content is immutable and the memo
        # table is first-write-wins, so an entry recorded as *complete*
        # (every reachable tree resident, every encountered Encode already
        # memoized) can never change — no invalidation needed.
        self._fp_cache: dict[tuple[bytes, bool], Footprint] = {}
        self._reach_cache: dict[bytes, tuple[Handle, ...]] = {}
        # Re-hash blob content on every read; CorruptData on mismatch.  Off
        # by default (content is immutable), switched on by the cluster when
        # a fault schedule can corrupt blobs at rest.
        self.verify_reads = False
        # Optional read-through to an external object store: consulted when
        # a blob/tree read misses locally (remote-worker safety net for
        # content the scheduler's need analysis didn't pre-stage).
        self._backing: Optional[Callable[[Handle], object]] = None

    # -------------------------------------------------------------- listeners
    def add_put_listener(self, fn: Callable[[Handle], None]) -> None:
        """``fn(handle)`` fires after new content lands (any thread)."""
        self._put_listeners.append(fn)

    def _notify_put(self, handle: Handle) -> None:
        for fn in self._put_listeners:
            fn(handle)

    # -------------------------------------------------------------- backing
    def set_backing(self, fetch: Optional[Callable[[Handle], object]]) -> None:
        """Install a read-through fallback for missing content.

        ``fetch(handle)`` must return the handle's data (blob bytes or a
        tuple of child Handles) or None when the backing store doesn't have
        it either.  The callable owns installation: if it wants the content
        resident (it almost always does), it installs via
        :meth:`put_handle_data` before returning.  Membership queries
        (:meth:`contains`) deliberately do *not* consult the backing — the
        scheduler's residency accounting must reflect what has actually
        moved, not what could move on demand.
        """
        self._backing = fetch

    def _backing_read(self, handle: Handle):
        if self._backing is None:
            return None
        return self._backing(handle)

    # ------------------------------------------------------------------ put
    def put_blob(self, payload: bytes) -> Handle:
        h = Handle.blob(payload)
        if not h.is_literal:
            key = h.content_key()
            with self._lock:
                fresh = key not in self._blobs
                if fresh:
                    self._blobs[key] = bytes(payload)
                    self._blob_bytes += len(payload)
            if fresh:
                self._notify_put(h)
        return h

    def put_tree(self, children: Iterable[Handle]) -> Handle:
        kids = tuple(children)
        h = Handle.tree(kids)
        key = h.content_key()
        with self._lock:
            fresh = key not in self._trees
            if fresh:
                self._trees[key] = kids
        if fresh:
            self._notify_put(h)
        return h

    def put_handle_data(self, handle: Handle, payload, *,
                        verify: bool = True) -> bool:
        """Install data received from elsewhere (network worker path).

        With ``verify`` (the default) the payload is hashed and checked
        against the handle before it lands — content addressing makes the
        handle its own checksum, so a delivery corrupted on the wire is
        *rejected* here rather than silently poisoning the store.  Returns
        True when the content is resident after the call (installed now or
        already present), False when the payload was rejected."""
        if handle.is_literal:
            return True
        if verify and not self._payload_matches(handle, payload):
            return False
        key = handle.content_key()
        with self._lock:
            if handle.content_type == BLOB:
                fresh = key not in self._blobs
                if fresh:
                    self._blobs[key] = bytes(payload)
                    self._blob_bytes += len(payload)
            else:
                fresh = key not in self._trees
                if fresh:
                    self._trees[key] = tuple(payload)
            self.quarantined.discard(key)  # verified bytes clear quarantine
        if fresh:
            self._notify_put(handle)
        return True

    @staticmethod
    def _payload_matches(handle: Handle, payload) -> bool:
        """Does ``payload`` hash to ``handle``'s digest (and size)?"""
        try:
            if handle.content_type == BLOB:
                if not isinstance(payload, (bytes, bytearray)):
                    return False
                return (Handle.blob(bytes(payload)).digest == handle.digest
                        and len(payload) == handle.size)
            kids = tuple(payload)
            if not all(isinstance(k, Handle) for k in kids):
                return False
            return (Handle.tree(kids).digest == handle.digest
                    and len(kids) == handle.size)
        except (ValueError, TypeError):
            return False

    def verify_resident(self, handle: Handle) -> bool:
        """Re-hash this handle's *resident* content against its digest.

        False means at-rest corruption (or absence) — the caller should
        :meth:`quarantine` the entry so it is never served as a source."""
        if handle.is_literal:
            return True
        with self._lock:
            key = handle.content_key()
            payload = (self._blobs.get(key) if handle.content_type == BLOB
                       else self._trees.get(key))
        if payload is None:
            return False
        return self._payload_matches(handle, payload)

    def quarantine(self, handle: Handle) -> None:
        """Evict content that failed verification and remember its key so
        trace checkers can assert it is never served again (until a
        verified replacement lands)."""
        if handle.is_literal:
            return
        key = handle.content_key()
        with self._lock:
            if handle.content_type == BLOB:
                dropped = self._blobs.pop(key, None)
                if dropped is not None:
                    self._blob_bytes -= len(dropped)
            else:
                self._trees.pop(key, None)
            self.quarantined.add(key)

    def corrupt_nth_blob(self, index: int) -> Optional[bytes]:
        """Fault injection: flip the first byte of the ``index``-th resident
        blob (stable key order).  Returns the content key, or None when no
        blobs are resident.  Test/chaos harness use only."""
        with self._lock:
            if not self._blobs:
                return None
            keys = sorted(self._blobs)
            key = keys[index % len(keys)]
            data = bytearray(self._blobs[key])
            if not data:
                return None
            data[0] ^= 0xFF
            self._blobs[key] = bytes(data)
        return key

    # ------------------------------------------------------------------ get
    def get_blob(self, handle: Handle) -> bytes:
        if handle.content_type != BLOB:
            raise ValueError(f"not a blob handle: {handle!r}")
        if handle.is_literal:
            return handle.literal_payload()
        try:
            payload = self._blobs[handle.content_key()]
        except KeyError:
            payload = self._backing_read(handle)
            if payload is None:
                raise MissingData(handle) from None
            return payload  # verified by the backing's own install
        if self.verify_reads and not self._payload_matches(handle, payload):
            raise CorruptData(handle)
        return payload

    def get_tree(self, handle: Handle) -> tuple[Handle, ...]:
        if handle.content_type != TREE:
            raise ValueError(f"not a tree handle: {handle!r}")
        try:
            return self._trees[handle.content_key()]
        except KeyError:
            kids = self._backing_read(handle)
            if kids is None:
                raise MissingData(handle) from None
            return tuple(kids)

    def raw_payload(self, handle: Handle):
        """Blob bytes or Tree children — whatever this handle's content is."""
        return self.get_blob(handle) if handle.content_type == BLOB else self.get_tree(handle)

    # ----------------------------------------------------------------- memo
    def memo_get(self, handle: Handle) -> Optional[Handle]:
        return self._memo.get(handle.raw)

    def memo_put(self, handle: Handle, result: Handle) -> None:
        # first-write-wins: determinism makes duplicate writes identical, so
        # speculative/straggler duplicate execution is harmless.
        with self._lock:
            self._memo.setdefault(handle.raw, result)

    # Strictification memos share the table under a distinct key prefix so
    # a Tree's strict form is computed once per repository.  This is the
    # public API; callers must not reach into ``_memo`` directly.
    def strict_memo_get(self, handle: Handle) -> Optional[Handle]:
        return self._memo.get(b"S" + handle.raw)

    def strict_memo_put(self, handle: Handle, result: Handle) -> None:
        with self._lock:
            self._memo.setdefault(b"S" + handle.raw, result)

    # ----------------------------------------------------------- membership
    def contains(self, handle: Handle) -> bool:
        """Is this handle's own content resident (not transitively)?"""
        if handle.is_literal:
            return True
        key = handle.content_key()
        if handle.content_type == BLOB:
            return key in self._blobs
        return key in self._trees

    def contains_deep(self, handle: Handle) -> bool:
        """Is every Object reachable from this handle resident?"""
        return not self.missing(handle)

    # --------------------------------------------------------- reachability
    def footprint(self, handle: Handle, *, follow_memo: bool = True) -> Footprint:
        """Minimum repository of ``handle`` (paper §3.3).

        Objects are descended recursively (their bytes are accessible to the
        invocation); Refs contribute metadata only; Thunks inside trees stay
        lazy; Encodes are dependencies that must be evaluated first.  If an
        Encode already has a memoized result and ``follow_memo``, its result's
        footprint is folded in instead (the runtime sees through finished
        work).
        """
        cache_key = None
        if handle.is_object() and not handle.is_literal and handle.content_type == TREE:
            cache_key = (handle.content_key(), follow_memo)
            cached = self._fp_cache.get(cache_key)
            if cached is not None:
                return cached.copy()
        fp = Footprint()
        complete = True  # no missing trees / unresolved encodes encountered
        stack = [handle]
        seen: set[bytes] = set()
        while stack:
            h = stack.pop()
            if h.raw in seen:
                continue
            seen.add(h.raw)
            if h.is_encode():
                if follow_memo:
                    res = self.memo_get(h)
                    if res is not None:
                        stack.append(res)
                        continue
                    complete = False  # footprint grows once this memoizes
                fp.encodes.append(h)
                continue
            if h.is_thunk():
                # Fully lazy (paper fig. 2: the `if` codelet's minimum
                # repository *excludes* the branch thunks' definitions and
                # results).  A bare Thunk is an opaque 32-byte name; its
                # definition is staged only if/when the runtime reduces it.
                continue
            if h.is_ref():
                if not h.is_literal:
                    fp.refs.add(h.content_key())
                continue
            # Object
            if h.is_literal:
                continue
            fp.data.add(h.content_key())
            if h.content_type == TREE:
                sub = self._fp_cache.get((h.content_key(), follow_memo))
                if sub is not None and h.raw != handle.raw:
                    fp.merge(sub)  # shared subtree: reuse, don't re-walk
                    continue
                try:
                    stack.extend(self.get_tree(h))
                except MissingData:
                    # Tree node itself not resident: its key is already in
                    # fp.data; children unknown until it arrives.
                    complete = False
        if complete and cache_key is not None:
            self._fp_cache.setdefault(cache_key, fp.copy())
        return fp

    def reachable_objects(self, handle: Handle) -> tuple[Handle, ...]:
        """Every non-literal handle reachable as an Object from ``handle``
        (complete closures cached — see :func:`walk_object_closure`)."""
        return walk_object_closure(
            handle, self.memo_get,
            lambda h: self.get_tree(h) if self.contains(h) else None,
            self._reach_cache)

    def missing(self, handle: Handle) -> list[Handle]:
        """Handles reachable as Objects whose content is not resident."""
        return [h for h in self.reachable_objects(handle)
                if not self.contains(h)]

    def transitive_size(self, handle: Handle) -> int:
        """Bytes of resident data reachable as Objects from ``handle``.

        This is the scheduler's data-movement cost for shipping the minimum
        repository of a task to another node.
        """
        total = 0
        stack = [handle]
        seen: set[bytes] = set()
        while stack:
            h = stack.pop()
            if h.raw in seen:
                continue
            seen.add(h.raw)
            if h.is_encode():
                res = self.memo_get(h)
                if res is not None:
                    stack.append(res)
                continue
            if h.is_thunk():
                continue  # lazy — see footprint()
            if h.is_ref():
                continue
            if h.is_literal:
                total += h.size
                continue
            if h.content_type == BLOB:
                if self.contains(h):
                    total += h.size
            else:
                total += 32 * h.size  # the tree node itself
                if self.contains(h):
                    stack.extend(self.get_tree(h))
        return total

    # -------------------------------------------------------------- export
    def export(self, handle: Handle, sink: "Repository") -> int:
        """Copy everything reachable from ``handle`` into ``sink``.

        Returns bytes copied.  Used by the simulated network worker; real
        deployments would serialize over RPC — the wire format is exactly
        (handle, payload) pairs because handles are self-describing.
        """
        moved = 0
        stack = [handle]
        seen: set[bytes] = set()
        while stack:
            h = stack.pop()
            if h.raw in seen:
                continue
            seen.add(h.raw)
            if h.is_encode():
                res = self.memo_get(h)
                if res is not None:
                    sink.memo_put(h, res)
                    stack.append(res)
                continue
            if h.is_thunk():
                stack.append(h.unwrap_thunk())
                continue
            if h.is_ref() or h.is_literal:
                continue
            if not self.contains(h):
                continue
            if not sink.contains(h):
                payload = self.raw_payload(h)
                sink.put_handle_data(h, payload)
                moved += h.size if h.content_type == BLOB else 32 * h.size
            if h.content_type == TREE:
                stack.extend(self.get_tree(h))
        return moved

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "blobs": len(self._blobs),
            "trees": len(self._trees),
            "memos": len(self._memo),
            "blob_bytes": self._blob_bytes,  # maintained counter, O(1)
            "quarantined": len(self.quarantined),
        }
