"""Kwargs/defaults for ``@fix.codelet``: keys survive adding parameters.

The encoding rule under test: required parameters travel positionally in
the combination; optional (defaulted) parameters travel — only when the
provided value differs from the default — in one trailing Tree of
``[utf8-name-blob, value]`` pairs, in signature order.  All-default calls
therefore compile to byte-identical combinations as calls to the codelet
*before it grew the defaults* — old call sites keep their content keys
(and their memoized results).
"""
import pytest

import repro.fix as fix
from repro.core import Repository
from repro.core.procedures import procedure_blob
from repro.core.stdlib import add
from repro.fix.codelet import DEFAULT_LIMITS
from repro.fix.marshal import MarshalError, marshal

pytestmark = pytest.mark.usefixtures("no_thread_leaks")


@fix.codelet
def scaled_sum(a: int, b: int, factor: int = 1, offset: int = 0) -> int:
    return (a + b) * factor + offset


@fix.codelet
def tag(payload: bytes, label: str = "default") -> bytes:
    return label.encode() + b":" + payload


def _old_style(repo, name, *arg_values):
    """A combination hand-built the way a pre-defaults client would:
    ``[limits, procedure, arg...]`` — nothing trailing."""
    kids = [repo.put_blob(DEFAULT_LIMITS), repo.put_blob(procedure_blob(name))]
    kids.extend(marshal(repo, v, type(v)) for v in arg_values)
    return repo.put_tree(kids).application()


class TestKeyPreservation:
    def test_all_default_call_keeps_old_key(self):
        repo = Repository("t")
        assert (scaled_sum(3, 4).compile(repo).raw
                == _old_style(repo, "scaled_sum", 3, 4).raw)

    def test_explicitly_passing_the_default_still_elides(self):
        repo = Repository("t")
        base = scaled_sum(3, 4).compile(repo).raw
        assert scaled_sum(3, 4, factor=1).compile(repo).raw == base
        assert scaled_sum(3, 4, factor=1, offset=0).compile(repo).raw == base
        assert scaled_sum(3, 4, 1, 0).compile(repo).raw == base  # positional

    def test_property_old_call_sites_keep_their_keys(self):
        """For a spread of argument values, the defaults-era codelet
        compiles the same combination the pre-defaults codelet would
        have — the ISSUE's property, checked exhaustively over a grid."""
        repo = Repository("t")
        for a in (-(2**40), -1, 0, 1, 7, 2**40):
            for b in (0, 5, -3):
                assert (scaled_sum(a, b).compile(repo).raw
                        == _old_style(repo, "scaled_sum", a, b).raw)
        for payload in (b"", b"x", b"payload" * 20):
            assert (tag(payload).compile(repo).raw
                    == _old_style(repo, "tag", payload).raw)

    def test_override_changes_the_key(self):
        repo = Repository("t")
        base = scaled_sum(3, 4).compile(repo).raw
        h1 = scaled_sum(3, 4, factor=2).compile(repo).raw
        h2 = scaled_sum(3, 4, offset=9).compile(repo).raw
        assert len({base, h1, h2}) == 3

    def test_override_key_is_deterministic_and_order_insensitive(self):
        repo = Repository("t")
        # kwargs pairs ride in *signature* order, not call order
        h1 = scaled_sum(3, 4, factor=2, offset=9).compile(repo).raw
        h2 = scaled_sum(3, 4, offset=9, factor=2).compile(repo).raw
        assert h1 == h2


class TestEvaluation:
    def test_defaults_and_overrides_evaluate(self):
        with fix.local() as be:
            assert be.run(scaled_sum(3, 4)) == 7
            assert be.run(scaled_sum(3, 4, factor=2)) == 14
            assert be.run(scaled_sum(3, 4, offset=9)) == 16
            assert be.run(scaled_sum(3, 4, factor=2, offset=9)) == 23
            assert be.run(tag(b"p")) == b"default:p"
            assert be.run(tag(b"p", label="v2")) == b"v2:p"

    def test_lazy_value_in_kwarg_position(self):
        with fix.local() as be:
            assert be.run(scaled_sum(1, 1, factor=add(1, 2))) == 6

    def test_legacy_positional_combination_still_evaluates(self):
        """A combination minted before ``factor``/``offset`` had defaults
        carries them positionally; the same shim must accept it."""
        with fix.local() as be:
            comb = _old_style(be.repo, "scaled_sum", 3, 4, 2, 9)
            assert be.fetch(be.submit(comb), as_type=int) == 23

    def test_remote_backend_agrees(self):
        with fix.local() as lb:
            want = lb.evaluate(scaled_sum(5, 6, factor=3)).raw
        with fix.remote(n_workers=2) as be:
            assert be.evaluate(scaled_sum(5, 6, factor=3)).raw == want
            assert be.run(scaled_sum(5, 6, factor=3)) == 33


class TestValidation:
    def test_required_after_default_rejected(self):
        # (Python itself forbids `a: int = 1, b: int` positionally —
        # keyword-only is the spelling that can reach our decorator)
        with pytest.raises(MarshalError, match="follows a defaulted"):
            @fix.codelet
            def bad2(a: int = 1, *, b: int) -> int:
                return a + b

    def test_wrong_arity_still_rejected(self):
        with fix.local() as be:
            comb = _old_style(be.repo, "scaled_sum", 1, 2, 3)  # 3 of 2|4 args
            from repro.core import FixError
            with pytest.raises(FixError):
                be.fetch(be.submit(comb), as_type=int, timeout=30)

    def test_unknown_kwarg_rejected_client_side(self):
        with pytest.raises(MarshalError):
            scaled_sum(1, 2, scale=3)
