"""Typed codelets: Python signatures compiled to Table-1 shims.

``@fix.codelet`` reads a function's annotations and generates both halves
of the boundary:

* an **unmarshal shim**, registered in the ordinary procedure registry
  under ``fix/proc/<name>`` — at apply time it decodes the combination's
  argument handles into real Python values through the sealed
  :class:`~repro.core.api.FixAPI` (still the only I/O path), calls the
  body, and marshals the return value back to a Handle.  A body may also
  return a Handle directly, or a :class:`~repro.fix.lazy.Lazy` expression —
  the latter compiles through the same capability into a tail-call Thunk,
  so typed codelets recurse exactly like hand-written ones.
* a **client-side constructor**: calling the decorated object builds a
  :class:`~repro.fix.lazy.Lazy` call node, not an invocation.

Because the shim is a plain registered procedure, hand-built
``combination(repo, name, ...)`` trees keep working unchanged and evaluate
through the very same code — one representation, two spellings.
"""
from __future__ import annotations

import inspect
import typing
from typing import Any, Callable, Optional

from ..core.handle import Handle
from ..core.procedures import make_limits, procedure_blob, register
from .lazy import _CALL, Lazy
from .marshal import (
    ApiEmitter,
    ApiReader,
    MarshalError,
    marshal,
    unmarshal,
    validate_hint,
)

#: Default resource-limit blob for typed calls — identical bytes to the raw
#: helper's default (``stdlib.LIMITS_SMALL``), so typed and hand-built
#: combinations share content keys.
DEFAULT_LIMITS = make_limits(ram_bytes=1 << 16)


class TypedCodelet:
    """A registered procedure plus its typed client-side constructor."""

    def __init__(self, fn: Callable, name: str, limits: bytes):
        self.fn = fn
        self.name = name
        self.limits = limits
        self.proc_payload = procedure_blob(name)
        self.__name__ = fn.__name__
        self.__doc__ = fn.__doc__
        self.__wrapped__ = fn

        self._sig = inspect.signature(fn)
        hints = typing.get_type_hints(fn)
        self.param_hints: list[Any] = []
        for p in self._sig.parameters.values():
            if p.kind in (inspect.Parameter.VAR_POSITIONAL,
                          inspect.Parameter.VAR_KEYWORD):
                raise MarshalError(
                    f"codelet {name!r}: *args/**kwargs are not marshallable — "
                    f"take a list/tuple parameter instead")
            if p.name not in hints:
                raise MarshalError(
                    f"codelet {name!r}: parameter {p.name!r} needs a type "
                    f"annotation (int, bytes, str, bool, tuple/list, Handle)")
            hint = hints[p.name]
            validate_hint(hint)
            self.param_hints.append(hint)
        self.return_hint = hints.get("return")
        if self.return_hint is not None:
            validate_hint(self.return_hint)

        def _registered(api, comb, _self=self):  # plain function: the
            return _self._shim(api, comb)        # registry tags attributes
        _registered.__name__ = f"{name}.shim"
        _registered.__qualname__ = f"TypedCodelet({name}).shim"
        register(name)(_registered)
        self.shim = _registered

    # ------------------------------------------------------- server side
    def _shim(self, api, comb: Handle) -> Handle:
        kids = api.read_tree(comb)
        arg_handles = kids[2:]  # [limits, procedure, arg...]
        if len(arg_handles) != len(self.param_hints):
            raise MarshalError(
                f"codelet {self.name!r} takes {len(self.param_hints)} "
                f"argument(s), combination supplies {len(arg_handles)}")
        reader = ApiReader(api)
        values = [unmarshal(reader, h, hint)
                  for h, hint in zip(arg_handles, self.param_hints)]
        out = self.fn(*values)
        if isinstance(out, Handle):
            return out  # raw handle (data, or a hand-rolled tail call)
        if isinstance(out, Lazy):
            return out.compile(ApiEmitter(api))  # typed tail call
        return marshal(ApiEmitter(api), out, self.return_hint)

    # ------------------------------------------------------- client side
    def __call__(self, *args, **kwargs) -> Lazy:
        try:
            bound = self._sig.bind(*args, **kwargs)
        except TypeError as e:
            raise MarshalError(f"codelet {self.name!r}: {e}") from None
        bound.apply_defaults()
        ordered = [bound.arguments[p] for p in self._sig.parameters]
        return Lazy(_CALL, codelet=self, args=ordered,
                    out_type=self.return_hint)

    def __repr__(self) -> str:
        params = ", ".join(
            f"{p}: {getattr(h, '__name__', h)}"
            for p, h in zip(self._sig.parameters, self.param_hints))
        return f"<fix.codelet {self.name}({params})>"


def codelet(fn: Optional[Callable] = None, *, name: Optional[str] = None,
            limits: bytes = DEFAULT_LIMITS):
    """Decorator: turn an annotated function into a :class:`TypedCodelet`.

    ``@codelet`` and ``@codelet(name="add", limits=...)`` both work.
    ``limits`` is the resource-limit blob placed first in every combination
    this codelet's calls compile to.
    """
    def deco(f: Callable) -> TypedCodelet:
        return TypedCodelet(f, name or f.__name__, limits)

    return deco(fn) if fn is not None else deco
