"""Benchmark harness: one entry per paper table/figure + roofline report.

  PYTHONPATH=src python -m benchmarks.run            # all paper figures
  PYTHONPATH=src python -m benchmarks.run --fig 8b   # one figure
  PYTHONPATH=src python -m benchmarks.run --roofline results/dryrun_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

from benchmarks import figures  # noqa: E402

FIGS = {
    "7a": figures.fig7a_invocation,
    "7b": figures.fig7b_chain,
    "8a": figures.fig8a_late_binding,
    "8b": figures.fig8b_wordcount,
    "9": figures.fig9_btree,
    "10": figures.fig10_burst_compile,
    "staging": figures.fig_staging,
    "sweep": figures.fig_sweep,
    "waterfall": figures.fig_waterfall,
    "chaos": figures.fig_chaos,
    "remote_chaos": figures.fig_remote_chaos,
    "serving": figures.fig_serving,
    "obs": figures.fig_obs,
}


def print_csv(name: str, result: dict) -> None:
    for k, v in result.items():
        val = f"{v:.4g}" if isinstance(v, float) else v
        print(f"{name},{k},{val}")


def roofline_table(path: str) -> None:
    rows = json.load(open(path))
    print(f"{'arch':20s} {'shape':12s} {'mesh':8s} {'dom':10s} "
          f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
          f"{'useful':>7s} {'rooffrac':>8s} {'GiB':>7s} fits")
    for r in rows:
        if not r["ok"]:
            print(f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:8s} FAILED: "
                  f"{r['error'][:80]}")
            continue
        rf = r["roofline"]
        m = r["memory"]
        print(f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:8s} "
              f"{rf['dominant']:10s} {rf['compute_s']:10.3g} "
              f"{rf['memory_s']:10.3g} {rf['collective_s']:10.3g} "
              f"{rf['useful_fraction']:7.3f} {rf['roofline_fraction']:8.4f} "
              f"{m['peak_estimate_bytes']/2**30:7.2f} "
              f"{'Y' if m['fits_16GiB'] else 'N'}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig", action="append", default=None, choices=list(FIGS))
    ap.add_argument("--roofline", default=None,
                    help="print the roofline table from a dry-run json")
    ap.add_argument("--json", default=None,
                    help="also dump {figure: result} to this path")
    args = ap.parse_args()

    if args.roofline:
        roofline_table(args.roofline)
        return

    figs = args.fig or list(FIGS)
    collected = {}
    print("figure,metric,value")
    for name in figs:
        t0 = time.time()
        result = FIGS[name]()
        collected[name] = result
        print_csv(f"fig{name}", result)
        print(f"# fig{name} took {time.time()-t0:.1f}s", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(collected, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
