"""Observability surfaces over the telemetry plane.

The runtime half lives in :mod:`repro.runtime.telemetry` (the metrics
registry, span emitter, and codelet profiles threaded through all three
backends).  This package holds the *views*:

* :mod:`repro.obs.perfetto` — export a PR-4 trace stream (including the
  PR-10 ``span_begin``/``span_end`` events) to Chrome/Perfetto
  ``trace_event`` JSON, byte-stable so CI can diff it;
* :mod:`repro.obs.top` — a ``top``-style live renderer over the unified
  ``stats()`` snapshot shape shared by ``fix.local()``, ``fix.on()``,
  ``fix.remote()`` and :class:`~repro.serving.fixserve.FixServeEngine`.
"""
__all__ = ["export_json", "to_trace_events", "render_snapshot"]


def __getattr__(name):
    # lazy: keeps `python -m repro.obs.top` free of the runpy
    # found-in-sys.modules warning
    if name in ("export_json", "to_trace_events"):
        from . import perfetto
        return getattr(perfetto, name)
    if name == "render_snapshot":
        from .top import render_snapshot
        return render_snapshot
    raise AttributeError(name)
