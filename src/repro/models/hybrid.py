"""Zamba2-style hybrid: Mamba2 backbone with a *shared* attention block
applied every ``attn_every`` SSM layers (arXiv:2411.15242).

The shared block is one set of weights applied at G = floor(L/k) points —
a natural fit for Fix's content-addressing story: the block's weights are
one Handle referenced G times (checkpoints dedupe it automatically).

Long-context decode uses a windowed KV policy (``cfg.attn_window``) for the
shared-attention caches, keeping the 500k-token cell sub-quadratic; the SSM
states are O(1) regardless.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig, apply_remat, embed_tokens, ps, rmsnorm, unembed
from .mamba2 import mamba_block, mamba_layer_specs
from .transformer import attn_block, dense_layer_specs, mlp_block


def _group_shape(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, layers_per_group, tail_layers)."""
    k = cfg.attn_every
    g = cfg.n_layers // k
    return g, k, cfg.n_layers - g * k


def hybrid_specs(cfg: ModelConfig) -> dict:
    Vp, D = cfg.vocab_padded, cfg.d_model
    g, k, tail = _group_shape(cfg)
    shared = {n: s for n, s in dense_layer_specs(cfg, 1).items()}
    specs = {
        "embed": ps((Vp, D), ("p_vocab", "p_embed"), init="embed", scale=0.02),
        # grouped mamba stack: [G, k, ...] — outer scan over groups
        "groups": {
            name: ps((g,) + s.shape, ("p_layers",) + s.axes, s.init, s.scale, s.dtype)
            for name, s in mamba_layer_specs(cfg, k, layer_axis="p_layers").items()
        },
        "shared_attn": shared,  # ONE copy, applied after every group
        "tail": mamba_layer_specs(cfg, tail) if tail else {},
        "final_norm": ps((D,), ("p_none",), init="ones"),
        "unembed": ps((D, Vp), ("p_embed", "p_vocab")),
    }
    return specs


def _shared_block(x, sp, cfg: ModelConfig, sh, positions, kv_cache=None):
    """The shared transformer block (attn + mlp); params have a leading
    length-1 'layer' dim from dense_layer_specs(cfg, 1)."""
    lp = jax.tree.map(lambda a: a[0], sp)
    x, kv = attn_block(x, lp, cfg, sh, positions, kv_cache)
    x = mlp_block(x, lp, cfg, sh)
    return x, kv


def hybrid_forward(params, batch, cfg: ModelConfig, sh, remat_policy=None,
                   use_kernel: bool = False):
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), batch["tokens"], sh)
    positions = jnp.arange(x.shape[1])[None, :]
    g, k, tail = _group_shape(cfg)

    def inner(x, lp):
        x, _ = mamba_block(x, lp, cfg, sh, use_kernel=use_kernel)
        return x, None

    def group_body(x, gp):
        x, _ = jax.lax.scan(inner, x, gp)
        x, _ = _shared_block(x, params["shared_attn"], cfg, sh, positions)
        return x, None

    group_body = apply_remat(group_body, remat_policy)
    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if tail:
        x, _ = jax.lax.scan(inner, x, params["tail"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["unembed"].astype(x.dtype), sh)


def hybrid_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    from .mamba2 import mamba_cache_specs

    g, k, tail = _group_shape(cfg)
    W = min(max_seq, cfg.attn_window) if cfg.attn_window else max_seq
    Kv, hd = cfg.n_kv_heads, cfg.head_dim_eff
    ssm = mamba_cache_specs(cfg, batch, max_seq)
    return {
        "ssm_g": ps((g, k) + ssm["ssm"].shape[1:], ("p_layers",) + ssm["ssm"].axes,
                    init="zeros", dtype=jnp.float32),
        "conv_g": ps((g, k) + ssm["conv"].shape[1:], ("p_layers",) + ssm["conv"].axes,
                     init="zeros", dtype=cfg.compute_dtype),
        "ssm_t": ps((max(tail, 1),) + ssm["ssm"].shape[1:], ssm["ssm"].axes,
                    init="zeros", dtype=jnp.float32),
        "conv_t": ps((max(tail, 1),) + ssm["conv"].shape[1:], ssm["conv"].axes,
                     init="zeros", dtype=cfg.compute_dtype),
        # one KV cache per shared-block application (windowed)
        "attn_k": ps((g, batch, W, Kv, hd),
                     ("p_layers", "batch", "kv_seq", "kv_heads", "p_none"),
                     init="zeros", dtype=cfg.compute_dtype),
        "attn_v": ps((g, batch, W, Kv, hd),
                     ("p_layers", "batch", "kv_seq", "kv_heads", "p_none"),
                     init="zeros", dtype=cfg.compute_dtype),
        "pos": ps((), (), init="zeros", dtype=jnp.int32),
    }


def hybrid_decode_step(params, cache, tokens, cfg: ModelConfig, sh):
    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), tokens, sh)
    pos = cache["pos"]
    W = cache["attn_k"].shape[2]
    # windowed KV: wrap the write cursor (mask is exact until the first wrap;
    # see DESIGN.md §Arch-applicability on the rolling-window approximation)
    write_pos = pos % W if cfg.attn_window else pos
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    g, k, tail = _group_shape(cfg)

    def inner(carry, layer):
        x = carry
        lp, s, c = layer
        x, (s2, c2) = mamba_block(x, lp, cfg, sh, ssm_state=s, conv_state=c)
        return x, (s2, c2)

    def group_body(x, layer):
        gp, s, c, k_all, v_all = layer
        x, (s2, c2) = jax.lax.scan(inner, x, (gp, s, c))
        x, (k2, v2) = _shared_block(x, params["shared_attn"], cfg, sh, positions,
                                    kv_cache=(k_all, v_all, write_pos))
        return x, (s2, c2, k2, v2)

    x, (ssm_g, conv_g, k_g, v_g) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["ssm_g"], cache["conv_g"],
         cache["attn_k"], cache["attn_v"]))
    ssm_t, conv_t = cache["ssm_t"], cache["conv_t"]
    if tail:
        x, (ssm_t, conv_t) = jax.lax.scan(
            inner, x, (params["tail"], cache["ssm_t"], cache["conv_t"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"].astype(x.dtype), sh)
    new_cache = {"ssm_g": ssm_g, "conv_g": conv_g, "ssm_t": ssm_t, "conv_t": conv_t,
                 "attn_k": k_g, "attn_v": v_g, "pos": pos + 1}
    return logits, new_cache


def hybrid_prefill(params, batch, cfg: ModelConfig, sh):
    """Chunked SSD over the prompt + full attention at each shared block,
    emitting all decode states (window == prompt length at prefill)."""
    from .mamba2 import mamba_block_prefill

    x = embed_tokens(params["embed"].astype(cfg.compute_dtype), batch["tokens"], sh)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    g, k, tail = _group_shape(cfg)

    def inner(x, lp):
        x, state, conv = mamba_block_prefill(x, lp, cfg, sh)
        return x, (state, conv)

    def group_body(x, gp):
        x, (s, c) = jax.lax.scan(inner, x, gp)
        x, (k_full, v_full) = _shared_block(x, params["shared_attn"], cfg, sh, positions)
        return x, (s, c, k_full, v_full)

    x, (ssm_g, conv_g, k_g, v_g) = jax.lax.scan(group_body, x, params["groups"])
    if tail:
        x, (ssm_t, conv_t) = jax.lax.scan(inner, x, params["tail"])
    else:
        B = x.shape[0]
        ssm_t = jnp.zeros((1, B, cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                          jnp.float32)
        conv_t = jnp.zeros((1, B, cfg.conv_width, cfg.d_inner), cfg.compute_dtype)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x[:, -1:], params["unembed"].astype(x.dtype), sh)
    k_g = sh(k_g, None, "batch", "kv_seq", "kv_heads", None)
    v_g = sh(v_g, None, "batch", "kv_seq", "kv_heads", None)
    cache = {"ssm_g": ssm_g, "conv_g": conv_g, "ssm_t": ssm_t, "conv_t": conv_t,
             "attn_k": k_g, "attn_v": v_g, "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache
