"""Zamba2-7B [arXiv:2411.15242]: 81 Mamba2 layers d3584 (ssm_state=64,
d_inner 7168, 112 SSD heads) + ONE shared attention/MLP block (32H MHA,
head_dim 112, ff14336) applied every 6 SSM layers (13 applications + 3
tail SSM layers).  long_500k decode uses a 32k KV window for the shared
blocks; SSM state is O(1)."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv_heads=32, d_ff=14336, vocab=32000, ssm_state=64, ssm_headdim=64,
    expand=2, conv_width=4, ssm_chunk=256, attn_every=6, attn_window=32768,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", n_layers=7, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, ssm_state=16, ssm_headdim=16,
    ssm_chunk=8, attn_every=3, attn_window=0,
)
